"""End-to-end GraphD driver (the paper's full job lifecycle), declarative:

  describe the job -> the planner picks the physical plan -> one GraphDJob
  per analysis owns partition/spill, checkpoints + message logs, the
  superstep loop, single-shard fast recovery ([19]) and elastic rescale.

The last section shows the expert path: typed configs + the raw engine,
for when you want to pin the physical plan yourself.

    PYTHONPATH=src python examples/graph_analytics.py
"""

import os
import tempfile

import numpy as np

from repro.core import (
    SSSP, ChannelConfig, EngineConfig, GraphDEngine, GraphDJob, HashMin,
    MemoryBudget, PageRank, StreamConfig, plan,
)
from repro.graph import partition_graph_streamed, recode_ids, rmat_graph

graph = rmat_graph(scale=12, edge_factor=8, seed=42, directed=False,
                   sparse_ids=True)
print(f"graph: |V|={graph.n_vertices:,} |E|={graph.n_edges:,}")

N_MACHINES = 8  # one machine count for budgets AND id recoding below

with tempfile.TemporaryDirectory() as work:
    # --- PageRank, out-of-core, with checkpoints + message logs ------------
    # A tight RAM budget forces the planner out-of-core: edge streams spill
    # to <workdir>/edges automatically, and checkpoint_every=3 wires the
    # Checkpointer + message log (the persisted OMSs of §3.4) under the
    # same workdir.
    budget = MemoryBudget(ram_per_shard=96 << 10, n_shards=N_MACHINES)
    prog = PageRank(supersteps=9)
    print(plan(prog, graph, budget).explain(), "\n")
    job = GraphDJob(prog, graph, budget=budget,
                    workdir=os.path.join(work, "pagerank"),
                    checkpoint_every=3)
    print(f"planned mode: {job.plan.mode}"
          + (" + §4 pipeline" if job.plan.pipeline else ""))
    res = job.run()
    print(f"pagerank: {res.n_supersteps} supersteps, "
          f"final delta={res.history[-1].agg:.2e}, "
          f"planned/realized ram="
          f"{res.planned_ram}/{res.realized_ram} B")

    # --- machine 5 dies; only IT recomputes, replaying logged messages -----
    v5, a5 = job.recover_shard(5)
    # check the recovered rows against the completed run's public values,
    # mapping shard 5's positions back to original ids via the partition
    vmask5 = np.asarray(job.pg.vmask)[5]
    ids5 = np.asarray(job.pg.old_ids)[5][vmask5]
    ref5 = np.array([res.values[int(i)] for i in ids5])
    err = float(np.abs(np.asarray(v5)[vmask5] - ref5).max())
    print(f"fast recovery of shard 5: max err {err:.2e} (no global rerun)")
    job.close()

    # --- HashMin with an elastic rescale 8 -> 12 mid-job -------------------
    with GraphDJob(HashMin(), graph,
                   budget=MemoryBudget(n_shards=N_MACHINES)) as job2:
        job2.run(max_supersteps=4)
        r2 = job2.rescale(12).run()  # absorb 4 machines, continue in place
        comps = len(set(r2.values.values()))
        print(f"hash-min after 8->12 elastic rescale: {comps} components "
              f"(halted at superstep {r2.history[-1].step})")

    # --- SSSP: quiescence-driven, sparse skip() path -----------------------
    # SSSP sources are recoded ids; the recode map is deterministic per
    # (vertex_ids, n_shards) — N_MACHINES keeps it in lockstep with the
    # budget. (After construction the job's own map is public as job.rmap.)
    src = int(recode_ids(graph.vertex_ids, N_MACHINES)
              .to_new(np.array([int(graph.vertex_ids[0])]))[0])
    with GraphDJob(SSSP(src), graph,
                   budget=MemoryBudget(n_shards=N_MACHINES)) as job3:
        r3 = job3.run()
        reached = sum(1 for d in r3.values.values() if d < float("inf"))
        print(f"sssp: reached {reached:,}/{graph.n_vertices:,} vertices in "
              f"{r3.n_supersteps} supersteps")

    # --- expert path: typed configs + the raw engine -----------------------
    # When you want to pin the physical plan instead of budgeting for it:
    # partition + spill by hand and hand the engine an explicit EngineConfig
    # (the knobs the planner would otherwise derive).
    pgs, rmap, store = partition_graph_streamed(
        graph, n_shards=N_MACHINES, spill_dir=os.path.join(work, "expert")
    )
    eng = GraphDEngine(
        pgs, PageRank(supersteps=5),
        config=EngineConfig(
            mode="streamed",
            stream=StreamConfig(chunk_blocks=4, depth=2),
            channel=ChannelConfig(pipeline=True),  # §4 full-duplex overlap
        ),
        stream_store=store,
    )
    (values, active), hist = eng.run()
    st = eng.channel_stats
    print(f"expert path (raw engine, full-duplex streamed): "
          f"{len(hist)} supersteps, "
          f"sender overlap {st.sender_overlap_seconds()*1e3:.1f} ms, "
          f"receiver overlap {st.receiver_overlap_seconds()*1e3:.1f} ms")

print("done.")
