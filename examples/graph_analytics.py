"""End-to-end GraphD driver (the paper's full job lifecycle):

  load -> ID-recode -> partition -> compute (3 algorithms) with
  checkpointing + message logs -> simulate a machine failure ->
  fast-recover only the failed shard ([19]) -> elastic rescale 8->12 ->
  finish -> dump results.

    PYTHONPATH=src python examples/graph_analytics.py
"""

import os
import tempfile

import numpy as np

from repro.core import SSSP, GraphDEngine, HashMin, PageRank
from repro.core.checkpoint import Checkpointer, MessageLog, recover_shard
from repro.core.elastic import repartition
from repro.graph import partition_graph, rmat_graph

graph = rmat_graph(scale=12, edge_factor=8, seed=42, directed=False,
                   sparse_ids=True)
print(f"graph: |V|={graph.n_vertices:,} |E|={graph.n_edges:,}")
pg, rmap = partition_graph(graph, n_shards=8)

with tempfile.TemporaryDirectory() as work:
    # --- PageRank with checkpoints + message logs --------------------------
    ck = Checkpointer(os.path.join(work, "ckpt"), every=3)
    ml = MessageLog(os.path.join(work, "logs"))
    prog = PageRank(supersteps=9)
    eng = GraphDEngine(pg, prog, message_log=ml)
    ck.save(0, *eng.init())
    (values, active), hist = eng.run(checkpointer=ck, verbose=False)
    print(f"pagerank: {len(hist)} supersteps, "
          f"final delta={hist[-1].agg:.2e}")

    # --- machine 5 dies; only IT recomputes, replaying logged messages -----
    v5, a5 = recover_shard(pg, prog, failed=5, ckpt=ck, log=ml,
                           target_step=9)
    err = float(np.abs(np.asarray(v5) - np.asarray(values)[5]).max())
    print(f"fast recovery of shard 5: max err {err:.2e} (no global rerun)")

    # --- elastic: absorb 4 more machines mid-job ---------------------------
    eng2 = GraphDEngine(pg, HashMin())
    (v2, a2), h2 = eng2.run(max_supersteps=4)
    pg12, v12, a12 = repartition(pg, v2, a2, n_new=12)
    eng3 = GraphDEngine(pg12, HashMin())
    (v3, _), h3 = eng3.run(state=(v12, a12), start_step=4)
    comps = len(set(eng3.gather_values(v3).values()))
    print(f"hash-min after 8->12 elastic rescale: {comps} components "
          f"({len(h2)}+{len(h3)} supersteps)")

    # --- SSSP with the sparse skip() path ----------------------------------
    src = int(rmap.to_new(np.array([int(graph.vertex_ids[0])]))[0])
    eng4 = GraphDEngine(pg, SSSP(src), adapt_threshold=0.3)
    (v4, _), h4 = eng4.run()
    dists = eng4.gather_values(v4)
    reached = sum(1 for d in dists.values() if d < float("inf"))
    sparse_steps = sum(1 for h in h4 if h.mode == "sparse")
    print(f"sssp: reached {reached:,}/{graph.n_vertices:,} vertices in "
          f"{len(h4)} supersteps ({sparse_steps} sparse)")

print("done.")
