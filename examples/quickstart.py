"""Quickstart: PageRank with the declarative job API.

One call owns the whole lifecycle — the planner picks the execution mode
(in-memory recoded vs out-of-core streamed vs §4 pipelined) and sizes every
staging/window knob from the memory budget; the job partitions (spilling
edge streams to disk when the plan says so), runs, and hands back a
structured result.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import GraphDJob, MemoryBudget, PageRank, plan
from repro.graph import rmat_graph

# 1. load a graph (here: generated; loaders accept any edge list with
#    arbitrary 64-bit vertex ids — the recoding pass densifies them)
graph = rmat_graph(scale=12, edge_factor=16, seed=0, sparse_ids=True)
print(f"graph: |V|={graph.n_vertices:,} |E|={graph.n_edges:,}")

# 2. describe the machines, not the physical plan: 8 "machines", 256 KiB of
#    RAM each. The planner chooses the mode and derives the knobs — ask it
#    to explain itself before committing anything to disk.
budget = MemoryBudget(ram_per_shard=256 << 10, n_shards=8)
print(plan(PageRank(supersteps=10), graph, budget).explain(), "\n")

# 3. run the job (partition -> spill if needed -> engine -> supersteps)
with GraphDJob(PageRank(supersteps=10), graph, budget=budget) as job:
    result = job.run(verbose=True)

# 4. results, keyed by the original vertex ids, plus the audit trail
ranks = result.values
top = sorted(ranks.items(), key=lambda kv: -kv[1])[:5]
print("top-5 vertices by PageRank:")
for vid, r in top:
    print(f"  vertex {vid}: {r:.6f}")
print(f"rank mass: {sum(ranks.values()):.4f}")
s = result.summary()  # JSON-able: what was planned, what actually ran
print(f"mode={s['mode']} planned_ram={s['planned']['ram']}B "
      f"realized_ram={s['realized']['ram']}B "
      f"({s['n_supersteps']} supersteps)")
