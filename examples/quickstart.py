"""Quickstart: PageRank on a power-law graph with the GraphD engine.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import GraphDEngine, PageRank
from repro.graph import partition_graph, rmat_graph

# 1. load a graph (here: generated; loaders accept any edge list with
#    arbitrary 64-bit vertex ids — the recoding pass densifies them)
graph = rmat_graph(scale=12, edge_factor=16, seed=0, sparse_ids=True)
print(f"graph: |V|={graph.n_vertices:,} |E|={graph.n_edges:,}")

# 2. preprocess: ID-recode + hash-partition onto 8 "machines" (paper §5)
pg, recode_map = partition_graph(graph, n_shards=8)
print(pg.shape_summary)

# 3. run 10 supersteps of PageRank in the recoded (in-memory combining) mode
engine = GraphDEngine(pg, PageRank(supersteps=10), mode="recoded")
(values, active), history = engine.run(verbose=True)

# 4. results, keyed by the original vertex ids
ranks = engine.gather_values(values)
top = sorted(ranks.items(), key=lambda kv: -kv[1])[:5]
print("top-5 vertices by PageRank:")
for vid, r in top:
    print(f"  vertex {vid}: {r:.6f}")
print(f"rank mass: {sum(ranks.values()):.4f}")
