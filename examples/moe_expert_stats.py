"""MoE routing demo: the GraphD message-combining pattern applied to tokens
(DESIGN.md §Arch-applicability). Shows expert load distribution, capacity
drops, and the load-balance aux loss on a reduced qwen3-moe config.

    PYTHONPATH=src python examples/moe_expert_stats.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.tokens import synthetic_batch
from repro.models.moe import moe_ffn
from repro.models.transformer import init_params

cfg = get_config("qwen3-moe-235b-a22b").reduced()
params = init_params(cfg, jax.random.key(0))
moe_params = jax.tree.map(lambda p: p[0], params["groups"][0]["ffn"])

batch = synthetic_batch(cfg, 0, seq_len=64, global_batch=4)
x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cfg.dtype)

y, (aux, dropped) = moe_ffn(
    moe_params, x, n_experts=cfg.n_experts, topk=cfg.topk,
    capacity_factor=cfg.capacity_factor, n_shared=cfg.n_shared_experts,
)
print(f"moe: {cfg.n_experts} experts, top-{cfg.topk}")
print(f"  output shape      : {y.shape}")
print(f"  load-balance aux  : {float(aux):.4f} (1.0 = perfectly balanced)")
print(f"  capacity drops    : {float(dropped)*100:.2f}%")

logits = jnp.einsum("td,de->te",
                    x.reshape(-1, cfg.d_model).astype(jnp.float32),
                    moe_params["router"].astype(jnp.float32))
_, eidx = jax.lax.top_k(jax.nn.softmax(logits), cfg.topk)
load = jnp.bincount(eidx.reshape(-1), length=cfg.n_experts)
print(f"  expert load       : min={int(load.min())} max={int(load.max())} "
      f"mean={float(load.mean()):.1f}")
print("  (tokens = messages, experts = vertices, top-k routing = message "
      "sending, weighted sum = the SUM combiner)")
