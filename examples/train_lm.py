"""End-to-end training driver: train a ~20M-param minitron-family model for
a few hundred steps on the synthetic pipeline, with checkpoint + resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import subprocess
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
args = ap.parse_args()

subprocess.run(
    [sys.executable, "-m", "repro.launch.train",
     "--arch", "minitron-4b", "--reduced",
     "--steps", str(args.steps), "--batch", "8", "--seq", "128",
     "--ckpt-every", "100"],
    check=True,
)
