"""Batched serving example: prefill a batch of prompts, greedy-decode
continuations with ring-buffer KV caches (gemma3 family: 5:1 local:global
sliding-window attention, so the local caches stay window-sized).

    PYTHONPATH=src python examples/serve_lm.py
"""

import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve",
     "--arch", "gemma3-12b", "--reduced",
     "--batch", "4", "--prompt-len", "48", "--gen", "24"],
    check=True,
)
