"""Benchmark harness: one module per paper table. Prints CSV
``name,us_per_call,derived`` (benchmarks/common.emit)."""

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_hashmin, bench_kernels, bench_memory, bench_messages,
        bench_pagerank, bench_sssp,
    )

    print("name,us_per_call,derived")
    failed = []
    for mod in [bench_pagerank, bench_messages, bench_hashmin, bench_sssp,
                bench_memory, bench_kernels]:
        try:
            mod.main()
        except Exception:
            failed.append(mod.__name__)
            traceback.print_exc()
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
