"""Benchmark harness: one module per paper table. Prints CSV
``name,us_per_call,derived`` (benchmarks/common.emit) and consolidates
everything into one ``BENCH_PR5.json`` artifact — the perf trajectory's
seed record: per-bench wall-clock, the RAM model, the full-duplex overlap
milliseconds, and the payload-codec bytes-on-wire.

``--tiny`` runs the seconds-scale subset (the CI smoke job); ``--chaos``
runs ONLY the fixed-seed chaos-soak matrix (bench_chaos: coordinator
kill -9, peer reset, ENOSPC, bit-flip — the CI chaos-soak job) and gates
on every fault class recovering bit-identically; ``--out``
writes the consolidated JSON; ``--check`` fails the run when a required
section is missing or empty, when the receiver overlap is not positive,
when the lossless payload channel is under 1.5x, when the
``launch="processes"`` per-process RAM model grows with the process count,
when the semi-external hot cache fails to cut disk block reads below
pure streaming while staying inside the planner's ``hot_cache`` model,
or when the socket transport's measured link throughput does not beat the
file-exchange baseline (or its run left shared-filesystem exchange dirs
behind) — the acceptance gates, enforced where the numbers are produced.
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback

from benchmarks import common
from benchmarks.common import OVERLAP_MIN_CPUS, PAYLOAD_LOSSLESS_FLOOR

#: required BENCH_PR5.json sections; --check fails on a missing/empty one
REQUIRED_SECTIONS = ("wall_clock", "ram_model", "overlap", "bytes_on_wire",
                     "process_launch", "semi_external", "net")

#: the chaos-soak matrix (bench_chaos.CASES); --chaos --check fails unless
#: every class ran and recovered bit-identically
CHAOS_CASES = ("coord_kill", "peer_reset", "enospc_ckpt", "bitflip_log")


def _module_plan(tiny: bool, chaos: bool = False):
    if chaos:
        from benchmarks import bench_chaos

        # the soak is its own CI job: the perf sections stay out of it so
        # a chaos failure is unambiguously a recovery bug, not a perf gate
        return [("chaos", bench_chaos, [])]

    from benchmarks import (
        bench_hashmin, bench_kernels, bench_memory, bench_messages,
        bench_pagerank, bench_sssp,
    )

    if tiny:
        # bench_memory carries every PR-5 section and finishes in seconds;
        # the full-size table benches (scale 13-15 graphs) stay out of the
        # smoke budget
        return [("memory", bench_memory, ["--tiny"])]
    return [
        ("pagerank", bench_pagerank, []),
        ("messages", bench_messages, []),
        ("hashmin", bench_hashmin, []),
        ("sssp", bench_sssp, []),
        ("memory", bench_memory, []),
        ("kernels", bench_kernels, []),
    ]


def consolidate(records_by_bench: dict[str, list[dict]], tiny: bool,
                chaos: bool = False) -> dict:
    """Shape the per-bench emit() records into the BENCH_PR5 sections."""
    all_recs = [r for recs in records_by_bench.values() for r in recs]

    def values_of(name: str) -> dict:
        for r in all_recs:
            if r["name"] == name and "values" in r:
                return r["values"]
        return {}

    if chaos:
        # --chaos report: one section, one entry per fault class
        cases = {
            r["name"].split("/", 1)[1]: r.get("values", {})
            for r in all_recs
            if r["name"].startswith("chaos/") and r["name"] != "chaos/reference"
        }
        return dict(
            meta=dict(tiny=tiny, chaos=True,
                      benches=sorted(records_by_bench)),
            sections=dict(chaos=cases),
            records=records_by_bench,
        )

    wall_clock = [
        dict(name=r["name"], us=r["us"])
        for r in all_recs
        if r["us"] > 0 and ("superstep" in r["name"] or "/m_" in r["name"])
    ]
    ram_model = [
        dict(name=r["name"], derived=r["derived"])
        for r in all_recs
        if "ram" in r["name"] or "resident" in r["name"]
        or "model" in r["name"] or "planned_vs_measured" in r["name"]
    ]
    overlap = values_of("memory/pipeline_overlap")
    process_launch = values_of("memory/process_launch")
    semi_external = values_of("memory/semi_external")
    net = values_of("memory/net")
    wire = values_of("memory/payload_wire_lossless")
    bytes_on_wire = dict(
        lossless=wire,
        bf16=values_of("memory/payload_wire_bf16"),
    )
    return dict(
        meta=dict(tiny=tiny, benches=sorted(records_by_bench)),
        sections=dict(
            wall_clock=wall_clock,
            ram_model=ram_model,
            overlap=overlap,
            bytes_on_wire=bytes_on_wire if wire else {},
            process_launch=process_launch,
            semi_external=semi_external,
            net=net,
        ),
        records=records_by_bench,
    )


def check_chaos(report: dict) -> list[str]:
    """The chaos-soak acceptance gates: every fault class in the matrix
    ran, the drill really fired (respawn/recovery counts match), and the
    recovered run is bit-identical — no surviving silent-corruption path."""
    problems = []
    cases = (report.get("sections", {}) or {}).get("chaos") or {}
    for name in CHAOS_CASES:
        vals = cases.get(name)
        if not vals:
            problems.append(f"chaos case {name!r} missing from the soak")
            continue
        if not vals.get("identical"):
            problems.append(
                f"chaos case {name!r} diverged from the undisturbed "
                "reference — recovery is not bit-identical"
            )
        if vals.get("coord_restarts") != vals.get("expected_restarts"):
            problems.append(
                f"chaos case {name!r}: coordinator respawns "
                f"{vals.get('coord_restarts')!r} != expected "
                f"{vals.get('expected_restarts')!r} (drill misfired)"
            )
        if vals.get("recoveries") != vals.get("expected_recoveries"):
            problems.append(
                f"chaos case {name!r}: worker recoveries "
                f"{vals.get('recoveries')!r} != expected "
                f"{vals.get('expected_recoveries')!r} (drill misfired)"
            )
        if not vals.get("quarantined", True):
            problems.append(
                f"chaos case {name!r}: corrupt store was not quarantined"
            )
    return problems


def check(report: dict) -> list[str]:
    """The smoke-job acceptance gates; returns the list of violations."""
    if (report.get("meta") or {}).get("chaos"):
        return check_chaos(report)
    problems = []
    sections = report.get("sections", {})
    for name in REQUIRED_SECTIONS:
        if not sections.get(name):
            problems.append(f"BENCH_PR5 section {name!r} missing or empty")
    overlap = sections.get("overlap") or {}
    if overlap.get("recv_ms", 0) <= 0 or overlap.get("send_ms", 0) <= 0:
        problems.append(
            "both channel directions must have done work "
            f"(send_ms={overlap.get('send_ms')!r}, "
            f"recv_ms={overlap.get('recv_ms')!r})"
        )
    if overlap.get("cpus", 1) >= OVERLAP_MIN_CPUS:
        # overlap positivity is only a meaningful gate where the background
        # threads had a core to run on (mirrors bench_memory's own assert)
        if overlap.get("receiver_overlap_ms", 0) <= 0:
            problems.append(
                f"receiver overlap must be > 0 ms, got "
                f"{overlap.get('receiver_overlap_ms')!r}"
            )
        if overlap.get("sender_overlap_ms", 0) <= 0:
            problems.append(
                f"sender overlap must be > 0 ms, got "
                f"{overlap.get('sender_overlap_ms')!r}"
            )
    procs = sections.get("process_launch") or {}
    rams = procs.get("per_process_ram") or []
    if len(rams) < 2:
        problems.append(
            "process_launch must model >= 2 process counts, got "
            f"{procs.get('ns')!r}"
        )
    elif any(b > a for a, b in zip(rams, rams[1:])):
        problems.append(
            "per-process RAM must not grow with the process count: "
            f"ns={procs.get('ns')!r} ram={rams!r}"
        )
    semi = sections.get("semi_external") or {}
    if semi:
        if semi.get("semi_blocks", 0) >= semi.get("streamed_blocks", 0):
            problems.append(
                "semi-external must read strictly fewer edge blocks than "
                f"pure streaming: semi={semi.get('semi_blocks')!r} "
                f"streamed={semi.get('streamed_blocks')!r}"
            )
        if semi.get("late_semi", 0) >= semi.get("late_streamed", 0):
            problems.append(
                "semi-external must beat pure streaming on the sparse late "
                f"rounds: late_semi={semi.get('late_semi')!r} "
                f"late_streamed={semi.get('late_streamed')!r}"
            )
        cache_cap = semi.get("n_shards", 0) * semi.get("hot_cache_model", 0)
        if not 0 < semi.get("cached_bytes", 0) <= cache_cap:
            problems.append(
                "resident cache bytes must be positive and within the "
                f"planner's hot_cache model: "
                f"cached={semi.get('cached_bytes')!r} cap={cache_cap!r}"
            )
    net = sections.get("net") or {}
    if net:
        if net.get("link_bytes_per_s", 0) <= net.get("file_bytes_per_s", 0):
            problems.append(
                "measured socket link throughput must beat the "
                "file-exchange baseline: "
                f"link={net.get('link_bytes_per_s')!r} B/s "
                f"file={net.get('file_bytes_per_s')!r} B/s"
            )
        if not net.get("no_fs_exchange"):
            problems.append(
                "socket-transport run must not write shared-filesystem "
                "exchange dirs (announce markers found)"
            )
        if net.get("wire_bytes", 0) <= 0 or net.get("frames", 0) <= 0:
            problems.append(
                "socket transport moved no frames: "
                f"wire_bytes={net.get('wire_bytes')!r} "
                f"frames={net.get('frames')!r}"
            )
        if net.get("cpus", 1) >= OVERLAP_MIN_CPUS:
            if net.get("sender_overlap_ms", 0) <= 0:
                problems.append(
                    "socket-run sender overlap must be > 0 ms, got "
                    f"{net.get('sender_overlap_ms')!r}"
                )
            if net.get("receiver_overlap_ms", 0) <= 0:
                problems.append(
                    "socket-run receiver overlap must be > 0 ms, got "
                    f"{net.get('receiver_overlap_ms')!r}"
                )
    wire = (sections.get("bytes_on_wire") or {}).get("lossless") or {}
    if wire.get("ratio", 0) < PAYLOAD_LOSSLESS_FLOOR:
        problems.append(
            f"lossless payload channel must be >= "
            f"{PAYLOAD_LOSSLESS_FLOOR}x smaller, got "
            f"{wire.get('ratio')!r}"
        )
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale subset (CI smoke)")
    ap.add_argument("--chaos", action="store_true",
                    help="run ONLY the fixed-seed chaos-soak fault matrix "
                         "(coordinator kill, peer reset, ENOSPC, bit-flip)")
    ap.add_argument("--out", metavar="PATH", default=None,
                    help="write the consolidated BENCH_PR5.json here")
    ap.add_argument("--check", action="store_true",
                    help="fail unless every required section is present and "
                         "the overlap/wire acceptance gates hold (--chaos: "
                         "every fault class recovered bit-identically)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failed = []
    records_by_bench: dict[str, list[dict]] = {}
    for name, mod, mod_args in _module_plan(args.tiny, args.chaos):
        mark = len(common.all_records())
        argv = sys.argv
        try:
            sys.argv = [argv[0]] + mod_args  # argparse-driven mains
            mod.main()
        except Exception:
            failed.append(mod.__name__)
            traceback.print_exc()
        finally:
            sys.argv = argv
        records_by_bench[name] = common.records_since(mark)

    report = consolidate(records_by_bench, args.tiny, args.chaos)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {args.out}", file=sys.stderr)
    if args.check:
        for problem in check(report):
            failed.append(problem)
            print(f"CHECK FAILED: {problem}", file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
