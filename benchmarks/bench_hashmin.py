"""Table 5/6 — Hash-Min connected components: shrinking-workload behaviour.

The workload starts dense and sparsifies as labels converge; the engine's
auto dense->sparse dispatch (skip(), §3.2) should kick in. We report total
compute time per mode and the superstep-mode trajectory."""

from __future__ import annotations

import collections
import time

from benchmarks.common import emit
from repro.core import EngineConfig, GraphDEngine, HashMin
from repro.graph import partition_graph, rmat_graph


def main():
    g = rmat_graph(scale=14, edge_factor=8, seed=11, directed=False)
    pg, _ = partition_graph(g, n_shards=8, edge_block=512)

    for mode in ["basic", "recoded"]:
        eng = GraphDEngine(pg, HashMin(), config=EngineConfig(
            mode=mode, adapt_threshold=0.2, sparse_cap_frac=0.5))
        eng.run()  # warmup: compile both variants
        t0 = time.perf_counter()
        (_, _), hist = eng.run()
        dt = time.perf_counter() - t0
        modes = collections.Counter(h.mode for h in hist)
        emit(f"hashmin/total_{mode}", dt * 1e6,
             f"supersteps={len(hist)};sparse={modes.get('sparse', 0)}")

    # sparse-adaptive vs dense-forced (the skip() win on the tail supersteps)
    eng_d = GraphDEngine(pg, HashMin(),
                         config=EngineConfig(adapt_threshold=-1))
    eng_d.run()  # warmup
    t0 = time.perf_counter()
    (_, _), hist_d = eng_d.run()
    dt_dense = time.perf_counter() - t0
    eng_s = GraphDEngine(pg, HashMin(), config=EngineConfig(
        adapt_threshold=0.3, sparse_cap_frac=0.6))
    eng_s.run()  # warmup
    t0 = time.perf_counter()
    (_, _), hist_s = eng_s.run()
    dt_sparse = time.perf_counter() - t0
    emit("hashmin/dense_forced", dt_dense * 1e6, f"steps={len(hist_d)}")
    emit("hashmin/sparse_adaptive", dt_sparse * 1e6,
         f"speedup={dt_dense / dt_sparse:.2f}x")


if __name__ == "__main__":
    main()
