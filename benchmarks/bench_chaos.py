"""Chaos soak (PR 10): the fixed-seed fault matrix, run end to end.

Four distinct fault classes — one per recovery mechanism the chaos layer
must carry — each injected into a real multi-process HashMin run on the
tiny graph and compared bit-for-bit against an undisturbed reference:

- ``coord_kill`` (sockets): SIGKILL the coordinator mid-barrier, after an
  arrival is in but before the commit hits the WAL. The launcher respawns
  it; the successor restores from the WAL; workers reconnect and replay.
  Gate: exactly one coordinator respawn, zero worker respawns,
  bit-identical result.
- ``peer_reset`` (sockets): sever a data-plane socket mid-step with an
  injected ECONNRESET. The sender reconnects under its RetryPolicy and
  the RESUME handshake replays the lost runs from the outbox. Gate: zero
  recoveries (the connection heals in-step), bit-identical result.
- ``enospc_ckpt`` (files): ENOSPC on the very FIRST checkpoint dump —
  nothing is checkpointed yet, so the respawned worker must replay the
  whole prefix from the message log on the bootstrap state. Gate: one
  recovery, no torn ``.tmp`` checkpoint dirs, bit-identical result.
- ``bitflip_log`` (files): flip ONE bit in a spilled message-log blob;
  the write succeeds silently. Read-path CRC verification catches it,
  quarantines the poisoned store, and the worker respawns to re-receive.
  Gate: one recovery, the ``.quarantine`` dir exists, bit-identical
  result — the no-surviving-silent-corruption gate.

All schedules are fixed-seed (``FaultSchedule`` is deterministic), so a
failing case replays exactly under ``pytest tests/test_fault.py`` with
the same event dict. Every case emits one record; ``run.py --chaos
--check`` fails unless all four classes ran and recovered.
"""

from __future__ import annotations

import argparse
import copy
import os
import tempfile
import time

from benchmarks.common import emit, write_json
from repro.core import GraphDJob, HashMin, MemoryBudget
from repro.graph import rmat_graph

#: the soak matrix: (case, launch_opts overrides, checkpoint_every,
#: expected coordinator respawns, expected worker recoveries)
CASES = (
    ("coord_kill",
     {"transport": "sockets",
      "coord_kill": {"step": 1, "after_arrivals": 1}},
     2, 1, 0),
    ("peer_reset",
     {"transport": "sockets",
      "faults": {"seed": 11, "events": [
          {"site": "net.send", "kind": "reset", "shard": 1, "step": 1}]}},
     2, 0, 0),
    ("enospc_ckpt",
     {"faults": {"seed": 23, "events": [
         {"site": "io.write.ckpt", "kind": "enospc",
          "shard": 2, "step": 2}]}},
     2, 0, 1),
    ("bitflip_log",
     {"faults": {"seed": 41, "events": [
         {"site": "io.write.spill", "kind": "bitflip",
          "shard": 1, "step": 1, "where": "logs/"}]}},
     2, 0, 1),
)


def _job(g, workdir, **kw):
    return GraphDJob(HashMin(), g, budget=MemoryBudget(n_shards=3),
                     launch="processes", workdir=workdir, **kw)


def _save_artifacts(job, case: str) -> None:
    """Copy the run's post-mortem (failure-summary.json, per-worker failure
    records, the coordinator log) out of the soak's temp workdir into
    ``$CHAOS_ARTIFACTS/<case>/`` so CI can upload it after the temp dir is
    gone. Best-effort: a missing artifact is not a second failure."""
    import shutil

    out = os.path.join(os.environ.get("CHAOS_ARTIFACTS", "chaos-artifacts"),
                       case)
    procs_dir = job._dir("procs", getattr(job, "_tag", ""))
    try:
        os.makedirs(out, exist_ok=True)
        for name in ("failure-summary.json", "coord.log"):
            src = os.path.join(procs_dir, name)
            if os.path.isfile(src):
                shutil.copy(src, os.path.join(out, name))
        fdir = os.path.join(procs_dir, "failures")
        if os.path.isdir(fdir):
            shutil.copytree(fdir, os.path.join(out, "failures"),
                            dirs_exist_ok=True)
    except OSError:
        pass


def soak(g, ref_values, ref_history, case, opts, every, coord_restarts,
         recoveries, workdir):
    """One chaos case: run drilled, gate on recovery, emit the record."""
    launch_opts = dict(opts)
    launch_opts.setdefault("heartbeat_timeout", 5.0)
    job = _job(g, workdir, checkpoint_every=every, launch_opts=launch_opts)
    t0 = time.perf_counter()
    try:
        res = job.run()
    except Exception:
        _save_artifacts(job, case)
        job.close()
        raise
    wall = time.perf_counter() - t0
    identical = (
        res.values == ref_values
        and [(r.n_active, r.n_msgs) for r in res.history] == ref_history
    )
    got_restarts = job._last_run_coord_restarts
    got_recoveries = job._last_run_recoveries
    quarantined = True
    if case == "bitflip_log":
        quarantined = os.path.isdir(os.path.join(
            job._dir("logs", job._tag), "shard-1", "step-000001.quarantine"))
    ok = (identical and quarantined
          and got_restarts == coord_restarts
          and got_recoveries == recoveries)
    if not ok:
        _save_artifacts(job, case)
    job.close()
    emit(f"chaos/{case}", wall * 1e6,
         f"identical={identical};coord_restarts={got_restarts};"
         f"recoveries={got_recoveries};ok={ok}",
         identical=identical, coord_restarts=got_restarts,
         recoveries=got_recoveries, expected_restarts=coord_restarts,
         expected_recoveries=recoveries, quarantined=quarantined,
         supersteps=res.n_supersteps, ok=ok)
    assert identical, f"chaos case {case}: result diverged from reference"
    assert got_restarts == coord_restarts, (
        f"chaos case {case}: coordinator respawns "
        f"{got_restarts} != {coord_restarts} (drill misfired)"
    )
    assert got_recoveries == recoveries, (
        f"chaos case {case}: worker recoveries "
        f"{got_recoveries} != {recoveries} (drill misfired)"
    )
    assert quarantined, (
        f"chaos case {case}: poisoned store was not quarantined"
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", metavar="PATH", default=None)
    args = ap.parse_args()

    g = rmat_graph(scale=6, edge_factor=6, seed=5, weights="uniform")
    with tempfile.TemporaryDirectory(prefix="graphd-chaos-") as d:
        # the undisturbed reference every drilled run must match
        ref = _job(g, os.path.join(d, "ref"), checkpoint_every=2,
                   launch_opts={"heartbeat_timeout": 5.0})
        r = ref.run()
        ref_values = copy.deepcopy(r.values)
        ref_history = [(x.n_active, x.n_msgs) for x in r.history]
        ref.close()
        emit("chaos/reference", 0.0,
             f"supersteps={r.n_supersteps}", supersteps=r.n_supersteps)
        for i, (case, opts, every, restarts, recov) in enumerate(CASES):
            soak(g, ref_values, ref_history, case, opts, every, restarts,
                 recov, os.path.join(d, f"case-{i}"))

    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
