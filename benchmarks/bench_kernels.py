"""Kernel micro-benchmarks: Pallas (interpret mode on CPU) vs jnp oracle.

On CPU the interpret-mode kernel is NOT a performance claim — the numbers
recorded here are correctness-path costs; TPU performance is assessed
structurally in the §Roofline dry-run. The oracle timing column is the
meaningful CPU datapoint (it is the jnp path the engine actually uses on
CPU)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.graph import partition_graph, rmat_graph
from repro.graph.kblocks import build_kernel_layout, layout_stats
from repro.kernels import ops
from repro.kernels.ref import edge_combine_ref


def main():
    g = rmat_graph(scale=13, edge_factor=32, seed=5)
    pg, _ = partition_graph(g, n_shards=2, edge_block=512, vertex_pad=256)
    kl = build_kernel_layout(pg, BLK=256, SRC_WIN=256, DST_WIN=256)
    st = layout_stats(kl)
    emit("kernels/layout_fill", 0.0,
         f"fill={st['fill']:.3f};blocks={st['blocks']}")

    rng = np.random.default_rng(0)
    P = pg.P
    state3 = jnp.stack([
        jnp.asarray(rng.random(P, dtype=np.float32)),
        jnp.asarray(np.asarray(pg.degree)[0].astype(np.float32)),
        jnp.asarray((rng.random(P) < 0.5).astype(np.float32)),
    ])
    i, k = 0, 1
    ids = jnp.arange(kl.NB, dtype=jnp.int32)
    nk = jnp.int32(kl.NB)
    args = (state3, kl.sp[i, k], kl.dp[i, k], kl.w[i, k], ids, nk,
            kl.blk_swin[i, k], kl.blk_dwin[i, k])
    kw = dict(SRC_WIN=256, DST_WIN=256, msg_kind="div_deg", combiner="sum")

    us_k = time_fn(lambda *a: ops.edge_combine(*a, **kw), *args, iters=3)
    us_r = time_fn(lambda *a: edge_combine_ref(*a, **kw), *args, iters=3)
    edges = int((np.asarray(kl.sp[i, k]) >= 0).sum())
    emit("kernels/edge_combine_interpret", us_k, f"edges={edges}")
    emit("kernels/edge_combine_oracle", us_r,
         f"Medges_per_s={edges / us_r:.2f}")

    ar = jnp.asarray(rng.random(P, dtype=np.float32))
    cnt = jnp.zeros(P, jnp.int32)
    us_d = time_fn(
        lambda: ops.digest(ar, cnt, ar, cnt, combiner="sum", WIN=256),
        iters=3,
    )
    emit("kernels/digest_interpret", us_d, f"P={P}")


if __name__ == "__main__":
    main()
