"""Table 7/8 — SSSP/BFS: the sparse-frontier stress test.

BFS does O(|E|) total work across ALL supersteps — one PageRank superstep's
worth — so systems that rescan the full graph each superstep (X-Stream,
HaLoop) collapse here. We measure: (a) total time dense-forced vs
skip()-adaptive, (b) per-superstep bytes touched (the skip() saving), on the
pathological chain graph and a power-law RMAT."""

from __future__ import annotations

import collections
import time

import numpy as np

from benchmarks.common import emit
from repro.core import EngineConfig, GraphDEngine, SSSP
from repro.graph import chain_graph, partition_graph, rmat_graph


def _run(pg, src_new, adapt, cap, max_steps=4000):
    eng = GraphDEngine(pg, SSSP(src_new), config=EngineConfig(
        adapt_threshold=adapt, sparse_cap_frac=cap))
    eng.run(max_supersteps=max_steps)  # warmup: compile all variants
    t0 = time.perf_counter()
    (_, _), hist = eng.run(max_supersteps=max_steps)
    return time.perf_counter() - t0, hist


def main():
    # RMAT: shallow BFS, frontier dense in the middle supersteps
    g = rmat_graph(scale=15, edge_factor=16, seed=7)
    pg, rmap = partition_graph(g, n_shards=8, edge_block=256)
    src = int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])
    dt_dense, hist_d = _run(pg, src, adapt=-1, cap=0.5)
    dt_adapt, hist_s = _run(pg, src, adapt=0.3, cap=0.6)
    modes = collections.Counter(h.mode for h in hist_s)
    emit("sssp/rmat_dense_forced", dt_dense * 1e6,
         f"supersteps={len(hist_d)}")
    emit("sssp/rmat_adaptive", dt_adapt * 1e6,
         f"sparse={modes.get('sparse', 0)};speedup={dt_dense/dt_adapt:.2f}x")

    # chain: 1-vertex frontier for hundreds of supersteps (X-Stream's
    # admitted worst case, paper §6)
    gc = chain_graph(8192)
    pgc, rmapc = partition_graph(gc, n_shards=8, edge_block=64)
    srcc = int(rmapc.to_new(np.array([0]))[0])
    dt_dense, _ = _run(pgc, srcc, adapt=-1, cap=0.5)
    dt_adapt, hist = _run(pgc, srcc, adapt=0.9, cap=0.9)
    modes = collections.Counter(h.mode for h in hist)
    emit("sssp/chain_dense_forced", dt_dense * 1e6, "supersteps=8192")
    emit("sssp/chain_adaptive", dt_adapt * 1e6,
         f"sparse={modes.get('sparse', 0)};speedup={dt_dense/dt_adapt:.2f}x")

    # bytes saved by skip(): edge slots touched per sparse superstep
    total_blocks = pgc.n_shards * pgc.n_shards * pgc.n_blocks
    active_blocks = np.mean([h.density for h in hist]) * total_blocks
    emit("sssp/skip_block_fraction", 0.0,
         f"avg_active={active_blocks:.1f}/{total_blocks}")


if __name__ == "__main__":
    main()
