"""Lemma 1 / §3.3.3 / Theorem 1 — the O(|V|/n) memory bound, plus the §4
pipeline overlap and the varint-delta stream compression.

Measures: (a) hash-partition balance (max shard < 2|V|/n, Lemma 1),
(b) resident vs streamed bytes per shard (the DSS split: state array A in
"RAM" vs edge stream in the big tier) for the in-memory engine AND the
out-of-core ``streamed`` engine, (c) that the streamed resident footprint is
independent of |E| while disk grows — pipeline on AND off, (d) stream
throughput and the compute ∥ I/O overlap of the prefetching reader,
(e) sender overlap of the pipelined channel (transmit time hidden under
compute must be > 0), (f) on-disk bytes of compressed vs uncompressed edge
and message streams. Derived columns carry the bound checks.

``--tiny`` runs a seconds-scale subset (CI smoke job).
"""

from __future__ import annotations

import argparse
import os
import tempfile

import numpy as np

from benchmarks.common import emit, rss_bytes, stream_report, write_json
from repro.core import (
    ChannelConfig, DistinctInLabels, EngineConfig, GraphDEngine, GraphDJob,
    MemoryBudget, MessageSpillConfig, PageRank, StreamConfig, plan,
)
from repro.core.checkpoint import RunFileMessageLog
from repro.graph import (
    partition_graph, partition_graph_streamed, recode_ids, rmat_graph,
)


def _ram(m):
    return (m["resident"] + m["buffers"] + m["staging"]
            + m.get("msg_staging", 0) + m.get("channel", 0))


def _streamed_cfg(**kw):
    """EngineConfig for mode='streamed' from the old flat knob names."""
    return EngineConfig(
        mode="streamed",
        stream=StreamConfig(chunk_blocks=kw.pop("chunk_blocks", 8)),
        spill=MessageSpillConfig(slice_cap=kw.pop("slice_cap", 4096)),
        channel=ChannelConfig(pipeline=kw.pop("pipeline", False),
                              compress=kw.pop("compress", False)),
    )


def lemma1(g):
    V = g.n_vertices
    for n in [4, 16, 64]:
        rmap = recode_ids(g.vertex_ids, n)
        bound = 2 * V / n
        emit(f"memory/lemma1_n{n}", 0.0,
             f"max_shard={rmap.max_positions};bound={bound:.0f};"
             f"ok={rmap.max_positions < bound}")


def in_memory_model(g, edge_block):
    pg, _ = partition_graph(g, n_shards=8, edge_block=edge_block)
    eng = GraphDEngine(pg, PageRank(supersteps=3))
    m = eng.memory_model()
    emit("memory/resident_per_shard", 0.0, f"bytes={m['resident']}")
    emit("memory/buffers_per_shard", 0.0, f"bytes={m['buffers']}")
    emit("memory/streamed_per_shard", 0.0, f"bytes={m['streamed']}")
    emit("memory/resident_fraction", 0.0,
         f"{m['resident'] / (m['resident'] + m['streamed']):.4f}")


def streamed_model(g, edge_block, supersteps, chunk_blocks=8):
    """The tentpole measurement: resident footprint of mode='streamed' and
    the throughput/overlap of the disk tier."""
    with tempfile.TemporaryDirectory(prefix="graphd-stream-") as d:
        pg, _, store = partition_graph_streamed(
            g, 8, d, edge_block=edge_block
        )
        eng = GraphDEngine(pg, PageRank(supersteps=supersteps),
                           config=_streamed_cfg(chunk_blocks=chunk_blocks),
                           stream_store=store)
        rss0 = rss_bytes()
        (_, _), hist = eng.run()
        rss1 = rss_bytes()
        m = eng.memory_model()
        ram = m["resident"] + m["buffers"] + m["staging"]
        emit("memory/streamed_ram_per_shard", 0.0,
             f"bytes={ram};resident={m['resident']};buffers={m['buffers']};"
             f"staging={m['staging']}")
        emit("memory/streamed_disk_per_shard", 0.0, f"bytes={m['streamed']}")
        emit("memory/streamed_ram_vs_disk", 0.0,
             f"ratio={ram / max(m['streamed'], 1):.4f}")
        emit("memory/streamed_rss_delta", 0.0,
             f"bytes={max(rss1 - rss0, 0)}")
        per_step = np.mean([h.seconds for h in hist[1:]]) if len(hist) > 1 else hist[0].seconds
        emit("memory/streamed_superstep", per_step * 1e6,
             stream_report(eng._stream_reader))
        return ram


def streamed_nocombiner_model(g, edge_block, rounds=2, chunk_blocks=4):
    """The disk message tier (§3.3): a combiner-less apply_list program runs
    streamed with messages spilled to OMS runs and external-merged back —
    resident RAM is the vertex arrays + constant merge/slice windows."""
    with tempfile.TemporaryDirectory(prefix="graphd-oms-") as d:
        pg, _, store = partition_graph_streamed(g, 8, d,
                                                edge_block=edge_block)
        eng = GraphDEngine(
            pg, DistinctInLabels(n_groups=16, rounds=rounds),
            config=_streamed_cfg(chunk_blocks=chunk_blocks),
            stream_store=store,
        )
        rss0 = rss_bytes()
        (_, _), hist = eng.run()
        rss1 = rss_bytes()
        m = eng.memory_model()
        ram = _ram(m)
        emit("memory/oms_ram_per_shard", 0.0,
             f"bytes={ram};resident={m['resident']};"
             f"msg_staging={m['msg_staging']};"
             f"slice_cap={eng._msg_slice_cap_eff}")
        emit("memory/oms_disk_per_shard", 0.0, f"bytes={m['streamed']}")
        emit("memory/oms_rss_delta", 0.0, f"bytes={max(rss1 - rss0, 0)}")
        per_step = (np.mean([h.seconds for h in hist[1:]])
                    if len(hist) > 1 else hist[0].seconds)
        emit("memory/oms_superstep", per_step * 1e6,
             f"msgs={hist[-1].n_msgs};supersteps={len(hist)}")
        return ram


def independence_of_E(scale, factors, edge_block):
    """Same |V|, growing |E|: streamed RAM must stay flat — for the combiner
    path AND the combiner-less (message-spilling) path AND the pipelined
    path (whose channel budget is a compiled-in constant)."""
    rams, oms_rams, pipe_rams = [], [], []
    for ef in factors:
        g = rmat_graph(scale=scale, edge_factor=ef, seed=7)
        with tempfile.TemporaryDirectory(prefix="graphd-stream-") as d:
            pg, _, store = partition_graph_streamed(g, 8, d,
                                                    edge_block=edge_block)
            eng = GraphDEngine(pg, PageRank(supersteps=2),
                               config=_streamed_cfg(), stream_store=store)
            m = eng.memory_model()
            ram = _ram(m)
            rams.append(ram)
            emit(f"memory/streamed_ram_ef{ef}", 0.0,
                 f"E={g.n_edges};ram={ram};disk={m['streamed']}")
            eng_p = GraphDEngine(pg, PageRank(supersteps=2),
                                 config=_streamed_cfg(pipeline=True),
                                 stream_store=store)
            mp = eng_p.memory_model()
            pipe_rams.append(_ram(mp))
            emit(f"memory/pipelined_ram_ef{ef}", 0.0,
                 f"E={g.n_edges};ram={pipe_rams[-1]};"
                 f"channel={mp['channel']}")
        with tempfile.TemporaryDirectory(prefix="graphd-oms-") as d:
            pg, _, store = partition_graph_streamed(g, 8, d,
                                                    edge_block=edge_block)
            eng = GraphDEngine(
                pg, DistinctInLabels(n_groups=16),
                config=_streamed_cfg(slice_cap=8192), stream_store=store,
            )
            eng.run()
            m = eng.memory_model()
            oms_rams.append(_ram(m))
            emit(f"memory/oms_ram_ef{ef}", 0.0,
                 f"E={g.n_edges};ram={oms_rams[-1]};disk={m['streamed']}")
    emit("memory/streamed_ram_independent_of_E", 0.0,
         f"ok={len(set(rams)) == 1}")
    emit("memory/pipelined_ram_independent_of_E", 0.0,
         f"ok={len(set(pipe_rams)) == 1}")
    emit("memory/oms_ram_independent_of_E", 0.0,
         f"ok={len(set(oms_rams)) == 1}")


def pipeline_overlap(g, edge_block, supersteps, chunk_blocks=4):
    """§4's full-overlap claim, measured: the channel sender's busy time
    minus the compute thread's stalls on it = transmit time hidden under
    compute. ``ok`` iff that overlap is positive."""
    with tempfile.TemporaryDirectory(prefix="graphd-pipe-") as d:
        pg, _, store = partition_graph_streamed(g, 8, d,
                                                edge_block=edge_block)
        eng = GraphDEngine(pg, PageRank(supersteps=supersteps),
                           config=_streamed_cfg(chunk_blocks=chunk_blocks,
                                                pipeline=True),
                           stream_store=store)
        (_, _), hist = eng.run()
        st = eng.channel_stats
        ov = st.overlap_seconds()
        emit("memory/pipeline_sender_overlap", ov * 1e6,
             f"send_ms={st.send_seconds * 1e3:.1f};"
             f"stall_ms={st.stall_seconds * 1e3:.1f};"
             f"overlap_ms={ov * 1e3:.1f};packets={st.packets};"
             f"tx_KiB={st.payload_bytes >> 10};ok={ov > 0}")
        m = eng.memory_model()
        emit("memory/pipeline_ram_per_shard", 0.0,
             f"bytes={_ram(m)};channel={m['channel']}")
        per_step = (np.mean([h.seconds for h in hist[1:]])
                    if len(hist) > 1 else hist[0].seconds)
        emit("memory/pipeline_superstep", per_step * 1e6,
             stream_report(eng._stream_reader))


def compression_bytes_on_disk(g, edge_block, rounds=2):
    """The compress= knob end to end: varint-delta edge streams and message
    run logs must be measurably smaller than their raw counterparts."""
    with tempfile.TemporaryDirectory(prefix="graphd-cmp-") as d:
        _, _, plain = partition_graph_streamed(
            g, 8, os.path.join(d, "p"), edge_block=edge_block
        )
        pg, _, comp = partition_graph_streamed(
            g, 8, os.path.join(d, "c"), edge_block=edge_block, compress=True
        )
        pb, cb = plain.disk_bytes(), comp.disk_bytes()
        emit("memory/edge_stream_bytes", 0.0,
             f"plain={pb};compressed={cb};ratio={cb / max(pb, 1):.3f};"
             f"ok={cb < pb}")
        log_bytes = {}
        for compress in (False, True):
            tag = "c" if compress else "p"
            log = RunFileMessageLog(os.path.join(d, f"log-{tag}"))
            eng = GraphDEngine(
                pg, DistinctInLabels(n_groups=16, rounds=rounds),
                config=_streamed_cfg(compress=compress), stream_store=comp,
                message_log=log,
            )
            eng.run()
            log_bytes[tag] = sum(
                log._store_for(s).disk_bytes() for s in range(rounds)
            )
        emit("memory/msg_run_bytes", 0.0,
             f"plain={log_bytes['p']};compressed={log_bytes['c']};"
             f"ratio={log_bytes['c'] / max(log_bytes['p'], 1):.3f};"
             f"ok={log_bytes['c'] < log_bytes['p']}")


def planned_vs_measured(g, edge_block):
    """The planner's prediction vs what actually ran, per program class.

    The budget is set one byte below keeping the edge groups resident, so
    the planner must go out-of-core and size the chunk/window/fan-in knobs
    from the budget (the PR-2 ceiling: 559 KB of the measured combiner-less
    RAM was compiled-in merge/slice windows — here they are derived). The
    hard assertion is planned-vs-realized within 2x: the realized model is
    exact (same algebra, realized geometry + auto-bumped slice cap), so a
    drift means the predictive inputs lied. The RSS delta is reported
    alongside for the record; it is dominated by jit compilation and the
    allocator, so it gets no assertion."""
    for name, prog in (
        ("combiner", PageRank(supersteps=2)),
        ("oms", DistinctInLabels(n_groups=16, rounds=2)),
    ):
        loose = plan(prog, g, MemoryBudget(n_shards=8),
                     edge_block=edge_block)
        in_mem = loose.alternatives[0]  # recoded / basic, by construction
        budget = MemoryBudget(ram_per_shard=in_mem.ram_total - 1, n_shards=8)
        with tempfile.TemporaryDirectory(prefix="graphd-plan-") as d:
            job = GraphDJob(prog, g, budget=budget, workdir=d,
                            edge_block=edge_block)
            assert job.plan.mode == "streamed", job.plan.explain()
            rss0 = rss_bytes()
            res = job.run()
            rss1 = rss_bytes()
        planned, realized = res.planned_ram, res.realized_ram
        ratio = planned / max(realized, 1)
        # planned must honor the budget; realized may overshoot the estimate
        # by the hash-partition imbalance + the slice-cap auto-bump, both
        # covered by the 2x band
        ok = 0.5 <= ratio <= 2.0 and planned <= budget.ram_per_shard
        s = job.plan.config.spill
        emit(f"memory/planned_vs_measured_{name}", 0.0,
             f"planned={planned};realized={realized};ratio={ratio:.3f};"
             f"budget={budget.ram_per_shard};rss_delta={max(rss1 - rss0, 0)};"
             f"read_chunk={s.read_chunk};slice_cap={s.slice_cap};"
             f"merge_fanin={s.merge_fanin};ok={ok}")
        assert ok, (
            f"{name}: planned {planned} B vs realized {realized} B "
            f"(ratio {ratio:.3f}) under budget {budget.ram_per_shard} B\n"
            + job.plan.explain()
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale subset for CI smoke")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the emitted records as JSON (CI artifact)")
    args = ap.parse_args()

    if args.tiny:
        g = rmat_graph(scale=9, edge_factor=8, seed=3, sparse_ids=True)
        lemma1(g)
        in_memory_model(g, edge_block=64)
        streamed_model(g, edge_block=64, supersteps=2, chunk_blocks=4)
        streamed_nocombiner_model(g, edge_block=64, rounds=2, chunk_blocks=4)
        pipeline_overlap(g, edge_block=64, supersteps=2, chunk_blocks=4)
        compression_bytes_on_disk(g, edge_block=64)
        planned_vs_measured(g, edge_block=64)
        independence_of_E(scale=8, factors=[4, 16], edge_block=32)
    else:
        g = rmat_graph(scale=14, edge_factor=8, seed=3, sparse_ids=True)
        lemma1(g)
        in_memory_model(g, edge_block=512)
        streamed_model(g, edge_block=512, supersteps=3)
        streamed_nocombiner_model(g, edge_block=512, rounds=2)
        pipeline_overlap(g, edge_block=512, supersteps=3)
        compression_bytes_on_disk(g, edge_block=512)
        planned_vs_measured(g, edge_block=512)
        independence_of_E(scale=12, factors=[4, 16, 48], edge_block=256)
    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
