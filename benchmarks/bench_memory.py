"""Lemma 1 / §3.3.3 / Theorem 1 — the O(|V|/n) memory bound, plus the §4
pipeline overlap and the varint-delta stream compression.

Measures: (a) hash-partition balance (max shard < 2|V|/n, Lemma 1),
(b) resident vs streamed bytes per shard (the DSS split: state array A in
"RAM" vs edge stream in the big tier) for the in-memory engine AND the
out-of-core ``streamed`` engine, (c) that the streamed resident footprint is
independent of |E| while disk grows — pipeline on AND off, (d) stream
throughput and the compute ∥ I/O overlap of the prefetching reader,
(e) BOTH overlaps of the full-duplex pipelined channel (transmit AND
receiver digest hidden under compute must each be > 0 — asserted),
(f) payload-codec bytes on the wire (lossless >= 1.5x smaller — asserted),
(g) on-disk bytes of compressed vs uncompressed edge and message streams,
(h) the ``launch="processes"`` per-PROCESS RAM model staying flat as the
process count grows (asserted), with a real 3-process run's child ru_maxrss
recorded alongside,
(i) the semi-external hot-block cache: resident cache bytes within the
planner's ``hot_cache`` model and strictly fewer disk block reads than pure
streaming on SSSP's sparse late rounds (both asserted),
(j) the socket transport: measured framed-TCP link throughput vs the
file-exchange baseline (must win — asserted), plus a real 3-process
``transport="sockets"`` run's per-direction overlap with NO shared-
filesystem exchange dirs (asserted).
Derived columns carry the bound checks.

``--tiny`` runs a seconds-scale subset (CI smoke job).
"""

from __future__ import annotations

import argparse
import os
import tempfile

import numpy as np

from benchmarks.common import (
    OVERLAP_MIN_CPUS, PAYLOAD_LOSSLESS_FLOOR, emit, rss_bytes, stream_report,
    write_json,
)
from repro.core import (
    ChannelConfig, DistinctInLabels, EngineConfig, GraphDEngine, GraphDJob,
    MemoryBudget, MessageSpillConfig, PageRank, StreamConfig, plan,
)
from repro.core.checkpoint import RunFileMessageLog
from repro.graph import (
    partition_graph, partition_graph_streamed, recode_ids, rmat_graph,
)


def _ram(m):
    """RAM bytes of a streamed model — the planner's own summation, so a
    future model key cannot be counted there but dropped here."""
    from repro.core.plan import ram_total

    return ram_total(m, "streamed")


def _streamed_cfg(**kw):
    """EngineConfig for mode='streamed' from the old flat knob names."""
    return EngineConfig(
        mode="streamed",
        stream=StreamConfig(chunk_blocks=kw.pop("chunk_blocks", 8),
                            cache_bytes=kw.pop("cache_bytes", 0)),
        spill=MessageSpillConfig(slice_cap=kw.pop("slice_cap", 4096)),
        channel=ChannelConfig(pipeline=kw.pop("pipeline", False),
                              compress=kw.pop("compress", False),
                              compress_payload=kw.pop("compress_payload",
                                                      False),
                              full_duplex=kw.pop("full_duplex", True)),
    )


def lemma1(g):
    V = g.n_vertices
    for n in [4, 16, 64]:
        rmap = recode_ids(g.vertex_ids, n)
        bound = 2 * V / n
        emit(f"memory/lemma1_n{n}", 0.0,
             f"max_shard={rmap.max_positions};bound={bound:.0f};"
             f"ok={rmap.max_positions < bound}")


def in_memory_model(g, edge_block):
    pg, _ = partition_graph(g, n_shards=8, edge_block=edge_block)
    eng = GraphDEngine(pg, PageRank(supersteps=3))
    m = eng.memory_model()
    emit("memory/resident_per_shard", 0.0, f"bytes={m['resident']}")
    emit("memory/buffers_per_shard", 0.0, f"bytes={m['buffers']}")
    emit("memory/streamed_per_shard", 0.0, f"bytes={m['streamed']}")
    emit("memory/resident_fraction", 0.0,
         f"{m['resident'] / (m['resident'] + m['streamed']):.4f}")


def streamed_model(g, edge_block, supersteps, chunk_blocks=8):
    """The tentpole measurement: resident footprint of mode='streamed' and
    the throughput/overlap of the disk tier."""
    with tempfile.TemporaryDirectory(prefix="graphd-stream-") as d:
        pg, _, store = partition_graph_streamed(
            g, 8, d, edge_block=edge_block
        )
        eng = GraphDEngine(pg, PageRank(supersteps=supersteps),
                           config=_streamed_cfg(chunk_blocks=chunk_blocks),
                           stream_store=store)
        rss0 = rss_bytes()
        (_, _), hist = eng.run()
        rss1 = rss_bytes()
        m = eng.memory_model()
        ram = m["resident"] + m["buffers"] + m["staging"]
        emit("memory/streamed_ram_per_shard", 0.0,
             f"bytes={ram};resident={m['resident']};buffers={m['buffers']};"
             f"staging={m['staging']}")
        emit("memory/streamed_disk_per_shard", 0.0, f"bytes={m['streamed']}")
        emit("memory/streamed_ram_vs_disk", 0.0,
             f"ratio={ram / max(m['streamed'], 1):.4f}")
        emit("memory/streamed_rss_delta", 0.0,
             f"bytes={max(rss1 - rss0, 0)}")
        per_step = np.mean([h.seconds for h in hist[1:]]) if len(hist) > 1 else hist[0].seconds
        emit("memory/streamed_superstep", per_step * 1e6,
             stream_report(eng._stream_reader))
        return ram


def streamed_nocombiner_model(g, edge_block, rounds=2, chunk_blocks=4):
    """The disk message tier (§3.3): a combiner-less apply_list program runs
    streamed with messages spilled to OMS runs and external-merged back —
    resident RAM is the vertex arrays + constant merge/slice windows."""
    with tempfile.TemporaryDirectory(prefix="graphd-oms-") as d:
        pg, _, store = partition_graph_streamed(g, 8, d,
                                                edge_block=edge_block)
        eng = GraphDEngine(
            pg, DistinctInLabels(n_groups=16, rounds=rounds),
            config=_streamed_cfg(chunk_blocks=chunk_blocks),
            stream_store=store,
        )
        rss0 = rss_bytes()
        (_, _), hist = eng.run()
        rss1 = rss_bytes()
        m = eng.memory_model()
        ram = _ram(m)
        emit("memory/oms_ram_per_shard", 0.0,
             f"bytes={ram};resident={m['resident']};"
             f"msg_staging={m['msg_staging']};"
             f"slice_cap={eng._msg_slice_cap_eff}")
        emit("memory/oms_disk_per_shard", 0.0, f"bytes={m['streamed']}")
        emit("memory/oms_rss_delta", 0.0, f"bytes={max(rss1 - rss0, 0)}")
        per_step = (np.mean([h.seconds for h in hist[1:]])
                    if len(hist) > 1 else hist[0].seconds)
        emit("memory/oms_superstep", per_step * 1e6,
             f"msgs={hist[-1].n_msgs};supersteps={len(hist)}")
        return ram


def independence_of_E(scale, factors, edge_block):
    """Same |V|, growing |E|: streamed RAM must stay flat — for the combiner
    path AND the combiner-less (message-spilling) path AND the pipelined
    path (whose channel budget is a compiled-in constant)."""
    rams, oms_rams, pipe_rams = [], [], []
    for ef in factors:
        g = rmat_graph(scale=scale, edge_factor=ef, seed=7)
        with tempfile.TemporaryDirectory(prefix="graphd-stream-") as d:
            pg, _, store = partition_graph_streamed(g, 8, d,
                                                    edge_block=edge_block)
            eng = GraphDEngine(pg, PageRank(supersteps=2),
                               config=_streamed_cfg(), stream_store=store)
            m = eng.memory_model()
            ram = _ram(m)
            rams.append(ram)
            emit(f"memory/streamed_ram_ef{ef}", 0.0,
                 f"E={g.n_edges};ram={ram};disk={m['streamed']}")
            eng_p = GraphDEngine(pg, PageRank(supersteps=2),
                                 config=_streamed_cfg(pipeline=True),
                                 stream_store=store)
            mp = eng_p.memory_model()
            pipe_rams.append(_ram(mp))
            emit(f"memory/pipelined_ram_ef{ef}", 0.0,
                 f"E={g.n_edges};ram={pipe_rams[-1]};"
                 f"channel={mp['channel']}")
        with tempfile.TemporaryDirectory(prefix="graphd-oms-") as d:
            pg, _, store = partition_graph_streamed(g, 8, d,
                                                    edge_block=edge_block)
            eng = GraphDEngine(
                pg, DistinctInLabels(n_groups=16),
                config=_streamed_cfg(slice_cap=8192), stream_store=store,
            )
            eng.run()
            m = eng.memory_model()
            oms_rams.append(_ram(m))
            emit(f"memory/oms_ram_ef{ef}", 0.0,
                 f"E={g.n_edges};ram={oms_rams[-1]};disk={m['streamed']}")
    emit("memory/streamed_ram_independent_of_E", 0.0,
         f"ok={len(set(rams)) == 1}")
    emit("memory/pipelined_ram_independent_of_E", 0.0,
         f"ok={len(set(pipe_rams)) == 1}")
    emit("memory/oms_ram_independent_of_E", 0.0,
         f"ok={len(set(oms_rams)) == 1}")


def pipeline_overlap(g, edge_block, supersteps, chunk_blocks=4):
    """§4's full-overlap claim, measured in BOTH directions: the sender's
    busy time minus the compute thread's stalls on it = transmit hidden
    under compute (U_s ∥ U_c), and the background receiver's digest time
    minus the collect stalls = digest hidden under compute (U_r ∥ U_c).
    Both overlaps must be positive — the section asserts it (satellite:
    overlap accounting was sender-only through PR 4)."""
    with tempfile.TemporaryDirectory(prefix="graphd-pipe-") as d:
        pg, _, store = partition_graph_streamed(g, 8, d,
                                                edge_block=edge_block)
        # PR-4 baseline: the half-duplex (sender-only) pipeline
        eng_h = GraphDEngine(pg, PageRank(supersteps=supersteps),
                             config=_streamed_cfg(chunk_blocks=chunk_blocks,
                                                  pipeline=True,
                                                  full_duplex=False),
                             stream_store=store)
        (_, _), hist_h = eng_h.run()
        # a loaded scheduler can transiently starve the background threads
        # (overlap legally measures 0 even though the mechanism ran), so the
        # timing gate gets a bounded number of attempts before it judges
        for attempt in range(3):
            eng = GraphDEngine(pg, PageRank(supersteps=supersteps),
                               config=_streamed_cfg(
                                   chunk_blocks=chunk_blocks,
                                   pipeline=True),
                               stream_store=store)
            (_, _), hist = eng.run()
            st = eng.channel_stats
            s_ov = st.sender_overlap_seconds()
            r_ov = st.receiver_overlap_seconds()
            ok = s_ov > 0 and r_ov > 0
            if ok:
                break
        cpus = os.cpu_count() or 1
        emit("memory/pipeline_overlap", (s_ov + r_ov) * 1e6,
             f"send_ms={st.send_seconds * 1e3:.1f};"
             f"stall_ms={st.stall_seconds * 1e3:.1f};"
             f"sender_overlap_ms={s_ov * 1e3:.1f};"
             f"recv_ms={st.recv_seconds * 1e3:.1f};"
             f"recv_stall_ms={st.recv_stall_seconds * 1e3:.1f};"
             f"receiver_overlap_ms={r_ov * 1e3:.1f};"
             f"packets={st.packets};runs={st.recv_runs};"
             f"tx_KiB={st.wire_bytes >> 10};ok={ok}",
             sender_overlap_ms=s_ov * 1e3, receiver_overlap_ms=r_ov * 1e3,
             send_ms=st.send_seconds * 1e3, recv_ms=st.recv_seconds * 1e3,
             cpus=cpus)
        # the MECHANISM is deterministic and always asserted: both
        # background directions did real work
        assert st.packets > 0 and st.send_seconds > 0, "sender never ran"
        assert st.recv_runs > 0 and st.recv_seconds > 0, "receiver never ran"
        # overlap positivity needs a core for the background threads to run
        # ON while compute computes; on a single-vCPU runner the scheduler
        # may legally serialize them, so the timing gate applies only where
        # parallelism exists (same reason the wall-clock ok= is not asserted)
        if cpus >= OVERLAP_MIN_CPUS:
            assert ok, (
                f"full-duplex overlap must be positive both ways: "
                f"sender {s_ov * 1e3:.2f} ms, receiver {r_ov * 1e3:.2f} ms"
            )
        m = eng.memory_model()
        emit("memory/pipeline_ram_per_shard", 0.0,
             f"bytes={_ram(m)};channel={m['channel']};"
             f"receiver_staging={m.get('receiver_staging', 0)}")
        per_step = (np.mean([h.seconds for h in hist[1:]])
                    if len(hist) > 1 else hist[0].seconds)
        per_step_h = (np.mean([h.seconds for h in hist_h[1:]])
                      if len(hist_h) > 1 else hist_h[0].seconds)
        # wall-clock vs the PR-4 half-duplex baseline on the same graph
        # (reported, not asserted: CI machines make timing assertions flaky)
        emit("memory/pipeline_superstep", per_step * 1e6,
             stream_report(eng._stream_reader)
             + f";half_duplex_us={per_step_h * 1e6:.1f};"
             f"speedup={per_step_h / max(per_step, 1e-12):.2f}x;"
             f"ok={per_step <= per_step_h * 1.25}",
             full_duplex_us=per_step * 1e6, half_duplex_us=per_step_h * 1e6)


def payload_wire_bytes(g, edge_block, supersteps, chunk_blocks=4):
    """The compress_payload= knob on the wire: bytes the channel actually
    appended vs the fixed-width bytes the same packets would have cost.
    The lossless codec must shrink the payload channel >= 1.5x (asserted —
    the graph and seed are fixed, so the ratio is deterministic); the bf16
    scheme is reported alongside."""
    with tempfile.TemporaryDirectory(prefix="graphd-wire-") as d:
        pg, _, store = partition_graph_streamed(
            g, 8, d, edge_block=edge_block, compress=True,
            compress_payload=True,
        )
        ratios = {}
        for scheme in ("lossless", "bf16"):
            eng = GraphDEngine(
                pg, PageRank(supersteps=supersteps),
                config=_streamed_cfg(chunk_blocks=chunk_blocks,
                                     pipeline=True, compress=True,
                                     compress_payload=scheme),
                stream_store=store,
            )
            eng.run()
            st = eng.channel_stats
            ratios[scheme] = st.wire_ratio()
            emit(f"memory/payload_wire_{scheme}", 0.0,
                 f"fixed_KiB={st.payload_bytes >> 10};"
                 f"wire_KiB={st.wire_bytes >> 10};"
                 f"ratio={st.wire_ratio():.3f}x;"
                 f"ok={st.wire_ratio() >= (PAYLOAD_LOSSLESS_FLOOR if scheme == 'lossless' else 2.0)}",
                 fixed_bytes=st.payload_bytes, wire_bytes=st.wire_bytes,
                 ratio=st.wire_ratio())
        assert ratios["lossless"] >= PAYLOAD_LOSSLESS_FLOOR, (
            f"lossless payload channel only {ratios['lossless']:.3f}x "
            f"smaller than uncompressed (floor: {PAYLOAD_LOSSLESS_FLOOR}x)"
        )


def compression_bytes_on_disk(g, edge_block, rounds=2):
    """The compress= knob end to end: varint-delta edge streams and message
    run logs must be measurably smaller than their raw counterparts."""
    with tempfile.TemporaryDirectory(prefix="graphd-cmp-") as d:
        _, _, plain = partition_graph_streamed(
            g, 8, os.path.join(d, "p"), edge_block=edge_block
        )
        pg, _, comp = partition_graph_streamed(
            g, 8, os.path.join(d, "c"), edge_block=edge_block, compress=True
        )
        pb, cb = plain.disk_bytes(), comp.disk_bytes()
        emit("memory/edge_stream_bytes", 0.0,
             f"plain={pb};compressed={cb};ratio={cb / max(pb, 1):.3f};"
             f"ok={cb < pb}")
        log_bytes = {}
        for compress in (False, True):
            tag = "c" if compress else "p"
            log = RunFileMessageLog(os.path.join(d, f"log-{tag}"))
            eng = GraphDEngine(
                pg, DistinctInLabels(n_groups=16, rounds=rounds),
                config=_streamed_cfg(compress=compress), stream_store=comp,
                message_log=log,
            )
            eng.run()
            log_bytes[tag] = sum(
                log._store_for(s).disk_bytes() for s in range(rounds)
            )
        emit("memory/msg_run_bytes", 0.0,
             f"plain={log_bytes['p']};compressed={log_bytes['c']};"
             f"ratio={log_bytes['c'] / max(log_bytes['p'], 1):.3f};"
             f"ok={log_bytes['c'] < log_bytes['p']}")


def process_launch_model(g, edge_block, supersteps=2):
    """``launch="processes"``: the planner's per-PROCESS RAM must be flat
    (non-increasing) as the process count grows — each worker holds the
    O(|V|/n) vertex state plus constant stream/channel windows, so adding
    processes never raises any single process's footprint (the paper's
    scale-out story, now with real OS processes). The model numbers are
    asserted; a real 3-process run over the shared-filesystem transport
    is driven alongside and the children's peak ru_maxrss recorded for the
    report only (jit + allocator noise make child-RSS assertions flaky)."""
    import resource
    import time as _time

    ns, rams = [], []
    for n in (2, 3, 4):
        p = plan(PageRank(supersteps=supersteps), g,
                 MemoryBudget(n_shards=n), edge_block=edge_block,
                 launch="processes")
        assert p.launch == "processes" and p.mode == "streamed" and p.pipeline
        ns.append(n)
        rams.append(p.ram_total)
        emit(f"memory/procs_ram_n{n}", 0.0,
             f"per_process_ram={p.ram_total}")
    flat = all(b <= a for a, b in zip(rams, rams[1:]))

    with tempfile.TemporaryDirectory(prefix="graphd-procs-") as d:
        job = GraphDJob(PageRank(supersteps=supersteps), g,
                        budget=MemoryBudget(n_shards=3),
                        edge_block=edge_block, launch="processes", workdir=d)
        t0 = _time.perf_counter()
        res = job.run()
        wall = _time.perf_counter() - t0
        job.close()
    child_rss = resource.getrusage(resource.RUSAGE_CHILDREN).ru_maxrss * 1024
    emit("memory/process_launch", wall / max(res.n_supersteps, 1) * 1e6,
         f"ns={ns};per_process_ram={rams};flat={flat};"
         f"supersteps={res.n_supersteps};wall_s={wall:.2f};"
         f"child_maxrss={child_rss}",
         ns=ns, per_process_ram=rams, flat=flat,
         supersteps=res.n_supersteps, child_maxrss=child_rss)
    assert flat, (
        f"per-process RAM model must not grow with the process count: "
        f"{dict(zip(ns, rams))}"
    )


def socket_net(g, edge_block, supersteps=2):
    """The socket transport (launch/net.py): the measured per-link
    throughput of the framed TCP path must beat the file-exchange baseline
    it replaced (same bytes, write+fsync+read — asserted), and a real
    3-process ``transport="sockets"`` run must (a) leave NO shared-
    filesystem exchange dirs behind (no announce markers — runs travel as
    frames, asserted) and (b) hide transmit and receiver digest under
    compute, reported as the per-direction overlap of the summed worker
    channel stats (gated only where a core exists to overlap on, like the
    in-process pipeline section)."""
    import time as _time

    from repro.launch.net import probe_file_throughput, probe_link_throughput

    with tempfile.TemporaryDirectory(prefix="graphd-net-") as d:
        # throughput probes: the same 8 MiB through both transports (the
        # link probe frames+CRCs every chunk, so the comparison is honest);
        # a loaded machine can transiently starve either side, so the
        # ordering gate gets a bounded number of attempts before it judges
        for attempt in range(3):
            link_bw = probe_link_throughput()
            file_bw = probe_file_throughput(os.path.join(d, "probe"))
            if link_bw > file_bw:
                break
        job = GraphDJob(PageRank(supersteps=supersteps), g,
                        budget=MemoryBudget(n_shards=3),
                        edge_block=edge_block, launch="processes",
                        launch_opts=dict(transport="sockets"),
                        workdir=os.path.join(d, "job"))
        t0 = _time.perf_counter()
        res = job.run()
        wall = _time.perf_counter() - t0
        procs_dir = job._dir("procs", job._tag)
        # the whole point of the transport: no announce/exchange dirs ever
        # touch the shared filesystem (checked BEFORE close() sweeps)
        no_fs_exchange = not os.path.exists(
            os.path.join(procs_dir, "announce"))
        net = dict(job._last_run_net)
        job.close()
    cpus = os.cpu_count() or 1
    s_ov = net["net_send_s"] - net["net_stall_s"]
    r_ov = net["net_recv_s"] - net["net_recv_stall_s"]
    ok = link_bw > file_bw and no_fs_exchange
    emit("memory/net", wall / max(res.n_supersteps, 1) * 1e6,
         f"link_MiBps={link_bw / 2**20:.1f};file_MiBps={file_bw / 2**20:.1f};"
         f"speedup={link_bw / max(file_bw, 1.0):.2f}x;"
         f"send_ms={net['net_send_s'] * 1e3:.1f};"
         f"stall_ms={net['net_stall_s'] * 1e3:.1f};"
         f"sender_overlap_ms={s_ov * 1e3:.1f};"
         f"recv_ms={net['net_recv_s'] * 1e3:.1f};"
         f"recv_stall_ms={net['net_recv_stall_s'] * 1e3:.1f};"
         f"receiver_overlap_ms={r_ov * 1e3:.1f};"
         f"wire_KiB={int(net['net_wire_bytes']) >> 10};"
         f"frames={int(net['net_frames'])};"
         f"no_fs_exchange={no_fs_exchange};ok={ok}",
         link_bytes_per_s=link_bw, file_bytes_per_s=file_bw,
         sender_overlap_ms=s_ov * 1e3, receiver_overlap_ms=r_ov * 1e3,
         send_ms=net["net_send_s"] * 1e3, recv_ms=net["net_recv_s"] * 1e3,
         wire_bytes=int(net["net_wire_bytes"]),
         frames=int(net["net_frames"]), supersteps=res.n_supersteps,
         no_fs_exchange=no_fs_exchange, cpus=cpus)
    # deterministic gates: frames moved real bytes, nothing hit the fs
    assert no_fs_exchange, "socket run wrote shared-filesystem exchange dirs"
    assert net["net_wire_bytes"] > 0 and net["net_frames"] > 0, (
        "socket transport moved no frames"
    )
    assert link_bw > file_bw, (
        f"framed TCP link ({link_bw / 2**20:.1f} MiB/s) must beat the "
        f"file-exchange baseline ({file_bw / 2**20:.1f} MiB/s)"
    )
    # timing gates mirror pipeline_overlap: only where parallelism exists
    if cpus >= OVERLAP_MIN_CPUS:
        assert s_ov > 0 and r_ov > 0, (
            f"socket-run overlap must be positive both ways: "
            f"sender {s_ov * 1e3:.2f} ms, receiver {r_ov * 1e3:.2f} ms"
        )


def semi_external(g, edge_block, chunk_blocks=4):
    """The adaptive semi-external tier (streams/residency.py): SSSP's
    shrinking frontier makes late rounds sparse, and a hot-block cache
    sized to the planner's ``hot_cache`` model must (a) keep its resident
    bytes within that model and (b) read STRICTLY fewer edge blocks from
    disk than pure streaming on the same run — re-touched blocks are served
    from RAM; skip()-elided blocks cost nothing either way. Both gates are
    asserted here and re-checked from the consolidated report by
    ``benchmarks/run.py --check``."""
    from repro.core import SSSP

    with tempfile.TemporaryDirectory(prefix="graphd-semi-") as d:
        pg, rmap, store = partition_graph_streamed(
            g, 8, d, edge_block=edge_block
        )
        src = int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])
        n = pg.n_shards
        nonempty = store.nonempty_blocks()
        # the planner's "fits entirely" point: per-shard share of the whole
        # decoded edge stream (the cap estimate_memory's hot_cache sizing
        # uses)
        cache = -(-nonempty * store.block_bytes() // n)
        hist = {}
        eng = {}
        for tag, cache_bytes in (("streamed", 0), ("semi", cache)):
            e = GraphDEngine(
                pg, SSSP(src),
                config=_streamed_cfg(chunk_blocks=chunk_blocks,
                                     cache_bytes=cache_bytes),
                stream_store=store,
            )
            (_, _), h = e.run()
            hist[tag], eng[tag] = h, e
        reads = {t: sum(r.blocks_read for r in h) for t, h in hist.items()}
        # "late rounds": everything past the first superstep — the frontier
        # has started shrinking and blocks are being re-touched
        late = {t: sum(r.blocks_read for r in h[1:])
                for t, h in hist.items()}
        skipped = sum(r.blocks_skipped for r in hist["semi"])
        hits = sum(r.cache_hits for r in hist["semi"])
        res = eng["semi"]._residency
        model = eng["semi"].memory_model()
        cached = res.cached_bytes
        # gate (a): resident cache bytes within the planner's per-shard
        # hot_cache term times the shard count (ONE residency serves all n
        # emulated shards; see GraphDEngine's streamed init)
        ram_ok = 0 < cached <= n * model["hot_cache"]
        # gate (b): strictly fewer disk block reads on the sparse tail
        reads_ok = (late["semi"] < late["streamed"]
                    and reads["semi"] < reads["streamed"])
        reduction = reads["streamed"] / max(reads["semi"], 1)
        emit("memory/semi_external", 0.0,
             f"streamed_blocks={reads['streamed']};"
             f"semi_blocks={reads['semi']};reduction={reduction:.2f}x;"
             f"late_streamed={late['streamed']};late_semi={late['semi']};"
             f"hits={hits};skipped={skipped};cached_bytes={cached};"
             f"hot_cache_model={model['hot_cache']};n_shards={n};"
             f"supersteps={len(hist['semi'])};ok={ram_ok and reads_ok}",
             streamed_blocks=reads["streamed"], semi_blocks=reads["semi"],
             late_streamed=late["streamed"], late_semi=late["semi"],
             reduction=reduction, cache_hits=hits, blocks_skipped=skipped,
             cached_bytes=cached, hot_cache_model=model["hot_cache"],
             n_shards=n)
        assert ram_ok, (
            f"cached {cached} B outside the planner model "
            f"({n} x {model['hot_cache']} B)"
        )
        assert reads_ok, (
            f"semi-external must read strictly fewer blocks than pure "
            f"streaming: total {reads}, late {late}"
        )


def planned_vs_measured(g, edge_block):
    """The planner's prediction vs what actually ran, per program class.

    The budget is set one byte below keeping the edge groups resident, so
    the planner must go out-of-core and size the chunk/window/fan-in knobs
    from the budget (the PR-2 ceiling: 559 KB of the measured combiner-less
    RAM was compiled-in merge/slice windows — here they are derived). The
    hard assertion is planned-vs-realized within 2x: the realized model is
    exact (same algebra, realized geometry + auto-bumped slice cap), so a
    drift means the predictive inputs lied. The RSS delta is reported
    alongside for the record; it is dominated by jit compilation and the
    allocator, so it gets no assertion."""
    for name, prog in (
        ("combiner", PageRank(supersteps=2)),
        ("oms", DistinctInLabels(n_groups=16, rounds=2)),
    ):
        loose = plan(prog, g, MemoryBudget(n_shards=8),
                     edge_block=edge_block)
        in_mem = loose.alternatives[0]  # recoded / basic, by construction
        budget = MemoryBudget(ram_per_shard=in_mem.ram_total - 1, n_shards=8)
        with tempfile.TemporaryDirectory(prefix="graphd-plan-") as d:
            job = GraphDJob(prog, g, budget=budget, workdir=d,
                            edge_block=edge_block)
            assert job.plan.mode == "streamed", job.plan.explain()
            rss0 = rss_bytes()
            res = job.run()
            rss1 = rss_bytes()
        planned, realized = res.planned_ram, res.realized_ram
        ratio = planned / max(realized, 1)
        # planned must honor the budget; realized may overshoot the estimate
        # by the hash-partition imbalance + the slice-cap auto-bump, both
        # covered by the 2x band
        ok = 0.5 <= ratio <= 2.0 and planned <= budget.ram_per_shard
        s = job.plan.config.spill
        emit(f"memory/planned_vs_measured_{name}", 0.0,
             f"planned={planned};realized={realized};ratio={ratio:.3f};"
             f"budget={budget.ram_per_shard};rss_delta={max(rss1 - rss0, 0)};"
             f"read_chunk={s.read_chunk};slice_cap={s.slice_cap};"
             f"merge_fanin={s.merge_fanin};ok={ok}")
        assert ok, (
            f"{name}: planned {planned} B vs realized {realized} B "
            f"(ratio {ratio:.3f}) under budget {budget.ram_per_shard} B\n"
            + job.plan.explain()
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="seconds-scale subset for CI smoke")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the emitted records as JSON (CI artifact)")
    args = ap.parse_args()

    if args.tiny:
        g = rmat_graph(scale=9, edge_factor=8, seed=3, sparse_ids=True)
        lemma1(g)
        in_memory_model(g, edge_block=64)
        streamed_model(g, edge_block=64, supersteps=2, chunk_blocks=4)
        streamed_nocombiner_model(g, edge_block=64, rounds=2, chunk_blocks=4)
        pipeline_overlap(g, edge_block=64, supersteps=2, chunk_blocks=4)
        payload_wire_bytes(g, edge_block=64, supersteps=2, chunk_blocks=4)
        compression_bytes_on_disk(g, edge_block=64)
        semi_external(g, edge_block=64, chunk_blocks=4)
        planned_vs_measured(g, edge_block=64)
        process_launch_model(g, edge_block=64, supersteps=2)
        socket_net(g, edge_block=64, supersteps=2)
        independence_of_E(scale=8, factors=[4, 16], edge_block=32)
    else:
        g = rmat_graph(scale=14, edge_factor=8, seed=3, sparse_ids=True)
        lemma1(g)
        in_memory_model(g, edge_block=512)
        streamed_model(g, edge_block=512, supersteps=3)
        streamed_nocombiner_model(g, edge_block=512, rounds=2)
        pipeline_overlap(g, edge_block=512, supersteps=3)
        payload_wire_bytes(g, edge_block=512, supersteps=3)
        compression_bytes_on_disk(g, edge_block=512)
        semi_external(g, edge_block=512)
        planned_vs_measured(g, edge_block=512)
        process_launch_model(g, edge_block=512, supersteps=2)
        socket_net(g, edge_block=512, supersteps=2)
        independence_of_E(scale=12, factors=[4, 16, 48], edge_block=256)
    if args.json:
        write_json(args.json)


if __name__ == "__main__":
    main()
