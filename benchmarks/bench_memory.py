"""Lemma 1 / §3.3.3 — the O(|V|/n) memory bound.

Measures: (a) hash-partition balance (max shard < 2|V|/n, Lemma 1),
(b) resident vs streamed bytes per shard (the DSS split: state array A in
"RAM" vs edge stream in the big tier), (c) the constant-size exchange
buffers. Derived columns carry the bound check."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import GraphDEngine, PageRank
from repro.graph import partition_graph, recode_ids, rmat_graph


def main():
    g = rmat_graph(scale=14, edge_factor=8, seed=3, sparse_ids=True)
    V = g.n_vertices
    for n in [4, 16, 64]:
        rmap = recode_ids(g.vertex_ids, n)
        bound = 2 * V / n
        emit(f"memory/lemma1_n{n}", 0.0,
             f"max_shard={rmap.max_positions};bound={bound:.0f};"
             f"ok={rmap.max_positions < bound}")

    pg, _ = partition_graph(g, n_shards=8, edge_block=512)
    eng = GraphDEngine(pg, PageRank(supersteps=3))
    m = eng.memory_model()
    emit("memory/resident_per_shard", 0.0, f"bytes={m['resident']}")
    emit("memory/buffers_per_shard", 0.0, f"bytes={m['buffers']}")
    emit("memory/streamed_per_shard", 0.0, f"bytes={m['streamed']}")
    emit("memory/resident_fraction", 0.0,
         f"{m['resident'] / (m['resident'] + m['streamed']):.4f}")


if __name__ == "__main__":
    main()
