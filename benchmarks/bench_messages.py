"""Table 4 — message generation vs transmission/combine split.

The paper shows U_c (message generation, incl. edge streaming) takes a small
fraction of the superstep while transmission dominates — justifying OMS
buffering (C3). We measure the same decomposition: local combine (scatter)
alone vs the full superstep (combine + ring exchange + digest + apply),
per mode. Derived column = generation share of the superstep.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import GraphDEngine, PageRank
from repro.core.engine import _combine_scatter, _contrib_dense
from repro.graph import partition_graph, rmat_graph


def main():
    g = rmat_graph(scale=15, edge_factor=16, seed=7)
    pg, _ = partition_graph(g, n_shards=8, edge_block=512)
    prog = PageRank(supersteps=3)
    eng = GraphDEngine(pg, prog)
    values, active = eng.init()

    # M-Gene: vmapped local combine over all (shard, dest) pairs — exactly
    # the U_c work of one superstep, no exchange.
    def gen_only(values, active):
        def per_shard(pg_, v, a):
            def per_dest(d):
                return _contrib_dense(prog, pg_, v, a, jnp.int32(1), d,
                                      _combine_scatter)
            return jax.vmap(per_dest)(jnp.arange(pg.n_shards))
        return jax.vmap(per_shard)(pg, values, active)

    gen = jax.jit(gen_only)
    us_gen = time_fn(gen, values, active, iters=3)
    us_full = time_fn(
        lambda v, a: eng._step_dense(pg, v, a, jnp.int32(1)),
        values, active, iters=3,
    )
    emit("messages/m_gene", us_gen, f"share={us_gen / us_full:.2f}")
    emit("messages/superstep_total", us_full,
         f"exchange_share={1 - us_gen / us_full:.2f}")

    # raw (IO-Basic) exchange volume vs combined (IO-Recoded) volume
    raw = pg.n_shards * pg.n_shards * pg.E_cap * 8  # (dst,msg) pairs
    combined = pg.n_shards * pg.n_shards * pg.P * 8  # A_s buffers
    emit("messages/bytes_ratio_raw_vs_combined", 0.0,
         f"raw={raw};combined={combined};x={raw / combined:.2f}")


if __name__ == "__main__":
    main()
