"""Shared benchmark utilities. All benchmarks run on real CPU with
moderate-size graphs; times are per-superstep wall clock after jit warmup."""

from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, iters=5, warmup=1):
    """Median wall time of fn(*args) in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


#: PR-5 acceptance-gate thresholds, shared by bench_memory's inline asserts
#: and benchmarks/run.py's artifact --check so the two layers cannot drift
PAYLOAD_LOSSLESS_FLOOR = 1.5  # min wire shrink of the lossless payload codec
OVERLAP_MIN_CPUS = 2  # overlap-positivity gates need a core to overlap ON

_RECORDS: list[dict] = []


def emit(name: str, us: float, derived: str, **values):
    """One benchmark record: printed as CSV, kept for the JSON artifacts.
    ``values`` carries machine-readable numbers (benchmarks/run.py builds
    the consolidated BENCH_PR5.json sections from them — string parsing of
    the ``derived`` column is not a stable interface)."""
    print(f"{name},{us:.1f},{derived}")
    rec = dict(name=name, us=round(us, 1), derived=derived)
    if values:
        rec["values"] = values
    _RECORDS.append(rec)


def records_since(mark: int) -> list[dict]:
    """Records emitted after ``mark`` (= an earlier len(all_records()))."""
    return _RECORDS[mark:]


def all_records() -> list[dict]:
    return _RECORDS


def write_json(path: str):
    """Dump everything emit()ed so far as a JSON record list (uploaded as a
    CI artifact so memory/throughput regressions are inspectable per run)."""
    import json

    with open(path, "w") as f:
        json.dump(_RECORDS, f, indent=1)


def rss_bytes() -> int:
    """Current resident-set size of this process (Linux; 0 if unavailable).
    Used by bench_memory to show the streamed mode's footprint is real, not
    just the Lemma-1 model."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmRSS:"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    return 0


def stream_report(reader) -> str:
    """Derived-column summary of a StreamReader's last stream() pass."""
    s = reader.stats
    return (
        f"blocks={s.blocks_read};edges={s.edges_staged};"
        f"MiB={s.bytes_read / 2**20:.2f};"
        f"read_ms={s.read_seconds * 1e3:.1f};wait_ms={s.wait_seconds * 1e3:.1f};"
        f"edges_per_s={s.throughput_edges_per_s():.3g}"
    )
