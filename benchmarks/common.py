"""Shared benchmark utilities. All benchmarks run on real CPU with
moderate-size graphs; times are per-superstep wall clock after jit warmup."""

from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, iters=5, warmup=1):
    """Median wall time of fn(*args) in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
