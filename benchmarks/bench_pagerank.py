"""Table 2/3 — PageRank: IO-Basic vs IO-Basic+combiner vs IO-Recoded vs the
Pallas-kernel engine, plus the ID-recoding preprocessing cost column.

The paper's claim: IO-Recoded eliminates external sort/group-by, so it
approaches the in-memory system's speed; IO-Basic pays the sort + raw
message volume. Derived column reports MTEPS (million traversed edges/s).
"""

from __future__ import annotations

import time

import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import EngineConfig, GraphDEngine, PageRank
from repro.graph import partition_graph, rmat_graph


def main():
    g = rmat_graph(scale=15, edge_factor=16, seed=7, sparse_ids=True)
    t0 = time.perf_counter()
    pg, rmap = partition_graph(g, n_shards=8, edge_block=512, vertex_pad=64)
    t_prep = time.perf_counter() - t0
    emit("pagerank/preprocess_recode", t_prep * 1e6,
         f"V={g.n_vertices};E={g.n_edges}")

    for mode in ["basic", "basic_sc", "recoded"]:
        eng = GraphDEngine(pg, PageRank(supersteps=3),
                           config=EngineConfig(mode=mode))
        state = eng.init()
        us = time_fn(
            lambda s: eng._step_dense(eng.pg, s[0], s[1], jnp.int32(1)),
            state, iters=3,
        )
        emit(f"pagerank/superstep_{mode}", us,
             f"MTEPS={g.n_edges / us:.1f}")

    eng = GraphDEngine(pg, PageRank(supersteps=3),
                       config=EngineConfig(backend="pallas",
                                           kernel_windows=64))
    state = eng.init()
    us = time_fn(
        lambda s: eng._step_dense(eng.pg, s[0], s[1], jnp.int32(1)),
        state, iters=3,
    )
    emit("pagerank/superstep_pallas_interpret", us,
         f"MTEPS={g.n_edges / us:.1f}")


if __name__ == "__main__":
    main()
