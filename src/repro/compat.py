"""Version-compatibility shims.

The repo targets the current jax API surface; CI containers may ship an
older release. Keep every cross-version branch here so call sites stay
clean:

* ``shard_map`` — moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
  and renamed its replication-check kwarg ``check_rep`` -> ``check_vma``.
* ``cost_analysis`` — older jax returns a one-element list of dicts from
  ``Compiled.cost_analysis()``, newer jax returns the dict directly.
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` on old;
    ``check_vma=None`` means the version default."""
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
    )


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every jax version."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}
