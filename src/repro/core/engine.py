"""The DSS superstep engine (paper §3–§5), one SPMD body, two drivers.

Execution modes (benchmarked against each other, mirroring Tables 2–8):

* ``recoded``  — paper §5 (IO-Recoded): sender-side in-memory scatter-combine
  into ``A_s`` (one destination at a time), ring exchange, receiver-side
  in-memory digest into ``A_r``. No sorting anywhere. The ring is a classic
  reduce-scatter with a static shift-by-one ``ppermute``: at round r shard i
  contributes its messages for destination ``(i + n-1-r) mod n`` into the
  travelling accumulator — compute for round r+1 overlaps the collective
  permute of round r, which is exactly the paper's U_c ∥ U_s overlap (C3).

* ``basic``    — paper §3.3 (IO-Basic): raw ``(dst, payload)`` messages are
  exchanged uncombined (``all_to_all``), the receiver sorts by destination and
  segment-combines — the IMS merge-sort. Network bytes ∝ |E| (vs ∝ |V| for
  recoded), the measured gap reproduces the IO-Basic vs IO-Recoded rows.

* ``basic_sc`` — IO-Basic *with* combiner: the sender sort-combines each
  OMS (the external merge-sort of §3.3.1) before the ring exchange; transfer
  volume matches ``recoded`` but pays the sort.

* ``streamed`` — the paper's actual out-of-core deployment (§3, Theorem 1):
  per-shard resident state is ONLY the O(|V|/n) vertex arrays (values,
  active bitmap, degree, masks) plus constant-size combine buffers; the edge
  groups live on local disk in a ``streams.EdgeStreamStore`` and arrive
  group-by-group through a double-buffered ``streams.StreamReader`` whose
  background thread stages the next block chunk while the device digests the
  current one (U_c ∥ U_s at the host/device boundary). The §3.2 ``skip()``
  test runs against the store's block manifest BEFORE any I/O, so inactive
  blocks are never read off disk. Resident bytes are independent of |E| —
  see ``GraphDEngine.memory_model()`` and benchmarks/bench_memory.py.
  Typically paired with ``graph.partition_graph_streamed`` (spill at
  partition time, vertex-only PartitionedGraph). Host-driven: no mesh /
  Pallas backend; pick it when the graph does not fit device memory.
  With ``pipeline=True`` the §4 pipeline comes on, full duplex: a
  background sender (``streams/channel.py``) serializes each combined
  outgoing group (positions varint-delta compressed with ``compress=True``,
  payloads through the lossless/bf16 payload codec with
  ``compress_payload=``) and appends it to the destination's inbox run
  files, while a background receiver digests the runs already landed — both
  directions hidden under the fold of the next group, a bounded in-flight
  budget, and per-source owner views of the edge store (each emulated
  machine maps only its own rows). ``full_duplex=False`` falls back to the
  sender-only pipeline.

Sparse adaptation (C2, ``skip()``): per destination group the engine skips
edge blocks whose source range contains no active vertex, using the
``blk_lo/blk_hi`` metadata and a prefix sum over the active bitmap. The
sparse variant gathers only ``sparse_cap`` blocks (a compiled-in bound); the
host driver auto-dispatches dense vs sparse from the measured frontier
density, and the worst case equals one full dense scan — guarantee (3) of
§3.2.

The SPMD body runs identically under ``jax.vmap(axis_name=...)`` (n shards
emulated on one device — used by tests/benchmarks) and ``shard_map`` over a
device mesh (the production path; the dry-run lowers it on 256/512 chips).
"""

from __future__ import annotations

import dataclasses
import os
import time
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.api import Combiner, ShardContext, VertexProgram
from repro.core.config import MODES, ConfigError, EngineConfig
from repro.graph.partition import PartitionedGraph


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _shard_ctx(pg: PartitionedGraph, axis: str) -> ShardContext:
    return ShardContext(
        shard=lax.axis_index(axis),
        n_shards=pg.n_shards,
        n_vertices=pg.n_vertices,
        P=pg.P,
        degree=pg.degree,
        vmask=pg.vmask,
        old_ids=pg.old_ids,
        gids=pg.gids,
    )


def _active_prefix(active: jax.Array) -> jax.Array:
    """(P+1,) inclusive-prefix of the active bitmap; block [lo,hi] has an
    active source iff prefix[hi+1] - prefix[lo] > 0 (skip() test, §3.2)."""
    return jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(active.astype(jnp.int32))]
    )


def _block_active(pg: PartitionedGraph, prefix: jax.Array, lo, hi) -> jax.Array:
    nonempty = hi >= 0
    cnt = prefix[jnp.clip(hi + 1, 0, pg.P)] - prefix[jnp.clip(lo, 0, pg.P)]
    return nonempty & (cnt > 0)


# --------------------------------------------------------------------------
# local combine (the U_c hot loop): gen messages for one destination group and
# combine them into A_s. Dense, sparse (skip) and sort (merge-sort) variants.
# --------------------------------------------------------------------------

def _gen_messages(program, values, degree, sp, dp, w, active, step):
    """Gather source state, evaluate program.message, mask invalid/inactive."""
    spc = jnp.clip(sp, 0)
    aval = values[spc]
    adeg = degree[spc]
    aact = (sp >= 0) & active[spc]
    msg = program.message(aval, adeg, w, step).astype(program.msg_dtype)
    e0 = jnp.asarray(
        program.combiner.e0 if program.combiner is not None else 0,
        dtype=program.msg_dtype,
    )
    return jnp.where(aact, msg, e0), dp, aact


def _combine_scatter(program, P_dest, msg, dp, aact):
    """IO-Recoded: direct in-memory scatter-combine (A_s, paper §5)."""
    comb = program.combiner
    A_s = comb.identity((P_dest,), program.msg_dtype)
    A_s = comb.scatter(A_s, dp, msg)
    cnt = jnp.zeros((P_dest,), jnp.int32).at[dp].add(aact.astype(jnp.int32))
    return A_s, cnt


def _combine_sort(program, P_dest, msg, dp, aact):
    """IO-Basic w/ combiner: sort by destination then combine (merge-sort)."""
    comb = program.combiner
    key = jnp.where(aact, dp, P_dest)  # invalid entries sort to the tail
    skey, smsg, sact = lax.sort((key, msg, aact.astype(jnp.int32)), num_keys=1)
    A_s = comb.identity((P_dest,), program.msg_dtype)
    A_s = comb.scatter(A_s, jnp.where(skey < P_dest, skey, 0),
                       jnp.where(skey < P_dest, smsg,
                                 jnp.asarray(comb.e0, program.msg_dtype)))
    cnt = jnp.zeros((P_dest,), jnp.int32).at[skey].add(sact, mode="drop")
    return A_s, cnt


def _contrib_dense(program, pg, values, active, step, dest, combine):
    sp = lax.dynamic_index_in_dim(pg.src_pos, dest, 0, keepdims=False)
    dp = lax.dynamic_index_in_dim(pg.dst_pos, dest, 0, keepdims=False)
    w = lax.dynamic_index_in_dim(pg.eweight, dest, 0, keepdims=False)
    msg, dp, aact = _gen_messages(program, values, pg.degree, sp, dp, w, active, step)
    return combine(program, pg.P, msg, dp, aact)


def _contrib_pallas(program, pg, kl, values, active, prefix, step, dest):
    """Kernel-backed contribution: the fused Pallas edge_combine with the
    always-on skip-compacted block list (degenerates to the dense scan when
    the frontier is dense — the paper's adaptivity with zero dispatch)."""
    from repro.kernels import ops as kops

    pick = lambda a: lax.dynamic_index_in_dim(a, dest, 0, keepdims=False)
    sp, dp, w = pick(kl.sp), pick(kl.dp), pick(kl.w)
    swin, dwin = pick(kl.blk_swin), pick(kl.blk_dwin)
    lo, hi = pick(kl.blk_lo), pick(kl.blk_hi)
    keep = kops.skip_keep_mask(lo, hi, dwin, prefix)
    ids, nk = kops.compact_blocks(keep)
    # Sanitize ±inf (e.g. unreached SSSP distances) before the one-hot MXU
    # gather: 0 * inf = NaN would poison whole window rows. Active vertices
    # are always finite and inactive gathers are masked to e0 afterwards, so
    # a large-finite sentinel is exact.
    vals_f = jnp.nan_to_num(
        values.astype(jnp.float32), nan=0.0, posinf=1e30, neginf=-1e30
    )
    state3 = jnp.stack(
        [
            vals_f,
            pg.degree.astype(jnp.float32),
            active.astype(jnp.float32),
        ],
        axis=0,
    )
    A_s, cnt = kops.edge_combine(
        state3, sp, dp, w, ids, nk, swin, dwin,
        SRC_WIN=kl.SRC_WIN, DST_WIN=kl.DST_WIN,
        msg_kind=program.msg_kind, combiner=program.combiner.name,
    )
    return A_s, cnt.astype(jnp.int32)


def _contrib_sparse(program, pg, values, active, prefix, step, dest, cap, combine):
    """skip(): gather only active edge blocks (≤ cap of them) for this group."""
    B, nb = pg.edge_block, pg.n_blocks
    lo = lax.dynamic_index_in_dim(pg.blk_lo, dest, 0, keepdims=False)
    hi = lax.dynamic_index_in_dim(pg.blk_hi, dest, 0, keepdims=False)
    act_blk = _block_active(pg, prefix, lo, hi)
    (idx,) = jnp.nonzero(act_blk, size=cap, fill_value=nb)
    take = lambda a, fill: jnp.take(
        lax.dynamic_index_in_dim(a, dest, 0, keepdims=False).reshape(nb, B),
        idx, axis=0, mode="fill", fill_value=fill,
    ).reshape(cap * B)
    sp = take(pg.src_pos, -1)
    dp = take(pg.dst_pos, 0)
    w = take(pg.eweight, 0.0)
    msg, dp, aact = _gen_messages(program, values, pg.degree, sp, dp, w, active, step)
    return combine(program, pg.P, msg, dp, aact)


# --------------------------------------------------------------------------
# exchanges
# --------------------------------------------------------------------------

def _ring_exchange(program, pg, values, active, step, axis, contrib,
                   digest=None):
    """Ring reduce-scatter of per-destination combined buffers (§4.2/§5).

    Static shift-by-one permutation; n rounds; the accumulator arriving at
    shard i in round r is destined for ``(i + n-1-r) mod n``, so shard i folds
    in its own A_s for that destination and forwards. Round r+1's local
    combine is independent of round r's permute -> XLA overlaps them (C3).

    ``digest(acc_A, acc_cnt, A_s, cnt)`` merges a contribution into the
    travelling accumulator (default: jnp combine; the Pallas backend fuses it
    in kernels/digest.py).
    """
    n = pg.n_shards
    i = lax.axis_index(axis)
    comb: Combiner = program.combiner
    if digest is None:
        digest = lambda A, c, A2, c2: (comb.combine(A, A2), c + c2)
    perm = [(j, (j + 1) % n) for j in range(n)]

    acc = contrib((i + n - 1) % n)
    if n == 1:
        return acc

    def _round(r, acc):
        acc = jax.tree.map(lambda x: lax.ppermute(x, axis, perm), acc)
        dest = (i + (n - 1 - r)) % n
        A_s, cnt = contrib(dest)
        return digest(acc[0], acc[1], A_s, cnt)

    return lax.fori_loop(1, n, _round, acc)


def _basic_exchange(program, pg, values, active, step, axis):
    """IO-Basic: raw (dst, payload) pairs all-to-all, receiver-side merge-sort
    into the IMS, then one combining pass (§3.3.2)."""
    comb: Combiner = program.combiner
    Pn = pg.P
    msg, dp, aact = _gen_messages(
        program, values, pg.degree, pg.src_pos, pg.dst_pos, pg.eweight, active, step
    )  # (n, E_cap) each
    dp_send = jnp.where(aact, dp, Pn).astype(jnp.int32)
    recv_dp = lax.all_to_all(dp_send, axis, split_axis=0, concat_axis=0)
    recv_msg = lax.all_to_all(msg, axis, split_axis=0, concat_axis=0)
    flat_dp = recv_dp.reshape(-1)
    flat_msg = recv_msg.reshape(-1)
    # IMS construction: sort received messages by destination id
    sdp, smsg = lax.sort((flat_dp, flat_msg), num_keys=1)
    valid = sdp < Pn
    cnt = jnp.zeros((Pn,), jnp.int32).at[sdp].add(valid.astype(jnp.int32), mode="drop")
    if comb is None:  # non-combiner program: apply_list consumes the runs
        return None, cnt, sdp, smsg
    A_r = comb.identity((Pn,), program.msg_dtype)
    A_r = comb.scatter(A_r, jnp.where(valid, sdp, 0),
                       jnp.where(valid, smsg, jnp.asarray(comb.e0, program.msg_dtype)))
    return A_r, cnt, sdp, smsg


# --------------------------------------------------------------------------
# the SPMD superstep
# --------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass
class StepStats:
    n_active: jax.Array  # global active vertices after apply
    n_msgs: jax.Array  # global messages digested this superstep
    agg: jax.Array  # program aggregator (psum)
    density: jax.Array  # fraction of edge blocks active for NEXT superstep
    max_group_blocks: jax.Array  # max active blocks in any (shard,dest) group
    # (hard bound for the sparse path: sparse is safe iff this ≤ sparse_cap)


def _compact_exchange(program, pg, values, active, step, axis):
    """§Perf (beyond paper): one-hop all_to_all of *compact* combined buffers
    — bf16 message values + 1-byte has-msg flags (vs f32+int32 on the ring:
    8 B -> 3 B per slot, one rounding per message instead of per hop).
    Receiver digests in f32."""
    comb = program.combiner
    dests = jnp.arange(pg.n_shards, dtype=jnp.int32)
    A_s_all, cnt_all = jax.vmap(
        lambda d: _contrib_dense(program, pg, values, active, step, d,
                                 _combine_scatter)
    )(dests)
    wire_A = A_s_all.astype(jnp.bfloat16)
    wire_h = (cnt_all > 0).astype(jnp.int8)
    recv_A = lax.all_to_all(wire_A, axis, split_axis=0, concat_axis=0)
    recv_h = lax.all_to_all(wire_h, axis, split_axis=0, concat_axis=0)
    A_r = comb.reduce(recv_A.astype(program.msg_dtype), 0)
    cnt = jnp.sum(recv_h.astype(jnp.int32), 0)
    return A_r, cnt


def superstep_spmd(
    program: VertexProgram,
    pg: PartitionedGraph,
    values: jax.Array,
    active: jax.Array,
    step: jax.Array,
    *,
    axis: str,
    mode: str = "recoded",
    sparse_cap: int | None = None,
    kl=None,  # graph.kblocks.KernelLayout per-shard view => Pallas backend
):
    """One full superstep: scatter -> exchange -> digest -> apply -> vote."""
    ctx = _shard_ctx(pg, axis)

    if mode == "recoded_compact":
        A_r, cnt = _compact_exchange(program, pg, values, active, step, axis)
    elif mode == "basic" and program.combiner is None:
        # general Pregel path: destination-sorted message LISTS (§3.3.2)
        _, cnt, sdp, smsg = _basic_exchange(
            program, pg, values, active, step, axis
        )
        has_msg = (cnt > 0) & pg.vmask
        new_values, new_active = program.apply_list(
            values, pg.degree, sdp, smsg, has_msg, active, step, ctx
        )
        return _finish_superstep(
            program, pg, values, new_values, new_active, cnt, has_msg, axis
        )
    elif mode == "basic":
        A_r, cnt, _, _ = _basic_exchange(program, pg, values, active, step, axis)
    elif kl is not None:
        from repro.kernels import ops as kops

        prefix = _active_prefix(active)
        contrib = lambda dest: _contrib_pallas(
            program, pg, kl, values, active, prefix, step, dest
        )
        digest = lambda A, c, A2, c2: kops.digest(
            A, c, A2, c2, combiner=program.combiner.name,
            WIN=kl.DST_WIN,
        )
        A_r, cnt = _ring_exchange(
            program, pg, values, active, step, axis, contrib, digest=digest
        )
        A_r = A_r.astype(program.msg_dtype)
    else:
        combine = _combine_sort if mode == "basic_sc" else _combine_scatter
        if sparse_cap is not None:
            prefix = _active_prefix(active)
            contrib = lambda dest: _contrib_sparse(
                program, pg, values, active, prefix, step, dest, sparse_cap, combine
            )
        else:
            contrib = lambda dest: _contrib_dense(
                program, pg, values, active, step, dest, combine
            )
        A_r, cnt = _ring_exchange(program, pg, values, active, step, axis, contrib)

    has_msg = (cnt > 0) & pg.vmask
    new_values, new_active = program.apply(
        values, pg.degree, A_r, has_msg, active, step, ctx
    )
    return _finish_superstep(
        program, pg, values, new_values, new_active, cnt, has_msg, axis
    )


def _finish_superstep(program, pg, values, new_values, new_active, cnt,
                      has_msg, axis):
    """Shared superstep tail: halt voting, aggregator, frontier stats."""
    new_active = new_active & pg.vmask
    n_active = lax.psum(jnp.sum(new_active.astype(jnp.int32)), axis)
    n_msgs = lax.psum(jnp.sum(cnt), axis)
    agg = program.aggregate(values, new_values, has_msg)
    agg = (
        lax.psum(jnp.sum(agg.astype(jnp.float32)), axis)
        if agg is not None
        else jnp.float32(0)
    )
    # frontier density for the next superstep (drives dense/sparse dispatch)
    prefix2 = _active_prefix(new_active)
    act_blk = _block_active(pg, prefix2, pg.blk_lo, pg.blk_hi)  # (n, n_blocks)
    nonempty = pg.blk_hi >= 0
    num = lax.psum(jnp.sum(act_blk.astype(jnp.int32)), axis)
    den = lax.psum(jnp.sum(nonempty.astype(jnp.int32)), axis)
    density = num.astype(jnp.float32) / jnp.maximum(den, 1).astype(jnp.float32)
    max_grp = lax.pmax(jnp.max(jnp.sum(act_blk.astype(jnp.int32), axis=-1)), axis)

    return new_values, new_active, StepStats(n_active, n_msgs, agg, density, max_grp)


def superstep_logged_spmd(
    program: VertexProgram,
    pg: PartitionedGraph,
    values: jax.Array,
    active: jax.Array,
    step: jax.Array,
    *,
    axis: str,
):
    """Recoded superstep that also *materializes* every per-destination
    outgoing buffer A_s (so the driver can persist them — "keep all OMSs on
    local disk until a new checkpoint is written", §3.4). Exchange is an
    all_to_all of the combined buffers instead of the ring."""
    ctx = _shard_ctx(pg, axis)
    comb = program.combiner
    dests = jnp.arange(pg.n_shards, dtype=jnp.int32)
    A_s_all, cnt_all = jax.vmap(
        lambda d: _contrib_dense(program, pg, values, active, step, d,
                                 _combine_scatter)
    )(dests)  # (n_dest, P) each
    recv_A = lax.all_to_all(A_s_all, axis, split_axis=0, concat_axis=0)
    recv_c = lax.all_to_all(cnt_all, axis, split_axis=0, concat_axis=0)
    A_r = comb.reduce(recv_A, 0)
    cnt = jnp.sum(recv_c, 0)

    has_msg = (cnt > 0) & pg.vmask
    new_values, new_active = program.apply(
        values, pg.degree, A_r, has_msg, active, step, ctx
    )
    new_active = new_active & pg.vmask
    n_active = lax.psum(jnp.sum(new_active.astype(jnp.int32)), axis)
    n_msgs = lax.psum(jnp.sum(cnt), axis)
    agg = program.aggregate(values, new_values, has_msg)
    agg = (
        lax.psum(jnp.sum(agg.astype(jnp.float32)), axis)
        if agg is not None
        else jnp.float32(0)
    )
    prefix2 = _active_prefix(new_active)
    act_blk = _block_active(pg, prefix2, pg.blk_lo, pg.blk_hi)
    nonempty = pg.blk_hi >= 0
    num = lax.psum(jnp.sum(act_blk.astype(jnp.int32)), axis)
    den = lax.psum(jnp.sum(nonempty.astype(jnp.int32)), axis)
    density = num.astype(jnp.float32) / jnp.maximum(den, 1).astype(jnp.float32)
    max_grp = lax.pmax(jnp.max(jnp.sum(act_blk.astype(jnp.int32), axis=-1)), axis)
    stats = StepStats(n_active, n_msgs, agg, density, max_grp)
    return new_values, new_active, stats, A_s_all, cnt_all


def init_spmd(program: VertexProgram, pg: PartitionedGraph, *, axis: str):
    ctx = _shard_ctx(pg, axis)
    values, active = program.init(ctx)
    return values.astype(program.value_dtype), active & pg.vmask


# --------------------------------------------------------------------------
# streamed-mode kernels, shared by the in-process engine and worker processes
# --------------------------------------------------------------------------

class StreamKernels:
    """The jitted per-shard streamed-mode kernels, built from the program
    plus the partition SCALARS only (n_shards, n_vertices, P) — every
    per-shard array (values, degree, vmask, ...) is a call argument, never
    closed over. Both :class:`GraphDEngine` and the one-process-per-shard
    worker (``repro.launch.procs``) build their kernels here, so the two
    execution paths run literally the same compiled math and cannot drift.

    Combiner programs get ``fold``/``fold_batch``/``apply``/``digest``;
    combiner-less programs get ``msgs``/``apply_list``/``finish``. ``init``
    is always present (the per-row replica of :func:`init_spmd`).
    """

    def __init__(self, program: VertexProgram, n_shards: int,
                 n_vertices: int, P: int):
        self.program = program
        self.n_shards = int(n_shards)
        self.n_vertices = int(n_vertices)
        self.P = int(P)
        self.combined = program.combiner is not None
        self.init = jax.jit(self._make_init())
        if self.combined:
            comb = program.combiner
            self.fold = jax.jit(self._make_fold())
            self.fold_batch = jax.jit(self._make_fold_batch())
            self.apply = jax.jit(self._make_apply())
            # receiver digest of one densified inbox group (pipelined
            # path): identical per-position sequence to the unpipelined
            # grouped fold, so pipelining cannot change results
            self.digest = jax.jit(
                lambda A, c, A2, c2: (comb.combine(A, A2), c + c2)
            )
        else:
            self.msgs = jax.jit(self._make_msgs())
            self.apply_list = jax.jit(self._make_apply_list())
            self.finish = jax.jit(self._make_finish())

    def _ctx(self, shard, degree, vmask, old_ids, gids) -> ShardContext:
        return ShardContext(
            shard=shard, n_shards=self.n_shards, n_vertices=self.n_vertices,
            P=self.P, degree=degree, vmask=vmask, old_ids=old_ids, gids=gids,
        )

    def _make_init(self):
        """Jitted per-shard init: one row of :func:`init_spmd` (the worker
        process holds only its own row, so ``shard`` is an argument instead
        of ``lax.axis_index``)."""
        program = self.program

        def init_row(shard, degree, vmask, old_ids, gids):
            ctx = self._ctx(shard, degree, vmask, old_ids, gids)
            values, active = program.init(ctx)
            return values.astype(program.value_dtype), active & vmask

        return init_row

    def _make_fold(self):
        """Jitted chunk combine: fold one staged edge chunk into the
        destination accumulator (the in-memory A_s combine of §5, applied to
        an O(1)-sized staged slice instead of the whole resident group)."""
        program = self.program
        comb = program.combiner

        def fold(A, cnt, values, degree, active, sp, dp, w, step):
            msg, dp2, aact = _gen_messages(
                program, values, degree, sp, dp, w, active, step
            )
            A = comb.scatter(A, dp2, msg)
            cnt = cnt.at[dp2].add(aact.astype(jnp.int32))
            return A, cnt

        return fold

    def _make_fold_batch(self):
        """Jitted multi-group fold: ``group_batch`` SMALL groups (each one
        staged chunk) scatter-combined in one vmapped dispatch — per lane
        the exact op sequence of :meth:`_make_fold` on a fresh identity
        accumulator, so batching is pure dispatch amortization and results
        stay bit-identical (the lanes never mix)."""
        program, P_dest = self.program, self.P
        comb = program.combiner

        def fold_batch(values, degree, active, src, sp, dp, w, step):
            # values/degree/active: the full (n, P) stacks; src: (G,) source
            # shard per lane; sp/dp/w: (G, chunk_slots). Padding lanes carry
            # sp = -1 everywhere and fold to the identity.
            def one(src_g, sp_g, dp_g, w_g):
                msg, dp2, aact = _gen_messages(
                    program, values[src_g], degree[src_g], sp_g, dp_g, w_g,
                    active[src_g], step,
                )
                A = comb.scatter(
                    comb.identity((P_dest,), program.msg_dtype), dp2, msg
                )
                cnt = jnp.zeros((P_dest,), jnp.int32).at[dp2].add(
                    aact.astype(jnp.int32)
                )
                return A, cnt

            return jax.vmap(one)(src, sp, dp, w)

        return fold_batch

    def _make_apply(self):
        """Jitted per-shard digest + apply + vote (shard index is traced, so
        one compilation serves all shards)."""
        program = self.program

        def apply_shard(values, degree, vmask, old_ids, gids, A_r, cnt,
                        active, step, shard):
            ctx = self._ctx(shard, degree, vmask, old_ids, gids)
            has_msg = (cnt > 0) & vmask
            new_values, new_active = program.apply(
                values, degree, A_r, has_msg, active, step, ctx
            )
            new_active = new_active & vmask
            agg = program.aggregate(values, new_values, has_msg)
            agg = (
                jnp.sum(agg.astype(jnp.float32))
                if agg is not None
                else jnp.float32(0)
            )
            return (
                new_values.astype(program.value_dtype),
                new_active,
                jnp.sum(new_active.astype(jnp.int32)),
                jnp.sum(cnt),
                agg,
            )

        return apply_shard

    def _make_msgs(self):
        """Jitted raw-message generation for one staged edge chunk (the
        combiner-less scatter half): returns ``(payload, dst_pos, valid)``
        for the host to sort by destination and spill into an OMS run."""
        program = self.program

        def gen(values, degree, active, sp, dp, w, step):
            msg, dp2, aact = _gen_messages(
                program, values, degree, sp, dp, w, active, step
            )
            return msg, dp2, aact

        return gen

    def _make_apply_list(self):
        """Jitted apply over ONE destination-aligned slice of the merged
        message stream. ``cnt`` is the full per-position message count, so
        ``has_msg`` matches mode="basic" exactly; only the destinations whose
        runs live in this slice are kept by the caller."""
        program = self.program

        def apply_slice(values, degree, vmask, old_ids, gids, sdp, smsg,
                        cnt, active, step, shard):
            ctx = self._ctx(shard, degree, vmask, old_ids, gids)
            has_msg = (cnt > 0) & vmask
            new_values, new_active = program.apply_list(
                values, degree, sdp, smsg, has_msg, active, step, ctx
            )
            return new_values.astype(program.value_dtype), new_active & vmask

        return apply_slice

    def _make_finish(self):
        """Jitted per-shard superstep tail for the combiner-less path
        (active count, message count, aggregator)."""
        program = self.program

        def fin(values, new_values, new_active, cnt, vmask):
            has_msg = (cnt > 0) & vmask
            agg = program.aggregate(values, new_values, has_msg)
            agg = (
                jnp.sum(agg.astype(jnp.float32))
                if agg is not None
                else jnp.float32(0)
            )
            return (
                jnp.sum(new_active.astype(jnp.int32)),
                jnp.sum(cnt),
                agg,
            )

        return fin


# --------------------------------------------------------------------------
# drivers
# --------------------------------------------------------------------------

@dataclass
class SuperstepRecord:
    step: int
    n_active: int
    n_msgs: int
    agg: float
    density: float
    mode: str
    seconds: float
    # step a checkpoint auto-restore resumed from (first record only)
    restored_from: int | None = None
    # residency observability (streamed mode; defaults elsewhere): edge
    # blocks actually read off disk this superstep, blocks served from the
    # hot cache, cache evictions, and blocks the §3.2 skip() test kept off
    # the schedule entirely (selective scheduling)
    blocks_read: int = 0
    cache_hits: int = 0
    cache_evictions: int = 0
    blocks_skipped: int = 0


class GraphDEngine:
    """Host driver: jits the SPMD body under vmap (emulation) or shard_map
    (device mesh), adapts dense/sparse per superstep, runs the job loop."""

    AXIS = "machines"

    MODES = MODES  # single source of truth: repro.core.config.MODES

    def __init__(
        self,
        pg: PartitionedGraph,
        program: VertexProgram,
        config: EngineConfig | None = None,
        *,
        mesh: Mesh | None = None,
        message_log=None,  # core.checkpoint.MessageLog for fast recovery
        stream_store=None,  # streams.EdgeStreamStore, required for "streamed"
        **flat,  # rejected: the PR-4 flat-kwarg shim's window is over
    ):
        if flat:
            raise ConfigError(
                "GraphDEngine no longer accepts flat keyword arguments "
                f"({', '.join(sorted(flat))}); build an EngineConfig — e.g. "
                "config=EngineConfig(mode='streamed', "
                "channel=ChannelConfig(pipeline=True))"
            )
        if config is None:
            config = EngineConfig()
        if not isinstance(config, EngineConfig):
            raise ConfigError(
                "config must be an EngineConfig (the positional mode string "
                f"was removed with the flat-kwarg shim), got "
                f"{type(config).__name__}"
            )
        cfg = config.finalize()
        self.config = cfg
        mode = cfg.mode
        backend = cfg.backend
        pipeline = cfg.channel.pipeline
        compress = cfg.channel.compress
        # Config-value and cross-config validation happened in finalize();
        # what follows needs the program, the partition, or a live object —
        # facts no config can know.
        if mode != "streamed" and pg.E_cap > 0 and pg.src_pos.shape[-1] == 0:
            raise ValueError(
                "this partition is vertex-only (its edge groups were spilled "
                "to disk by drop_edges/partition_graph_streamed); it can only "
                "run with mode='streamed' and the matching stream_store"
            )
        if mode in ("recoded", "recoded_compact", "basic_sc") and (
            program.combiner is None
        ):
            raise ValueError(f"mode={mode} requires a message combiner (paper §5)")
        if mode == "recoded_compact" and program.msg_dtype not in (
            jnp.float32, jnp.bfloat16
        ):
            # bf16 wire rounds integers > 256 — min-label algorithms would
            # silently merge distinct labels. Float-message programs only.
            raise ValueError("recoded_compact needs float messages")
        if (cfg.channel.payload_scheme == "bf16"
                and program.msg_dtype != jnp.float32):
            # the same guard as recoded_compact, applied to the wire codec
            raise ValueError(
                "compress_payload='bf16' rounds float32 messages on the "
                "wire; integer/min-label programs need the lossless scheme"
            )
        if cfg.channel.payload_scheme == "bf16" and message_log is not None:
            # logged OMSs are recovery state: recover_shard_streamed
            # regenerates the failed shard's own groups EXACTLY and digests
            # them against the logged runs — rounding the log would make
            # recovered state diverge from the live run, breaking the
            # bit-match invariant every fault drill asserts
            raise ValueError(
                "compress_payload='bf16' is a lossy wire codec and cannot "
                "back a message log (recovery must replay bit-identically);"
                " use the lossless scheme with message logging"
            )
        if cfg.channel.payload_scheme == "auto" and message_log is not None:
            # a run-file log fixes its wire format once at configure();
            # the auto-pick resolves it only after the first superstep's
            # sample, and a recovery replay could not re-derive the same
            # mid-run switch point
            raise ValueError(
                "compress_payload='auto' resolves the codec from a "
                "first-superstep sample; a message log needs a fixed wire "
                "format — pass 'lossless' (or False) explicitly"
            )
        if backend == "pallas" and getattr(program, "msg_kind", None) is None:
            raise ValueError(
                "backend='pallas' needs mode='recoded' and a program.msg_kind"
            )
        if mode == "streamed":
            if stream_store is None:
                raise ValueError(
                    "mode='streamed' needs stream_store= (an "
                    "streams.EdgeStreamStore; see graph.partition_graph_streamed)"
                )
            if mesh is not None:
                raise ValueError(
                    "mode='streamed' is host-driven: backend='jnp', mesh=None"
                )
            if message_log is not None and not hasattr(message_log, "save_group"):
                raise ValueError(
                    "mode='streamed' logs messages incrementally to run files;"
                    " pass a core.checkpoint.RunFileMessageLog"
                )
            geom = stream_store.geom
            if (geom.n_shards, geom.P, geom.edge_block) != (
                pg.n_shards, pg.P, pg.edge_block
            ):
                raise ValueError(
                    "stream store geometry does not match the partition: "
                    f"store (n={geom.n_shards}, P={geom.P}, B={geom.edge_block})"
                    f" vs pg (n={pg.n_shards}, P={pg.P}, B={pg.edge_block})"
                )
        if message_log is not None and hasattr(message_log, "configure"):
            # run-file logs densify sparse runs back with the combiner
            # identity; they must learn it (and the geometry) from the
            # program, whatever the mode
            message_log.configure(
                n_shards=pg.n_shards, P=pg.P,
                msg_dtype=np.dtype(program.msg_dtype),
                e0=program.combiner.e0 if program.combiner is not None else 0,
                combined=program.combiner is not None,
                compress=compress,
                compress_payload=cfg.channel.payload_scheme,
            )
        self.pg = pg
        self.program = program
        self.mode = mode
        self.mesh = mesh
        self.backend = backend
        self.adapt_threshold = cfg.adapt_threshold
        self.sparse_cap = max(1, int(pg.n_blocks * cfg.sparse_cap_frac))
        self.message_log = message_log
        self.stream_store = stream_store
        self.pipeline = bool(pipeline)
        self.compress = bool(compress)
        scheme = cfg.channel.payload_scheme  # None | scheme | "auto"
        # "auto": spill the first superstep raw while a PayloadAutoPicker
        # trial-encodes a sample of its runs; the end-of-superstep decision
        # (see _run_streamed) fixes compress_payload/_payload_channels for
        # every later per-step store and records itself in
        # channel_stats.payload_choice
        self._payload_auto = scheme == "auto"
        self._payload_picker = None
        self._payload_channels: tuple | None = None
        self.compress_payload = None if self._payload_auto else scheme
        self.full_duplex = bool(cfg.channel.full_duplex)
        axis = self.AXIS

        if mode == "streamed":
            from repro.streams.channel import ChannelStats
            from repro.streams.reader import StreamReader
            from repro.streams.residency import BlockResidency

            # every streamed superstep path reads through the residency
            # tier: cache_bytes=0 degenerates to pure streaming (counted
            # pass-through), a positive budget pins hot blocks. ONE
            # residency serves all n emulated shards, so its capacity is
            # the per-shard budget times n — launch="processes" workers
            # each build their own with just the per-shard share instead
            self._residency = BlockResidency(
                stream_store,
                int(cfg.stream.cache_bytes) * pg.n_shards,
            )
            self._stream_reader = StreamReader(
                stream_store, chunk_blocks=cfg.stream.chunk_blocks,
                depth=cfg.stream.depth, owner_views=self.pipeline,
                residency=self._residency,
            )
            self.channel_inflight = int(cfg.channel.inflight)
            self._channel_fault = cfg.channel.fault
            self._recv_fault = cfg.channel.recv_fault
            self.group_batch = int(cfg.stream.group_batch)
            # cumulative over the current run(); bench_memory reads it for
            # the pipeline_overlap section (both directions)
            self.channel_stats = ChannelStats()
            # zombie channel threads recorded by crash-path aborts; surfaced
            # at the next run() instead of masking the original exception
            self.thread_leaks: list[Exception] = []
            self._inbox_dir = os.path.join(stream_store.dir, "inbox")
            self.msg_spill_dir = cfg.spill.spill_dir or os.path.join(
                stream_store.dir, "oms"
            )
            self.msg_slice_cap = int(cfg.spill.slice_cap)
            # effective slice capacity; bumped (in powers of two) if a vertex
            # in-degree ever exceeds it — Pregel's compute() needs a vertex's
            # whole message list in one slice
            self._msg_slice_cap_eff = int(cfg.spill.slice_cap)
            self.msg_read_chunk = int(cfg.spill.read_chunk)
            self.msg_merge_fanin = int(cfg.spill.merge_fanin)
            # one kernel bundle serves this engine and (via launch/procs)
            # any per-shard worker process — same compiled math by
            # construction
            kern = StreamKernels(program, pg.n_shards, pg.n_vertices, pg.P)
            self._kernels = kern
            if program.combiner is not None:
                self._stream_fold = kern.fold
                self._stream_fold_batch = kern.fold_batch
                self._stream_apply = kern.apply
                self._stream_digest = kern.digest
            else:
                self._stream_msgs = kern.msgs
                self._stream_apply_list = kern.apply_list
                self._stream_finish = kern.finish
            self._step_dense = self._step_sparse = self._step_logged = None
            self._init = jax.jit(self._wrap(
                lambda pg_: init_spmd(program, pg_, axis=axis), n_in=1,
                n_stats=0,
            ))
            return

        self.kl = None
        if backend == "pallas":
            from repro.graph.kblocks import build_kernel_layout

            win = cfg.kernel_windows
            while pg.P % win:
                win //= 2  # largest power-of-2 window dividing P
            self.kl = build_kernel_layout(
                pg, BLK=min(512, max(win, 8)), SRC_WIN=win, DST_WIN=win
            )

        def _dense(pg_, v, a, s):
            return superstep_spmd(program, pg_, v, a, s, axis=axis, mode=mode)

        def _sparse(pg_, v, a, s):
            return superstep_spmd(
                program, pg_, v, a, s, axis=axis, mode=mode,
                sparse_cap=self.sparse_cap,
            )

        def _pallas(pg_, kl_, v, a, s):
            return superstep_spmd(program, pg_, v, a, s, axis=axis,
                                  mode=mode, kl=kl_)

        def _logged(pg_, v, a, s):
            return superstep_logged_spmd(program, pg_, v, a, s, axis=axis)

        def _init(pg_):
            return init_spmd(program, pg_, axis=axis)

        if backend == "pallas":
            step_fn = jax.jit(self._wrap_kl(_pallas))
            self._step_dense = lambda pg_, v, a, s: step_fn(pg_, self.kl, v, a, s)
            self._step_sparse = self._step_dense  # skip is always-on in-kernel
        else:
            self._step_dense = jax.jit(self._wrap(_dense, n_in=4, n_stats=1))
            self._step_sparse = (
                jax.jit(self._wrap(_sparse, n_in=4, n_stats=1))
                if mode in ("recoded", "basic_sc")
                else self._step_dense
            )
        self._step_logged = (
            jax.jit(self._wrap_logged(_logged)) if message_log is not None else None
        )
        self._init = jax.jit(self._wrap(_init, n_in=1, n_stats=0))

    # -- vmap / shard_map wrapping ------------------------------------------
    def _wrap(self, fn, n_in: int, n_stats: int):
        """Run the SPMD body over the machines axis: vmap (emulated shards on
        one device) or shard_map (one shard per device on a mesh)."""
        axis = self.AXIS
        is_step = n_in == 4  # (pg, values, active, step) -> (v, a, stats)
        if self.mesh is None:
            if is_step:
                def wrapped(pg_, v, a, s):
                    nv, na, st = jax.vmap(
                        fn, axis_name=axis, in_axes=(0, 0, 0, None)
                    )(pg_, v, a, s)
                    # psum'd stats are identical across shards; take shard 0
                    return nv, na, jax.tree.map(lambda x: x[0], st)
                return wrapped
            return lambda pg_: jax.vmap(fn, axis_name=axis)(pg_)
        # shard_map keeps a size-1 local leading axis; squeeze it around fn so
        # the SPMD body sees the same per-shard shapes as under vmap.
        sq = lambda t: jax.tree.map(lambda x: x[0], t)
        spec = P(axis)
        if is_step:
            def body(pg_, v, a, s):
                nv, na, st = fn(sq(pg_), sq(v), sq(a), s)
                return nv[None], na[None], st
            return shard_map(
                body, mesh=self.mesh,
                in_specs=(spec, spec, spec, P()), out_specs=(spec, spec, P()),
            )

        def body(pg_):
            v, a = fn(sq(pg_))
            return v[None], a[None]
        return shard_map(body, mesh=self.mesh, in_specs=(spec,),
                             out_specs=(spec, spec))

    def _wrap_kl(self, fn):
        """Like _wrap(is_step) but with the kernel layout as a second arg."""
        axis = self.AXIS
        if self.mesh is None:
            def wrapped(pg_, kl_, v, a, s):
                nv, na, st = jax.vmap(
                    fn, axis_name=axis, in_axes=(0, 0, 0, 0, None)
                )(pg_, kl_, v, a, s)
                return nv, na, jax.tree.map(lambda x: x[0], st)
            return wrapped
        sq = lambda t: jax.tree.map(lambda x: x[0], t)
        spec = P(axis)

        def body(pg_, kl_, v, a, s):
            nv, na, st = fn(sq(pg_), sq(kl_), sq(v), sq(a), s)
            return nv[None], na[None], st

        # check_vma=False: pallas_call outputs carry no varying-mesh-axes
        # metadata, which the vma checker would otherwise reject.
        return shard_map(
            body, mesh=self.mesh,
            in_specs=(spec, spec, spec, spec, P()),
            out_specs=(spec, spec, P()),
            check_vma=False,
        )

    def _wrap_logged(self, fn):
        axis = self.AXIS
        if self.mesh is None:
            def wrapped(pg_, v, a, s):
                nv, na, st, As, cn = jax.vmap(
                    fn, axis_name=axis, in_axes=(0, 0, 0, None)
                )(pg_, v, a, s)
                return nv, na, jax.tree.map(lambda x: x[0], st), As, cn
            return wrapped
        sq = lambda t: jax.tree.map(lambda x: x[0], t)
        spec = P(axis)

        def body(pg_, v, a, s):
            nv, na, st, As, cn = fn(sq(pg_), sq(v), sq(a), s)
            return nv[None], na[None], st, As[None], cn[None]

        return shard_map(
            body, mesh=self.mesh,
            in_specs=(spec, spec, spec, P()),
            out_specs=(spec, spec, P(), spec, spec),
        )

    # -- streamed mode (out-of-core, paper §3 / Theorem 1) --------------------
    def _fold_groups(self, values, active, step, schedule, sink):
        """Fold staged edge chunks into per-(src, dst) group accumulators
        (§5's A_s, one group at a time) and hand each COMPLETED group to
        ``sink(src, dst, A_g, cnt_g)`` in schedule order. Shared by the
        logged unpipelined superstep (sink: combine locally + save_group)
        and the pipelined superstep (sink: channel transmit) — the group
        keying, identity re-init and buffer-recycle contract live in
        exactly one place, so the two paths' bit-identical-grouping
        guarantee cannot drift.

        Small groups (a single staged chunk) are folded ``group_batch`` at
        a time through one padded vmapped dispatch — per lane the same ops
        on a fresh identity accumulator, so sinks still see each group's
        exact unbatched result; only the Python/dispatch overhead is
        amortized (graphs with many small destinations pay one dispatch
        per G groups instead of one per group)."""
        program, pg, comb = self.program, self.pg, self.program.combiner
        G = max(1, self.group_batch)
        CB = self._stream_reader.chunk_blocks
        # chunks per (src, dst) group, known from the schedule up front
        n_chunks = {(i, k): -(-len(ids) // CB) for i, k, ids in schedule}
        slots = CB * pg.edge_block
        pad = (np.full((slots,), -1, np.int32), np.zeros((slots,), np.int32),
               np.zeros((slots,), np.float32))
        pending: list = []  # copied single-chunk groups awaiting one dispatch
        state = {"cur": None, "A": None, "cnt": None}

        def close_cur():
            if state["cur"] is not None:
                sink(state["cur"][0], state["cur"][1], state["A"],
                     state["cnt"])
                state["cur"] = None

        def flush_batch():
            if not pending:
                return
            if len(pending) == 1:
                i, k, sp, dp, w = pending[0]
                A_g, cnt_g = self._stream_fold(
                    comb.identity((pg.P,), program.msg_dtype),
                    jnp.zeros((pg.P,), jnp.int32),
                    values[i], pg.degree[i], active[i],
                    jnp.asarray(sp), jnp.asarray(dp), jnp.asarray(w), step,
                )
                sink(i, k, A_g, cnt_g)
            else:
                lanes = pending + [(0, -1) + pad] * (G - len(pending))
                src = jnp.asarray(np.array([p[0] for p in lanes], np.int32))
                sp = jnp.asarray(np.stack([p[2] for p in lanes]))
                dp = jnp.asarray(np.stack([p[3] for p in lanes]))
                w = jnp.asarray(np.stack([p[4] for p in lanes]))
                A_b, cnt_b = self._stream_fold_batch(
                    values, pg.degree, active, src, sp, dp, w, step
                )
                for g, (i, k, *_rest) in enumerate(pending):
                    sink(i, k, A_b[g], cnt_b[g])
            pending.clear()

        for chunk in self._stream_reader.stream(schedule):
            i, k = chunk.src_shard, chunk.dst_shard
            if state["cur"] is not None and state["cur"] != (i, k):
                close_cur()  # the previous multi-chunk group just completed
            if G > 1 and n_chunks[(i, k)] == 1:
                # copy out of the reader's recycled staging buffers; the
                # batch holds at most G chunks (modeled in the staging tier)
                pending.append((i, k, np.array(chunk.sp), np.array(chunk.dp),
                                np.array(chunk.w)))
                if len(pending) == G:
                    flush_batch()
                continue
            if state["cur"] != (i, k):
                flush_batch()  # batched groups precede this one in order
                state["cur"] = (i, k)
                state["A"] = comb.identity((pg.P,), program.msg_dtype)
                state["cnt"] = jnp.zeros((pg.P,), jnp.int32)
            state["A"], state["cnt"] = self._stream_fold(
                state["A"], state["cnt"], values[i], pg.degree[i], active[i],
                chunk.sp, chunk.dp, chunk.w, step,
            )
            # block before the reader recycles this chunk's buffer: on CPU
            # jax the jitted fold may zero-copy ALIAS the staged numpy
            # arrays, and dispatch is async — advancing the iterator would
            # let the prefetch thread overwrite memory a pending computation
            # still reads. Disk I/O still overlaps: the producer thread
            # reads ahead while we wait on compute.
            jax.block_until_ready(state["cnt"])
        close_cur()
        flush_batch()

    def _superstep_streamed_comb(self, values, active, s, plan):
        """One streamed superstep with a combiner: fold staged edge chunks
        straight into the O(|V|/n) destination accumulators (§5 applied to
        O(1)-sized staged slices). With a message log, fold per (src,dst)
        group instead so each combined OMS A_s(i→k) persists to the run
        files as its group completes (§3.4)."""
        program, pg, comb = self.program, self.pg, self.program.combiner
        n = pg.n_shards
        reader = self._stream_reader
        log = self.message_log
        step = jnp.int32(s)
        A_r = [comb.identity((pg.P,), program.msg_dtype) for _ in range(n)]
        cnt = [jnp.zeros((pg.P,), jnp.int32) for _ in range(n)]
        schedule = [entry for per_dest in plan for entry in per_dest]
        # U_c ∥ U_s: the reader thread stages chunk t+1 while fold digests
        # chunk t
        if log is None:
            for chunk in reader.stream(schedule):
                i, k = chunk.src_shard, chunk.dst_shard
                A_r[k], cnt[k] = self._stream_fold(
                    A_r[k], cnt[k], values[i], pg.degree[i], active[i],
                    chunk.sp, chunk.dp, chunk.w, step,
                )
                # block before the reader recycles this chunk's buffer: on
                # CPU jax the jitted fold may zero-copy ALIAS the staged
                # numpy arrays, and dispatch is async — advancing the
                # iterator would let the prefetch thread overwrite memory a
                # pending computation still reads. Disk I/O still overlaps:
                # the producer thread reads ahead while we wait on compute.
                jax.block_until_ready(cnt[k])
        else:
            # create the step's run store up front: even an all-skipped
            # superstep must publish an (empty) index or recovery of that
            # step would find no directory at all
            log.open_step(s)

            def _digest_and_log(gi, gk, A_g, cnt_g):
                A_r[gk] = comb.combine(A_r[gk], A_g)
                cnt[gk] = cnt[gk] + cnt_g
                log.save_group(s, gi, gk, np.asarray(A_g), np.asarray(cnt_g))

            self._fold_groups(values, active, step, schedule, _digest_and_log)
            log.close_step(s)  # release write handles; runs stay readable
        new_v, new_a = [], []
        n_active = n_msgs = 0
        agg = 0.0
        for k in range(n):
            nv, na, nact, nm, ag = self._stream_apply(
                values[k], pg.degree[k], pg.vmask[k], pg.old_ids[k],
                pg.gids[k], A_r[k], cnt[k], active[k], step,
                jnp.int32(k),
            )
            new_v.append(nv)
            new_a.append(na)
            n_active += int(nact)
            n_msgs += int(nm)
            agg += float(ag)
        st = reader.stats
        io_note = f"{st.blocks_read}blk/{st.bytes_read >> 10}KiB"
        return (jnp.stack(new_v), jnp.stack(new_a), n_active, n_msgs, agg,
                io_note)

    def _open_inbox(self, s: int, with_counts: bool):
        """The superstep's inbox store: the message log's per-step run store
        when a log is attached (transmitted groups ARE the persisted OMSs of
        §3.4 — recoverable and GC'd with the log), else a scratch store under
        the stream store, deleted once applied."""
        from repro.streams.msgstore import MessageRunStore

        if self.message_log is not None:
            return self.message_log.open_step(s)
        store = MessageRunStore(
            os.path.join(self._inbox_dir, f"step-{s:06d}"),
            self.pg.n_shards, self.pg.P, np.dtype(self.program.msg_dtype),
            with_counts=with_counts, compress=self.compress,
            compress_payload=self.compress_payload or False,
            payload_channels=self._payload_channels,
        )
        self._attach_payload_sampler(store)
        return store

    def _attach_payload_sampler(self, store) -> None:
        """Under ``compress_payload="auto"`` (and until the decision), let
        the picker see every value column this step's store spills."""
        if self._payload_auto:
            if self._payload_picker is None:
                from repro.streams.codec import PayloadAutoPicker

                self._payload_picker = PayloadAutoPicker()
            store.payload_sampler = self._payload_picker

    def _decide_payload_codec(self) -> None:
        """End-of-superstep half of the auto-pick: once the sample exists,
        fix the per-channel wire format for every later per-step store and
        record the verdict (measured ratios included) in the run's
        channel stats."""
        picker = self._payload_picker
        if not self._payload_auto or picker is None or not picker.sampled:
            return
        picked = picker.choose()
        self.compress_payload = "lossless" if picked else None
        self._payload_channels = picked or None
        self.channel_stats.payload_choice = picker.summary()
        self._payload_auto = False  # decided: stop sampling
        self._payload_picker = None

    def _close_inbox(self, s: int, inbox, ok: bool) -> None:
        """Publish/delete the inbox at superstep end. On failure (``ok``
        False, e.g. a sender crash) the step store is left WITHOUT an index:
        a rerun's ``open_step`` truncates it and the engine's startup sweep
        removes scratch leftovers — a torn inbox is never consumed."""
        if self.message_log is not None:
            if ok:
                self.message_log.close_step(s)
        elif ok:
            inbox.delete()

    def _abort_channels(self, channel, receiver) -> None:
        """Crash-path teardown of both pipeline directions. A zombie thread
        detected by abort() is RECORDED here, not raised — the superstep's
        own exception is already propagating and must stay visible; the
        recorded leak is surfaced by the next run() instead."""
        from repro.streams.channel import ChannelError

        for part in (channel, receiver):
            if part is None:
                continue
            try:
                part.abort()
            except ChannelError as e:
                self.thread_leaks.append(e)

    def _accum_channel(self, channel) -> None:
        st, tot = channel.stats, self.channel_stats
        tot.packets += st.packets
        tot.messages += st.messages
        tot.payload_bytes += st.payload_bytes
        tot.wire_bytes += st.wire_bytes
        tot.send_seconds += st.send_seconds
        tot.stall_seconds += st.stall_seconds
        tot.recv_runs += st.recv_runs
        tot.recv_seconds += st.recv_seconds
        tot.recv_stall_seconds += st.recv_stall_seconds

    def _superstep_streamed_comb_pipelined(self, values, active, s, plan):
        """One pipelined streamed superstep with a combiner — the paper's §4
        compute ∥ communicate overlap, full duplex: while the fold is still
        digesting edge chunks of the NEXT group, each finished combined
        group A_s(i→k) is serialized (sparse, optionally compressed) and
        appended to destination k's inbox run files by the background
        sender — AND the background receiver densifies and digests every
        run the sender has landed, in transmit order, so U_r hides under
        U_c exactly like U_s does. ``receiver.collect(k)`` after the
        per-destination flush barrier is the only receiver-side sync point.
        With ``full_duplex=False`` (PR-3's half-duplex pipeline, kept for
        A/B benchmarking) the receiver digests inline after the barrier.
        Either way the digest order is the transmit order — bit-identical
        to the unpipelined grouped fold.

        ``plan`` is destination-grouped; resident state stays O(|V|/n):
        one group accumulator, one receiver accumulator, one densified run,
        and at most ``channel_inflight`` sparse packets in flight.
        """
        from repro.streams.channel import ChannelReceiver, ShardChannels

        program, pg, comb = self.program, self.pg, self.program.combiner
        n = pg.n_shards
        reader = self._stream_reader
        step = jnp.int32(s)
        inbox = self._open_inbox(s, with_counts=True)
        receiver = None
        if self.full_duplex:
            identity = lambda: (comb.identity((pg.P,), program.msg_dtype),
                                jnp.zeros((pg.P,), jnp.int32))

            def _recv_digest(A, cnt, A_d, c_d):
                A, cnt = self._stream_digest(
                    A, cnt, jnp.asarray(A_d), jnp.asarray(c_d)
                )
                # block so recv_seconds measures real digest work (and the
                # accumulator is materialized before the next run's fold)
                jax.block_until_ready(cnt)
                return A, cnt

            receiver = ChannelReceiver(inbox, _recv_digest, identity,
                                       comb.e0, fault=self._recv_fault)
        channel = ShardChannels(inbox, inflight=self.channel_inflight,
                                fault=self._channel_fault, receiver=receiver)
        new_v, new_a = [], []
        n_active = n_msgs = 0
        agg = 0.0
        blocks = kib = 0
        ok = False
        try:
            for k in range(n):

                def _transmit(gi, gk, A_g, cnt_g):
                    # the sender sparsifies on its own thread (the shared
                    # append_combined wire format, streams/msgstore.py)
                    channel.send_combined(gk, np.asarray(A_g),
                                          np.asarray(cnt_g), tag=gi)

                self._fold_groups(values, active, step, plan[k], _transmit)
                blocks += reader.stats.blocks_read
                kib += reader.stats.bytes_read >> 10
                # barrier: every group for dest k has landed in its inbox
                # (and, full duplex, been announced to the receiver)
                channel.flush()
                if receiver is not None:
                    # receiver-side barrier: most digests already ran under
                    # the fold; this only waits out the tail
                    A_r, cnt = receiver.collect(k)
                else:
                    # half-duplex: digest inline, in transmit order
                    A_r = comb.identity((pg.P,), program.msg_dtype)
                    cnt = jnp.zeros((pg.P,), jnp.int32)
                    for seg in inbox.runs(k):
                        A_d, c_d = inbox.read_combined(k, seg, comb.e0)
                        A_r, cnt = self._stream_digest(
                            A_r, cnt, jnp.asarray(A_d), jnp.asarray(c_d)
                        )
                nv, na, nact, nm, ag = self._stream_apply(
                    values[k], pg.degree[k], pg.vmask[k], pg.old_ids[k],
                    pg.gids[k], A_r, cnt, active[k], step, jnp.int32(k),
                )
                new_v.append(nv)
                new_a.append(na)
                n_active += int(nact)
                n_msgs += int(nm)
                agg += float(ag)
            channel.close()  # surface a late sender error before publishing
            if receiver is not None:
                receiver.close()
            ok = True
        finally:
            if not ok:
                self._abort_channels(channel, receiver)
            self._accum_channel(channel)
            self._close_inbox(s, inbox, ok)
        st = channel.stats
        io_note = (f"{blocks}blk/{kib}KiB "
                   f"tx={st.packets}pk/{st.wire_bytes >> 10}KiB "
                   f"ov={st.sender_overlap_seconds() * 1e3:.1f}"
                   f"/{st.receiver_overlap_seconds() * 1e3:.1f}ms")
        return (jnp.stack(new_v), jnp.stack(new_a), n_active, n_msgs, agg,
                io_note)

    def _apply_list_merged(self, mstore, dest, values_k, active_k, step,
                           channel=None):
        """Merge destination ``dest``'s spilled runs and fold destination-
        aligned apply_list slices into that shard's new (values, active)
        rows; returns them with the full per-position message count. Shared
        by the superstep loop and single-shard recovery so the two can never
        drift in slice semantics.

        With a live ``channel`` (the full-duplex pipelined path) the merge
        runs on an accounted receiver thread (``streams.channel
        .receive_iter``): its merge/decode time lands in the channel's
        ``recv_seconds`` — receiver digest hidden under apply compute is
        the OMS path's U_r overlap — and the receiver-side FaultPoint can
        kill it mid-merge. Either producer yields the same slices in the
        same order, so results cannot depend on which one ran."""
        from repro.streams.channel import receive_iter
        from repro.streams.reader import prefetch_iter

        program, pg = self.program, self.pg
        counts = mstore.dest_counts(dest)
        max_run = int(counts.max()) if counts.size else 0
        while self._msg_slice_cap_eff < max_run:
            self._msg_slice_cap_eff *= 2
        cap = self._msg_slice_cap_eff
        cnt_k = jnp.asarray(
            np.minimum(counts, np.iinfo(np.int32).max).astype(np.int32)
        )
        shard = jnp.int32(dest)
        acc_v = acc_a = None
        slices = mstore.merged_slices(dest, cap, self.msg_read_chunk)
        if channel is not None and self.full_duplex:
            it = receive_iter(slices, stats=channel.stats,
                              fault=self._recv_fault,
                              depth=self._stream_reader.depth)
        else:
            it = prefetch_iter(slices, depth=self._stream_reader.depth)
        # slices are prefetched so merge-read I/O hides behind apply compute
        for sdp, smsg, covered in it:
            nv, na = self._stream_apply_list(
                values_k, pg.degree[dest], pg.vmask[dest], pg.old_ids[dest],
                pg.gids[dest], jnp.asarray(sdp), jnp.asarray(smsg),
                cnt_k, active_k, step, shard,
            )
            if acc_v is None:
                # any one call is already exact for every vertex without
                # messages; per-slice overwrites fix the covered rest
                acc_v, acc_a = nv, na
            else:
                cov = jnp.asarray(covered)
                acc_v = jnp.where(cov, nv, acc_v)
                acc_a = jnp.where(cov, na, acc_a)
        if acc_v is None:  # no messages at all: one padding-only call
            acc_v, acc_a = self._stream_apply_list(
                values_k, pg.degree[dest], pg.vmask[dest], pg.old_ids[dest],
                pg.gids[dest],
                jnp.asarray(np.full((cap,), pg.P, np.int32)),
                jnp.asarray(np.zeros((cap,), np.dtype(program.msg_dtype))),
                cnt_k, active_k, step, shard,
            )
        return acc_v, acc_a, cnt_k

    def _superstep_streamed_nocomb(self, values, active, s, plan):
        """One combiner-less streamed superstep (§3.3): stream edges in,
        spill destination-sorted raw-message runs to local disk, external-
        merge them back, and apply destination-aligned slices — O(|E|)
        messages flow through, never resident.

        ``plan`` is destination-grouped: destination k's spill, merge, apply
        and run cleanup all finish before destination k+1's edges are read,
        so peak spill disk is one destination's traffic, not the superstep's.

        With ``pipeline=True`` the spill sort + run append (and the §3.3.1
        compaction passes) run on the channel's background sender in strict
        send order — the run table evolves exactly as inline, so results are
        byte-identical — while the compute thread goes on generating the
        next chunk's messages (§4's U_c ∥ U_s); with ``full_duplex`` the
        external merge feeding apply slices runs on the accounted receiver
        thread too (U_r), so merge-read I/O hides under apply compute.
        """
        from repro.streams.channel import ShardChannels
        from repro.streams.msgstore import MessageRunStore

        program, pg = self.program, self.pg
        n = pg.n_shards
        reader = self._stream_reader
        log = self.message_log
        step = jnp.int32(s)
        if log is not None:
            # the run files persist under the log: the OMSs ARE the log (§3.4)
            mstore = log.open_step(s)
        else:
            mstore = MessageRunStore(
                os.path.join(self.msg_spill_dir, f"step-{s:06d}"), n, pg.P,
                np.dtype(program.msg_dtype), compress=self.compress,
                compress_payload=self.compress_payload or False,
                payload_channels=self._payload_channels,
            )
            self._attach_payload_sampler(mstore)
        channel = (
            ShardChannels(mstore, inflight=self.channel_inflight,
                          fault=self._channel_fault)
            if self.pipeline else None
        )
        # one compaction entry point for both paths (the channel enqueues the
        # same op in FIFO order, so the run table evolves identically)
        compact = (channel.compact if channel is not None
                   else mstore.compact_tag)
        new_v, new_a = [], []
        n_active = n_msgs = 0
        agg = 0.0
        blocks = kib = 0
        ok = False
        try:
            for k in range(n):
                # -- spill: raw messages out, one sorted run per edge chunk
                cur_src = None
                for chunk in reader.stream(plan[k]):
                    i = chunk.src_shard
                    if cur_src is not None and i != cur_src:
                        # keep the merge fan-in bounded: collapse the finished
                        # source's runs down to one (multi-pass §3.3.1)
                        compact(k, cur_src, self.msg_merge_fanin,
                                self.msg_read_chunk)
                    cur_src = i
                    msg, dp, valid = self._stream_msgs(
                        values[i], pg.degree[i], active[i],
                        chunk.sp, chunk.dp, chunk.w, step,
                    )
                    # np.asarray both blocks on the async result and copies
                    # out of the reader's recycled staging buffers
                    msg = np.asarray(msg)
                    dp = np.asarray(dp)
                    valid = np.asarray(valid)
                    if channel is not None:
                        # sort + append move to the sender thread; the next
                        # chunk's message generation overlaps them
                        channel.send_raw(k, dp, msg, valid, tag=i)
                    else:
                        mstore.append_raw(k, dp, msg, valid, tag=i)
                if cur_src is not None:
                    compact(k, cur_src, self.msg_merge_fanin,
                            self.msg_read_chunk)
                blocks += reader.stats.blocks_read
                kib += reader.stats.bytes_read >> 10
                if channel is not None:
                    channel.flush()  # dest k's runs all landed; safe to merge

                # -- merge + apply (shared with recovery); with a channel
                # the merge runs on the accounted receiver thread (U_r)
                acc_v, acc_a, cnt_k = self._apply_list_merged(
                    mstore, k, values[k], active[k], step, channel=channel
                )
                nact, nm, ag = self._stream_finish(
                    values[k], acc_v, acc_a, cnt_k, pg.vmask[k]
                )
                new_v.append(acc_v)
                new_a.append(acc_a)
                n_active += int(nact)
                n_msgs += int(nm)
                agg += float(ag)
                if log is None:
                    mstore.clear_dest(k)  # applied => this OMS is dead (§3.3)
            if channel is not None:
                channel.close()
            ok = True
        finally:
            if channel is not None:
                if not ok:
                    self._abort_channels(channel, None)
                self._accum_channel(channel)
            if log is not None:
                if ok:
                    log.close_step(s)  # publish the run index, drop handles
            elif ok:
                mstore.delete()
        io_note = f"{blocks}blk/{kib}KiB"
        if channel is not None:
            st = channel.stats
            io_note += (f" tx={st.packets}pk/{st.wire_bytes >> 10}KiB "
                        f"ov={st.sender_overlap_seconds() * 1e3:.1f}"
                        f"/{st.receiver_overlap_seconds() * 1e3:.1f}ms")
        return (jnp.stack(new_v), jnp.stack(new_a), n_active, n_msgs, agg,
                io_note)

    def _run_streamed(self, max_supersteps, state, start_step, verbose,
                      checkpointer, on_step):
        """Out-of-core superstep loop: edges arrive from disk group-by-group
        via the prefetching reader; resident per shard = vertex arrays +
        constant-size buffers. Mirrors ``run``'s contract exactly."""
        from repro.streams.schedule import plan_stream_schedule

        program, pg, comb = self.program, self.pg, self.program.combiner
        store = self.stream_store
        import shutil

        from repro.streams.channel import ChannelError, ChannelStats

        if self.thread_leaks:
            # a previous failed superstep left a channel thread alive; it
            # may still hold this store's inbox run files open — rerunning
            # over them would race the zombie's appends
            raise ChannelError(
                f"{len(self.thread_leaks)} channel thread(s) leaked by an "
                "earlier failed superstep; build a fresh engine/store "
                "instead of rerunning over their open inbox files"
            ) from self.thread_leaks[0]

        # scratch inboxes / OMS spills live under the store; a crashed
        # superstep leaves its step dir behind — sweep at run start (like
        # Checkpointer sweeps .tmp-step-*) so crashes cannot leak disk.
        # Done here, not at construction: a recovery engine (which never
        # runs) must not clobber another engine's in-flight scratch state.
        for d in (self._inbox_dir, self.msg_spill_dir):
            if os.path.isdir(d):
                for name in os.listdir(d):
                    if name.startswith(("step-", "recover-")):
                        shutil.rmtree(os.path.join(d, name),
                                      ignore_errors=True)
        self.channel_stats = ChannelStats()  # fresh overlap accounting
        values, active = state if state is not None else self.init()
        history: list[SuperstepRecord] = []
        target = min(
            program.num_supersteps
            if program.num_supersteps is not None
            else max_supersteps,
            max_supersteps,
        )
        restored_from = None
        if (
            checkpointer is not None
            and state is None
            and checkpointer.latest() is not None
        ):
            values, active, start_step = checkpointer.restore(
                expected_meta=store.signature()
            )
            restored_from = start_step
        # skip() against the block manifest BEFORE any disk I/O; the plan for
        # step s is made from step s's frontier, then re-made after apply so
        # rec.density matches StepStats semantics (frontier of the NEXT step)
        plan, _, _ = plan_stream_schedule(
            store, np.asarray(active), by_dest=True
        )
        residency = self._residency
        nonempty_total = store.nonempty_blocks()
        for s in range(start_step, target):
            t0 = time.perf_counter()
            if comb is None:
                superstep = self._superstep_streamed_nocomb
            elif self.pipeline:
                superstep = self._superstep_streamed_comb_pipelined
            else:
                superstep = self._superstep_streamed_comb
            # selective scheduling: everything skip() left off this step's
            # plan is disk I/O that never happens — tally it before the
            # step so the record's counters describe THIS superstep
            scheduled = sum(
                len(ids) for per_dest in plan for _, _, ids in per_dest
            )
            residency.note_skipped(nonempty_total - scheduled)
            hits0, miss0, evict0, _ = residency.counters()
            values, active, n_active, n_msgs, agg, io_note = superstep(
                values, active, s, plan
            )
            hits1, miss1, evict1, _ = residency.counters()
            self._decide_payload_codec()  # no-op unless "auto" undecided
            plan, density, max_grp = plan_stream_schedule(
                store, np.asarray(active), by_dest=True
            )
            dt = time.perf_counter() - t0
            rec = SuperstepRecord(
                step=s, n_active=n_active, n_msgs=n_msgs, agg=agg,
                density=density, mode="streamed", seconds=dt,
                restored_from=restored_from if s == start_step else None,
                blocks_read=miss1 - miss0, cache_hits=hits1 - hits0,
                cache_evictions=evict1 - evict0,
                blocks_skipped=nonempty_total - scheduled,
            )
            history.append(rec)
            if verbose:
                print(
                    f"  superstep {s:4d}: active={n_active:>9d} "
                    f"msgs={n_msgs:>10d} agg={agg:.6g} "
                    f"density={density:.4f} [streamed {io_note}] "
                    f"{dt*1e3:.1f} ms"
                )
            if on_step is not None:
                on_step(rec, (values, active))
            if checkpointer is not None:
                saved = checkpointer.maybe_save(
                    s + 1, values, active, meta=store.signature()
                )
                if saved and self.message_log is not None:
                    # paper §3.4: OMS logs live until a newer checkpoint is
                    # durable
                    self.message_log.gc_before(s + 1)
            if program.num_supersteps is None and n_active == 0:
                break
        return (values, active), history

    # -- job API --------------------------------------------------------------
    def init(self):
        return self._init(self.pg)

    def run(
        self,
        max_supersteps: int = 10_000,
        state=None,
        start_step: int = 0,
        verbose: bool = False,
        checkpointer=None,
        on_step=None,
    ):
        """Host superstep loop with dense/sparse auto-dispatch (§3.2)."""
        if self.mode == "streamed":
            return self._run_streamed(
                max_supersteps, state, start_step, verbose, checkpointer,
                on_step,
            )
        values, active = state if state is not None else self.init()
        history: list[SuperstepRecord] = []
        target = min(
            self.program.num_supersteps
            if self.program.num_supersteps is not None
            else max_supersteps,
            max_supersteps,
        )
        density = 1.0  # step 0: unknown, assume dense
        max_grp = self.pg.n_blocks  # hard per-group bound; start pessimistic
        restored_from = None
        # auto-restore only when the caller did NOT hand us state: an
        # explicit (state, start_step) — e.g. after elastic repartitioning —
        # must win over whatever the checkpoint directory holds
        if (
            checkpointer is not None
            and state is None
            and checkpointer.latest() is not None
        ):
            values, active, start_step = checkpointer.restore()
            restored_from = start_step
        for s in range(start_step, target):
            use_sparse = (
                self.mode in ("recoded", "basic_sc")
                and max_grp <= self.sparse_cap  # no group overflows (correctness)
                and density < self.adapt_threshold  # sparse is worth it (perf)
            )
            t0 = time.perf_counter()
            if self.message_log is not None:
                values, active, stats, A_s_all, cnt_all = self._step_logged(
                    self.pg, values, active, jnp.int32(s)
                )
                self.message_log.save(s, A_s_all, cnt_all)
            else:
                fn = self._step_sparse if use_sparse else self._step_dense
                values, active, stats = fn(self.pg, values, active, jnp.int32(s))
            n_active = int(stats.n_active)
            density = float(stats.density)
            max_grp = int(stats.max_group_blocks)
            dt = time.perf_counter() - t0
            rec = SuperstepRecord(
                step=s, n_active=n_active, n_msgs=int(stats.n_msgs),
                agg=float(stats.agg), density=density,
                mode="sparse" if use_sparse else "dense", seconds=dt,
                restored_from=restored_from if s == start_step else None,
            )
            history.append(rec)
            if verbose:
                print(
                    f"  superstep {s:4d}: active={rec.n_active:>9d} "
                    f"msgs={rec.n_msgs:>10d} agg={rec.agg:.6g} "
                    f"density={rec.density:.4f} [{rec.mode}] {dt*1e3:.1f} ms"
                )
            if on_step is not None:
                on_step(rec, (values, active))
            if checkpointer is not None:
                saved = checkpointer.maybe_save(s + 1, values, active)
                if saved and self.message_log is not None:
                    # paper §3.4: OMS logs live until a newer checkpoint is
                    # durable — GC everything older as soon as one lands
                    self.message_log.gc_before(s + 1)
            if self.program.num_supersteps is None and n_active == 0:
                break
        return (values, active), history

    # -- result extraction ----------------------------------------------------
    def gather_values(self, values) -> dict[int, Any]:
        """{old_id: value} for all real vertices (the paper's HDFS dump)."""
        vals = np.asarray(values)
        old = np.asarray(self.pg.old_ids)
        mask = np.asarray(self.pg.vmask)
        return dict(zip(old[mask].tolist(), vals[mask].tolist()))

    def memory_model(self) -> dict[str, int]:
        """Bytes per shard held resident vs streamed (Lemma 1 / Theorem 1
        accounting).

        ``resident`` + ``buffers`` + ``staging`` (+ ``msg_staging`` +
        ``channel``) is what a machine must keep in RAM. For the in-memory
        modes the edge groups are device-resident (``streamed`` counts their
        HBM bytes); for ``mode="streamed"`` the edge groups are on disk
        (``streamed`` counts disk bytes) and the only edge-sized thing in
        RAM is the constant staging pool — so the RAM total is O(|V|/n),
        independent of |E|.

        Delegates to ``core.plan.estimate_memory`` — the SAME algebra the
        resource planner runs predictively — parameterized with the
        *realized* geometry and knobs (including the auto-bumped effective
        apply-slice cap and the actual on-disk stream bytes), so planned and
        realized models cannot drift.
        """
        from repro.core.plan import estimate_memory

        pg = self.pg
        streamed = self.mode == "streamed"
        return estimate_memory(
            mode=self.mode,
            n_shards=pg.n_shards,
            P=pg.P,
            E_cap=pg.E_cap,
            edge_block=pg.edge_block,
            value_itemsize=np.dtype(self.program.value_dtype).itemsize,
            msg_itemsize=np.dtype(self.program.msg_dtype).itemsize,
            combined=self.program.combiner is not None,
            pipeline=self.pipeline,
            compress=self.compress,
            compress_payload=(self.compress_payload or False) if streamed
            else self.config.channel.compress_payload,
            full_duplex=self.full_duplex if streamed
            else self.config.channel.full_duplex,
            chunk_blocks=(self._stream_reader.chunk_blocks if streamed
                          else self.config.stream.chunk_blocks),
            depth=(self._stream_reader.depth if streamed
                   else self.config.stream.depth),
            group_batch=(self.group_batch if streamed
                         else self.config.stream.group_batch),
            slice_cap=(self._msg_slice_cap_eff if streamed
                       else self.config.spill.slice_cap),
            read_chunk=self.config.spill.read_chunk,
            merge_fanin=self.config.spill.merge_fanin,
            inflight=self.config.channel.inflight,
            cache_bytes=self.config.stream.cache_bytes,
            disk_bytes_per_shard=(
                self.stream_store.disk_bytes() // pg.n_shards
                if streamed else None
            ),
        )
