"""File-based superstep coordination for the multi-process launch.

One worker process per shard, one coordinator in the job process, a shared
filesystem between them — the smallest deployment that makes the paper's
n-machines claim real. Every record is published with the repo-wide atomic
idiom (write ``.tmp``, then ``os.replace``), so a reader either sees a
complete JSON document or no file at all; no locks, no sockets.

Protocol per superstep ``s`` (all paths under the coordinator directory)::

    worker w                         coordinator (job process)
    --------                         -------------------------
    heartbeat/w.json  (daemon, ~4Hz) watches ages + process liveness
    ...send/receive/apply...
    step-SSSSSS/arrive-w.json  ───►  waits for all n arrivals
                                     reduces totals / halt vote / aggregator
                                     (shard-ascending order, matching the
                                     threaded driver's accumulation)
    step-SSSSSS/commit.json    ◄───  publishes totals + halt + ckpt_landed
    reads commit, continues / halts

``abort.json`` is the poison pill: the coordinator writes it when the run
cannot continue (worker death without recovery wiring); every worker wait
loop polls it and exits instead of hanging on a barrier that will never
open.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time


class WorkerFailed(RuntimeError):
    """A worker process died (or went heartbeat-silent) and the run could
    not recover it. ``record`` carries the worker's structured failure
    record (``repro.fault.failure_record``) when it classified itself —
    e.g. which storage tier faulted — before exiting; ``shard`` is -1 when
    the coordinator process itself is the casualty."""

    def __init__(self, shard: int, message: str, record: dict | None = None):
        super().__init__(message)
        self.shard = shard
        self.record = record


class RunAborted(RuntimeError):
    """The coordinator published ``abort.json``; workers raise this instead
    of waiting forever on a barrier no one will open."""


def atomic_write_json(path: str, obj, *, fsync: bool = True) -> None:
    """The repo-wide publish idiom: a record appears complete or not at all,
    and (by default) is durable before its name exists. ``fsync=False`` is
    for high-rate ephemeral records (heartbeats) where losing the newest
    write in a crash is exactly the signal the record exists to carry."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    os.replace(tmp, path)


def read_json(path: str):
    """Read a published record; None when not (yet) published."""
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        # a concurrent os.replace is atomic, so decode errors only happen
        # for unrelated partial files; treat both as "not published yet"
        return None


class FileCoordinator:
    """Path schema + record IO + barrier waits over one coordinator dir.

    The same class serves both sides: the coordinator (in the job process)
    calls :meth:`wait_arrivals` / :meth:`publish_commit` /
    :meth:`reduce_arrivals`; each worker calls :meth:`arrive` /
    :meth:`wait_commit` / :meth:`start_heartbeat`. Neither side holds any
    state the filesystem does not — a respawned worker re-derives
    everything from the records.
    """

    POLL = 0.005  # first barrier poll interval (seconds)
    POLL_MAX = 0.1  # backoff cap: blocked waiters settle at <= 10 stats/s
    POLL_GROWTH = 2.0

    def __init__(self, directory: str, n_shards: int, *,
                 heartbeat_interval: float = 0.25,
                 heartbeat_timeout: float = 10.0):
        self.dir = directory
        self.n = int(n_shards)
        self.heartbeat_interval = float(heartbeat_interval)
        self.heartbeat_timeout = float(heartbeat_timeout)
        self._beat_seq = 0  # this process's own beat counter
        # shard -> (last JSON progress key, monotonic time it was first seen)
        self._hb_seen: dict[int, tuple] = {}
        os.makedirs(os.path.join(directory, "heartbeat"), exist_ok=True)

    def _poll_delays(self):
        """Exponential backoff for barrier waits: starts at POLL so a
        nearly-open barrier stays fast, caps at POLL_MAX so n blocked
        workers cost O(n/POLL_MAX) stat syscalls/s instead of starving
        co-located folds. One generator per wait — backoff never leaks
        across barriers."""
        d = self.POLL
        while True:
            yield d
            d = min(d * self.POLL_GROWTH, self.POLL_MAX)

    # -- paths ----------------------------------------------------------------
    def step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step-{step:06d}")

    def arrive_path(self, step: int, shard: int) -> str:
        return os.path.join(self.step_dir(step), f"arrive-{shard}.json")

    def commit_path(self, step: int) -> str:
        return os.path.join(self.step_dir(step), "commit.json")

    def heartbeat_path(self, shard: int) -> str:
        return os.path.join(self.dir, "heartbeat", f"{shard}.json")

    def abort_path(self) -> str:
        return os.path.join(self.dir, "abort.json")

    # -- abort (poison pill) ---------------------------------------------------
    def abort(self, reason: str) -> None:
        atomic_write_json(self.abort_path(), dict(reason=reason))

    def aborted(self) -> str | None:
        rec = read_json(self.abort_path())
        return rec["reason"] if rec else None

    def check_abort(self) -> None:
        reason = self.aborted()
        if reason is not None:
            raise RunAborted(f"run aborted by coordinator: {reason}")

    # -- heartbeats ------------------------------------------------------------
    def beat(self, shard: int) -> None:
        """One heartbeat record. ``seq`` is the liveness signal: staleness
        is judged from sequence PROGRESS (plus the watcher's own monotonic
        clock), never from file mtime — shared filesystems round mtime to
        whole seconds and writer/watcher wall clocks skew, either of which
        false-trips worker-dead detection. ``t`` (writer wall time) stays in
        the record for post-mortem reading only."""
        self._beat_seq += 1
        atomic_write_json(self.heartbeat_path(shard),
                          dict(shard=shard, seq=self._beat_seq,
                               # post-mortem reporting only, never liveness
                               t=time.time()),  # analysis: allow[liveness-clock] wall time is recorded, not compared
                          fsync=False)  # ~4Hz; durability loss IS the signal

    def start_heartbeat(self, shard: int) -> threading.Thread:
        """Daemon heartbeat writer; dies with the process — which is the
        point: SIGKILL stops the beats, and the coordinator notices."""
        self.beat(shard)
        stop = threading.Event()

        def run():
            while not stop.wait(self.heartbeat_interval):
                self.beat(shard)

        # deliberately never joined: the thread's whole job is to die with
        # the process so the coordinator sees the beats stop
        t = threading.Thread(target=run, name=f"heartbeat-{shard}",  # analysis: allow[thread-lifecycle] daemon beat thread must die WITH the process, not before
                             daemon=True)
        t.stop = stop  # type: ignore[attr-defined]
        t.start()
        return t

    def heartbeat_age(self, shard: int) -> float:
        """Seconds (on THIS process's monotonic clock) since the shard's
        heartbeat record last made progress — inf before the first record.

        Progress means the ``(seq, t)`` content of the JSON changed; the
        file's mtime is deliberately ignored (coarse-granularity shared
        filesystems and clock skew made the mtime-based age false-trip).
        The first observation of any record counts as fresh: the watcher
        cannot know how long it sat there, and the spawn grace window is
        what covers startup latency."""
        rec = read_json(self.heartbeat_path(shard))
        if rec is None:
            return float("inf")
        key = (rec.get("seq"), rec.get("t"))
        seen = self._hb_seen.get(shard)
        now = time.monotonic()
        if seen is None or seen[0] != key:
            self._hb_seen[shard] = (key, now)
            return 0.0
        return now - seen[1]

    def stale(self, shard: int) -> bool:
        return self.heartbeat_age(shard) > self.heartbeat_timeout

    # -- worker side -----------------------------------------------------------
    def arrive(self, step: int, shard: int, stats: dict) -> None:
        os.makedirs(self.step_dir(step), exist_ok=True)
        atomic_write_json(self.arrive_path(step, shard),
                          dict(shard=shard, step=step, **stats))

    def wait_commit(self, step: int, shard: int) -> dict:
        path = self.commit_path(step)
        delays = self._poll_delays()
        while True:
            rec = read_json(path)
            if rec is not None:
                return rec
            self.check_abort()
            time.sleep(next(delays))

    def commit(self, step: int) -> dict | None:
        """The commit record for ``step`` if published (non-blocking)."""
        return read_json(self.commit_path(step))

    def wait_file(self, path: str, shard: int) -> None:
        """Worker-side wait for any published record (e.g. a peer's outbox
        announce marker); polls the poison pill so a dead coordinator run
        cannot strand the worker."""
        delays = self._poll_delays()
        while not os.path.exists(path):
            self.check_abort()
            time.sleep(next(delays))

    # -- coordinator side --------------------------------------------------------
    def arrivals(self, step: int) -> dict[int, dict]:
        out = {}
        for w in range(self.n):
            rec = read_json(self.arrive_path(step, w))
            if rec is not None:
                out[w] = rec
        return out

    def wait_arrivals(self, step: int, on_wait=None) -> dict[int, dict]:
        """Block until all n workers arrived at ``step``. ``on_wait()`` runs
        every poll tick — the launcher hooks liveness monitoring (process
        exit + heartbeat staleness → recovery or abort) there."""
        delays = self._poll_delays()
        while True:
            got = self.arrivals(step)
            if len(got) == self.n:
                return got
            if on_wait is not None:
                on_wait(got)
            time.sleep(next(delays))

    @staticmethod
    def reduce_arrivals(arrivals: dict[int, dict]) -> dict:
        """Shard-ascending reduction, exactly mirroring the threaded
        driver's per-destination accumulation (``n_active``/``n_msgs`` as
        ints, ``agg`` as a Python-float left fold), so the committed totals
        are bit-identical to the single-process history."""
        n_active = n_msgs = 0
        agg = 0.0
        blocks = 0
        residency = dict(blocks_read=0, cache_hits=0, cache_evictions=0,
                         blocks_skipped=0)
        # socket-transport channel accounting (seconds busy/stalled per
        # direction + bytes framed); zero under the file transport
        net = dict(net_send_s=0.0, net_stall_s=0.0, net_recv_s=0.0,
                   net_recv_stall_s=0.0, net_wire_bytes=0.0,
                   net_frames=0.0)
        for w in sorted(arrivals):
            rec = arrivals[w]
            n_active += int(rec["n_active"])
            n_msgs += int(rec["n_msgs"])
            agg += float(rec["agg"])
            blocks += int(rec.get("active_blocks", 0))
            for key in residency:
                residency[key] += int(rec.get(key, 0))
            for key in net:
                net[key] += float(rec.get(key, 0.0))
        return dict(n_active=n_active, n_msgs=n_msgs, agg=agg,
                    active_blocks=blocks, **residency, **net)

    def publish_commit(self, step: int, totals: dict, *, halt: bool,
                       ckpt_landed: bool) -> dict:
        os.makedirs(self.step_dir(step), exist_ok=True)
        rec = dict(step=step, halt=bool(halt),
                   ckpt_landed=bool(ckpt_landed), **totals)
        atomic_write_json(self.commit_path(step), rec)
        return rec

    # -- cleanup ----------------------------------------------------------------
    def gc_steps(self, before: int) -> None:
        """Drop barrier records older than ``before`` (they are audit crumbs,
        not recovery state — recovery replays from checkpoints + logs)."""
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                try:
                    s = int(name.split("-", 1)[1])
                except ValueError:
                    continue
                if s < before:
                    shutil.rmtree(os.path.join(self.dir, name),
                                  ignore_errors=True)
