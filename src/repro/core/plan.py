"""Resource-aware job planning: from a logical job description to a plan.

The paper's pitch is that GraphD processes very large graphs "with ordinary
computing resources" without the user thinking about memory. This module is
that promise as code: :func:`plan` takes a vertex program, the graph's size,
and a :class:`MemoryBudget`, runs the engine's memory-model algebra
*predictively* over every execution mode the engine offers, and returns an
:class:`ExecutionPlan` — the chosen mode plus every staging/window/fan-in
knob derived from the budget instead of compiled-in constants.

The algebra (:func:`estimate_memory`) is the SAME function the engine's
``memory_model()`` reports after construction — prediction and realization
cannot drift because they are one formula, parameterized by (estimated vs
realized) partition geometry. The per-format byte units live next to the
formats they describe (``streams.store.EDGE_SLOT_BYTES``,
``MessageRunStore.fixed_bytes_per_message``, ``ShardChannels.packet_bytes``).

Mode preference (first feasible wins, all alternatives reported):

* combiner programs:   ``recoded`` → ``recoded_compact`` → ``streamed`` →
  ``streamed+pipeline`` — in-memory combining is fastest; the out-of-core
  tier engages when the edge groups stop fitting; the §4 pipeline engages
  when even the n destination accumulators of the unpipelined streamed fold
  stop fitting (the pipelined fold keeps ONE group + ONE receiver
  accumulator and spills finished groups to inbox runs);
* combiner-less:       ``basic`` → ``streamed`` (OMS spill) →
  ``streamed+pipeline``.

``compress`` (positions) and ``compress_payload`` (message payloads) are
engaged per streamed candidate when the disk or network budget demands
them — the net ladder flips positions first, then payloads, before giving
up; the full-duplex receiver staging and the batched-dispatch lanes sit on
the RAM knob ladder and are shed under pressure. An over-constrained
budget raises :class:`PlanInfeasible` carrying the most frugal candidate's
per-tier byte breakdown.

``launch="processes"`` plans for the true multi-process deployment
(``repro.launch.procs``): every "per-shard" figure in the model then reads
as per-PROCESS — ``ram_total`` is what ONE worker process keeps resident
(its owner view of the edge streams is on disk, its state rows are O(P)),
and ``net_total`` is what one process's NIC carries per superstep over the
shared-filesystem transport. Only the full-duplex streamed pipeline runs
across processes (the transport IS the inbox-run-file channel), so the
in-memory modes and the unpipelined streamed fold are vetoed rather than
silently rewritten.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
from dataclasses import dataclass, field

import numpy as np

from repro.core.config import (
    ChannelConfig, EngineConfig, MessageSpillConfig, RecoveryConfig,
    StreamConfig, validate_launch_opts,
)
from repro.streams.channel import ShardChannels
from repro.streams.msgstore import MessageRunStore
from repro.streams.store import (
    COMPRESS_RATIO_ESTIMATE, EDGE_SLOT_BYTES, estimate_edge_disk_bytes,
)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _fmt(b: int | None) -> str:
    if b is None:
        return "unbounded"
    b = int(b)
    if b >= 1 << 30:
        return f"{b / (1 << 30):.2f} GiB"
    if b >= 1 << 20:
        return f"{b / (1 << 20):.2f} MiB"
    if b >= 1 << 10:
        return f"{b / (1 << 10):.1f} KiB"
    return f"{b} B"


# --------------------------------------------------------------------------
# the shared memory-model algebra (Lemma 1 / Theorem 1 accounting)
# --------------------------------------------------------------------------

#: model keys that live in RAM for every mode; ``streamed`` is the big tier
#: (device memory for in-memory modes, local disk for mode="streamed").
#: ``hot_cache`` is the adaptive semi-external tier: hot edge blocks pinned
#: in RAM by streams/residency.py, sized from the budget's leftover
RAM_KEYS = ("resident", "buffers", "staging", "msg_staging", "channel",
            "receiver_staging", "codec", "wire", "hot_cache")


def estimate_memory(
    *,
    mode: str,
    n_shards: int,
    P: int,
    E_cap: int,
    edge_block: int,
    value_itemsize: int,
    msg_itemsize: int,
    combined: bool,
    pipeline: bool = False,
    compress: bool = False,
    compress_payload=False,
    full_duplex: bool = True,
    chunk_blocks: int = 8,
    depth: int = 2,
    group_batch: int = 1,
    slice_cap: int = 4096,
    read_chunk: int = 4096,
    merge_fanin: int = 16,
    inflight: int = 4,
    cache_bytes: int = 0,
    disk_bytes_per_shard: int | None = None,
) -> dict[str, int]:
    """Per-shard bytes by tier for one (mode, geometry, knobs) point.

    This is the engine's ``memory_model()`` algebra factored out so the
    planner can run it over *candidate* geometries before anything is
    partitioned. Keys: ``resident`` (state array A), ``buffers`` (combine
    accumulators), ``staging`` (edge-reader pool + batched-dispatch
    copies), ``msg_staging`` (combiner-less merge/slice windows),
    ``channel`` (§4 in-flight budget), ``receiver_staging`` (the
    full-duplex background receiver: its accumulator + densified-run /
    queued-slice buffers), ``codec`` (payload-codec encode/decode scratch),
    ``wire`` (mode="basic" raw exchange buffers), ``streamed`` (the big
    tier: device edge groups, or on-disk streams for mode="streamed").
    """
    from repro.streams.codec import PAYLOAD_BLOCK

    resident = P * (value_itemsize + 1 + 4 + 1 + 8)  # values, active, degree, vmask, old
    per_slot = msg_itemsize + 4  # message + count, the A_s/A_r unit (§5)
    if mode != "streamed":
        out = dict(
            resident=resident,
            buffers=P * per_slot * 2,  # A_s + A_r, two in flight (§5)
            staging=0,
            streamed=n_shards * E_cap * EDGE_SLOT_BYTES,  # edge groups in HBM
        )
        if mode == "basic":
            # raw (dst, payload) all_to_all: E-sized send + receive buffers
            out["wire"] = 2 * n_shards * E_cap * (4 + msg_itemsize)
        return out
    chunk_slots = chunk_blocks * edge_block
    staging = (depth + 1) * chunk_slots * EDGE_SLOT_BYTES
    if combined and group_batch > 1:
        # batched group dispatch holds up to G copied single-chunk groups
        # on the way in AND the (G, P) accumulator/count stacks on the way
        # out (vs the ONE group accumulator already counted in ``buffers``)
        staging += group_batch * chunk_slots * EDGE_SLOT_BYTES
        staging += (group_batch - 1) * P * (msg_itemsize + 4)
    if combined:
        if pipeline:
            # one group accumulator folding + one receiver accumulator
            buffers = 2 * P * per_slot
        else:
            # all n destination accumulators resident until apply, plus the
            # group accumulator when a message log splits the fold per group
            buffers = (n_shards + 1) * P * per_slot
    else:
        # double-buffered (values, active) rows for the slice overwrite
        # merge, plus the per-position message counts
        buffers = 2 * P * (value_itemsize + 1) + P * 4
    out = dict(
        resident=resident,
        buffers=buffers,
        staging=staging,
        streamed=(
            disk_bytes_per_shard
            if disk_bytes_per_shard is not None
            else estimate_edge_disk_bytes(n_shards, E_cap, compress,
                                          bool(compress_payload))
        ),
    )
    if cache_bytes:
        # the semi-external hot-block tier: decoded edge blocks pinned in
        # RAM by BlockResidency, a hard byte budget (admission is refused
        # beyond it) — so the model term IS the bound, not an estimate
        out["hot_cache"] = int(cache_bytes)
    if pipeline:
        out["channel"] = inflight * ShardChannels.packet_bytes(
            P=P, msg_itemsize=msg_itemsize, combined=combined,
            chunk_slots=chunk_slots,
        )
        if full_duplex:
            # the background receiver's resident slice of the §4 budget:
            # combiner path — one densified (A, cnt) run beside the
            # accumulator already counted in ``buffers``; OMS path — the
            # receive_iter queue of up to ``depth`` decoded apply slices
            out["receiver_staging"] = (
                P * per_slot if combined
                else depth * slice_cap * (4 + msg_itemsize)
            )
    if compress_payload:
        # payload-codec scratch: one encode + one decode buffer of the
        # largest unit the engine feeds it (a combined run is <= P slots, a
        # raw spill chunk <= chunk_slots), capped by the codec's own block
        # bound. (The varint codec's scratch is byte-windowed and noise.)
        unit = min(PAYLOAD_BLOCK, P if combined else chunk_slots)
        out["codec"] = 2 * unit * per_slot
    if not combined:
        # the disk message tier (§3.3): merge cursor windows (fan-in bounded
        # by compaction), one destination-aligned apply slice, and the
        # spill-sort staging for one staged edge chunk (all DECODED widths —
        # the wire codecs never change resident windows)
        per_msg = MessageRunStore.fixed_bytes_per_message(msg_itemsize)
        fanin = max(merge_fanin, n_shards)
        out["msg_staging"] = (
            fanin * read_chunk * per_msg
            + slice_cap * per_msg
            + chunk_slots * per_msg
        )
    return out


def ram_total(model: dict[str, int], mode: str) -> int:
    """What one machine must keep in RAM under ``model``. For the in-memory
    modes the edge groups (the ``streamed`` tier) are device-resident and
    count; for ``mode="streamed"`` they are on local disk and do not."""
    total = sum(model.get(k, 0) for k in RAM_KEYS)
    if mode != "streamed":
        total += model.get("streamed", 0)
    return int(total)


def estimate_net(mode: str, *, n_shards: int, P: int, E_cap: int,
                 msg_itemsize: int, combined: bool, compress: bool = False,
                 compress_payload=False) -> int:
    """Bytes one shard puts on the wire per superstep (the Table 2-8 axis).
    For the streamed channel the per-message unit is
    :meth:`ShardChannels.wire_bytes_per_message`, so the ``compress`` /
    ``compress_payload`` knobs shrink the estimate exactly where they
    shrink the stream."""
    if mode == "recoded_compact":
        return n_shards * P * 3  # bf16 value + 1-byte has-msg flag
    if mode in ("recoded", "basic_sc"):
        return n_shards * P * (msg_itemsize + 4)  # combined A_s + counts
    if mode == "basic":
        return n_shards * E_cap * (4 + msg_itemsize)  # raw (dst, payload)
    per_msg = ShardChannels.wire_bytes_per_message(
        msg_itemsize=msg_itemsize, combined=combined, compress=compress,
        compress_payload=compress_payload,
    )
    if not combined:
        return int(n_shards * E_cap * per_msg)  # raw runs, one per chunk
    return int(n_shards * P * per_msg)  # sparse combined groups


def estimate_net_seconds(net_bytes: int, link_bytes_per_s: float) -> float:
    """Seconds one shard spends transmitting per superstep at a MEASURED
    per-link throughput — the time axis the byte model alone cannot give.
    Pair with :func:`measured_link_throughput` (or any bytes/s figure)."""
    if link_bytes_per_s <= 0:
        raise ValueError("link_bytes_per_s must be positive")
    return net_bytes / float(link_bytes_per_s)


def measured_link_throughput(n_bytes: int = 8 << 20) -> float:
    """Probe the actual link (loopback TCP through the socket transport's
    frame path, framing + CRC included) instead of proxying network cost
    with disk bandwidth. Lazy import: the planner stays importable without
    the launch layer."""
    from repro.launch.net import probe_link_throughput

    return probe_link_throughput(n_bytes)


# --------------------------------------------------------------------------
# budget / metadata inputs
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MemoryBudget:
    """What one machine may spend. ``None`` = unconstrained tier."""

    ram_per_shard: int | None = None
    n_shards: int = 4
    disk_per_shard: int | None = None
    net_per_superstep: int | None = None

    def validate(self) -> "MemoryBudget":
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        for name in ("ram_per_shard", "disk_per_shard", "net_per_superstep"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be positive (or None)")
        return self

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclass(frozen=True)
class GraphMeta:
    """The logical facts the planner needs about a graph.

    When built from an already-partitioned graph the exact per-shard
    geometry rides along (``max_shard_vertices``/``for_n_shards``), making
    the plan's P — and with it every P-proportional tier — exact instead of
    the ``ceil(|V|/n)`` estimate (the hash partition is near-balanced but
    not perfect; Lemma 1 only bounds the skew by 2)."""

    n_vertices: int
    n_edges: int
    max_shard_vertices: int | None = None  # realized P (pre-padding) if known
    for_n_shards: int | None = None  # shard count that P was realized for

    @classmethod
    def of(cls, graph) -> "GraphMeta":
        """Accepts a ``graph.csr.Graph``, a ``PartitionedGraph``, or an
        existing GraphMeta."""
        if isinstance(graph, cls):
            return graph
        return cls(n_vertices=int(graph.n_vertices),
                   n_edges=int(graph.n_edges),
                   max_shard_vertices=getattr(graph, "P", None),
                   for_n_shards=getattr(graph, "n_shards", None))

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class PlanInfeasible(RuntimeError):
    """No execution mode fits the budget; ``breakdown`` holds the budget and
    every candidate's per-tier byte model (also formatted into the message,
    so the failure is actionable from the log line alone)."""

    def __init__(self, message: str, breakdown: dict):
        super().__init__(message)
        self.breakdown = breakdown


# --------------------------------------------------------------------------
# plan artifacts
# --------------------------------------------------------------------------

@dataclass
class Candidate:
    """One evaluated (mode, knobs) alternative — kept on the plan so
    ``explain()`` can say why everything NOT chosen was rejected."""

    name: str
    mode: str
    pipeline: bool
    compress: bool
    feasible: bool
    chosen: bool
    reason: str
    model: dict[str, int]
    ram_total: int
    disk_total: int
    net_total: int
    knobs: dict[str, int]
    compress_payload: bool = False
    # net_total priced at a measured per-link throughput (seconds/superstep);
    # 0.0 when the plan was made without a link probe
    net_seconds: float = 0.0

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclass
class ExecutionPlan:
    """The planner's output: a finalized EngineConfig plus the partition-time
    knobs, the predicted byte model, and the full audit trail."""

    config: EngineConfig
    budget: MemoryBudget
    meta: GraphMeta
    n_shards: int
    edge_block: int
    vertex_pad: int
    model: dict[str, int]
    ram_total: int
    disk_total: int
    net_total: int
    alternatives: list[Candidate] = field(default_factory=list)
    #: "threads" (single-process emulation) or "processes" (one worker
    #: process per shard over the shared-filesystem transport); with
    #: "processes" the per-shard model IS the per-process RAM/NIC budget
    launch: str = "threads"
    #: deployment knobs for launch="processes" (transport, timeouts, retry
    #: budget, chaos schedule — the surface documented by
    #: config.LAUNCH_OPT_FIELDS), validated at plan time so a serialized
    #: plan fully describes a runnable deployment; GraphDJob merges its own
    #: launch_opts over these
    launch_opts: dict = field(default_factory=dict)

    @property
    def mode(self) -> str:
        return self.config.mode

    @property
    def pipeline(self) -> bool:
        return self.config.channel.pipeline

    @property
    def compress(self) -> bool:
        return self.config.channel.compress

    @property
    def compress_payload(self):
        return self.config.channel.compress_payload

    def explain(self) -> str:
        """Human-readable plan audit: the per-tier byte model of the chosen
        mode and why each alternative was rejected (or not preferred)."""
        b = self.budget
        chosen = next(c for c in self.alternatives if c.chosen)
        lines = [
            f"ExecutionPlan: {chosen.name} for |V|={self.meta.n_vertices:,} "
            f"|E|={self.meta.n_edges:,} on n_shards={self.n_shards} "
            f"(edge_block={self.edge_block})",
            f"budget: ram/shard={_fmt(b.ram_per_shard)} "
            f"disk/shard={_fmt(b.disk_per_shard)} "
            f"net/superstep={_fmt(b.net_per_superstep)}",
            f"predicted: ram={_fmt(self.ram_total)} "
            f"disk={_fmt(self.disk_total)} net={_fmt(self.net_total)}/step",
            "model/shard: "
            + " ".join(f"{k}={_fmt(v)}" for k, v in self.model.items()),
        ]
        if chosen.knobs:
            lines.append(
                "knobs: "
                + " ".join(f"{k}={v}" for k, v in chosen.knobs.items())
            )
        lines.append("alternatives:")
        for c in self.alternatives:
            if c.chosen:
                verdict = "CHOSEN"
            elif c.feasible:
                verdict = "FEASIBLE"
            else:
                verdict = "REJECTED"
            line = (f"  {c.name:<20} {verdict:<8} ram={_fmt(c.ram_total)} "
                    f"disk={_fmt(c.disk_total)} net={_fmt(c.net_total)}/step")
            if c.net_seconds:
                line += f" ({c.net_seconds * 1e3:.2f} ms at measured link)"
            if c.reason:
                line += f" — {c.reason}"
            lines.append(line)
        return "\n".join(lines)

    def to_json(self) -> str:
        return json.dumps(dict(
            config=self.config.to_json(),
            budget=self.budget.to_json(),
            meta=self.meta.to_json(),
            n_shards=self.n_shards,
            edge_block=self.edge_block,
            vertex_pad=self.vertex_pad,
            model=self.model,
            ram_total=self.ram_total,
            disk_total=self.disk_total,
            net_total=self.net_total,
            alternatives=[c.to_json() for c in self.alternatives],
            launch=self.launch,
            launch_opts=self.launch_opts,
        ))

    @classmethod
    def from_json(cls, s: str) -> "ExecutionPlan":
        d = json.loads(s)
        return cls(
            config=EngineConfig.from_json(d["config"]),
            budget=MemoryBudget(**d["budget"]),
            meta=GraphMeta(**d["meta"]),
            n_shards=d["n_shards"],
            edge_block=d["edge_block"],
            vertex_pad=d["vertex_pad"],
            model=d["model"],
            ram_total=d["ram_total"],
            disk_total=d["disk_total"],
            net_total=d["net_total"],
            alternatives=[Candidate(**c) for c in d["alternatives"]],
            launch=d.get("launch", "threads"),
            launch_opts=d.get("launch_opts", {}),
        )


# --------------------------------------------------------------------------
# the planner
# --------------------------------------------------------------------------

# knob ladders, most preferred (fastest / default) first; the floor of each
# ladder is the most frugal configuration the engine still runs correctly
# (slice_cap auto-bumps to the max in-degree at runtime, the Pregel floor)
_CHUNK_LADDER = (8, 4, 2, 1)
_INFLIGHT_LADDER = (4, 2, 1)
_READ_LADDER = (4096, 1024, 256, 64)
_SLICE_LADDER = (4096, 1024, 512, 128)
_BATCH_LADDER = (4, 2, 1)  # batched group dispatch lanes (RAM: G chunk copies)


def plan(
    program,
    graph_meta,
    budget: MemoryBudget | None = None,
    *,
    edge_block: int = 512,
    vertex_pad: int = 8,
    depth: int = 2,
    skew: float = 1.5,
    recovery: RecoveryConfig | None = None,
    launch: str = "threads",
    launch_opts: dict | None = None,
    link_bytes_per_s: float | None = None,
) -> ExecutionPlan:
    """Choose an execution mode and derive every knob from the budget.

    ``graph_meta`` is a :class:`GraphMeta`, a ``Graph``, or a
    ``PartitionedGraph``; ``skew`` models the max/mean per-group padding
    overhead of the hash partition (Lemma 1 bounds it by 2).
    ``link_bytes_per_s`` prices every candidate's ``net_total`` in seconds
    (``Candidate.net_seconds``) at a measured per-link throughput — pass
    :func:`measured_link_throughput` for a real probe of the socket
    transport's frame path instead of a disk-bandwidth proxy.
    ``launch="processes"`` restricts the candidate set to what the
    multi-process deployment can actually execute — on-disk edge streams
    (each worker maps only its owner view) and the full-duplex pipelined
    channel (the shared-filesystem transport speaks the inbox-run-file
    format) — and frames the model as per-process RAM / per-NIC bytes.
    ``launch_opts`` pins deployment knobs (transport, net timeouts, retry
    budget — the surface of ``config.LAUNCH_OPT_FIELDS``) into the plan,
    validated here so a serialized plan is a runnable deployment spec.
    """
    if launch not in ("threads", "processes"):
        raise ValueError(
            f"launch must be 'threads' or 'processes', got {launch!r}"
        )
    launch_opts = validate_launch_opts(launch_opts, launch)
    meta = GraphMeta.of(graph_meta)
    budget = (budget or MemoryBudget()).validate()
    n = budget.n_shards
    combined = program.combiner is not None
    vdt = np.dtype(program.value_dtype).itemsize
    mdt = np.dtype(program.msg_dtype).itemsize
    float_msgs = np.dtype(program.msg_dtype).kind == "f" and mdt <= 4

    if meta.max_shard_vertices is not None and meta.for_n_shards == n:
        P = max(_round_up(meta.max_shard_vertices, vertex_pad), vertex_pad)
    else:
        P = max(_round_up(-(-meta.n_vertices // n), vertex_pad), vertex_pad)
    mean_group = meta.n_edges / (n * n)
    E_cap = max(_round_up(int(mean_group * skew), edge_block), edge_block)
    geom = dict(n_shards=n, P=P, E_cap=E_cap, edge_block=edge_block,
                value_itemsize=vdt, msg_itemsize=mdt, combined=combined)

    def in_memory(name: str, mode: str, reason_veto: str = "") -> Candidate:
        if launch == "processes" and not reason_veto:
            reason_veto = (
                "launch='processes' needs mode='streamed': workers exchange "
                "messages through on-disk inbox run files and map only "
                "their owner view of the edge streams"
            )
        model = estimate_memory(mode=mode, **geom)
        ram = ram_total(model, mode)
        net = estimate_net(mode, n_shards=n, P=P, E_cap=E_cap,
                           msg_itemsize=mdt, combined=combined)
        disk = 0
        feasible, reason = True, ""
        if reason_veto:
            feasible, reason = False, reason_veto
        elif budget.ram_per_shard is not None and ram > budget.ram_per_shard:
            feasible = False
            reason = (f"ram {_fmt(ram)} > budget "
                      f"{_fmt(budget.ram_per_shard)} (edge groups resident: "
                      f"{_fmt(model['streamed'])})")
        elif (budget.net_per_superstep is not None
              and net > budget.net_per_superstep):
            feasible = False
            reason = (f"net {_fmt(net)}/superstep > budget "
                      f"{_fmt(budget.net_per_superstep)}")
        return Candidate(name=name, mode=mode, pipeline=False, compress=False,
                         feasible=feasible, chosen=False, reason=reason,
                         model=model, ram_total=ram, disk_total=disk,
                         net_total=net, knobs={})

    def streamed(pipeline: bool) -> Candidate:
        name = "streamed+pipeline" if pipeline else "streamed"
        # disk tier first: engage compression only when the budget demands it
        compress = False
        compress_payload = False
        per_msg_spill = MessageRunStore.fixed_bytes_per_message(mdt)

        def disk_for(compress: bool, compress_payload: bool) -> int:
            d = estimate_edge_disk_bytes(n, E_cap, compress,
                                         compress_payload)
            spill_per_msg = ShardChannels.wire_bytes_per_message(
                msg_itemsize=mdt, combined=combined, compress=compress,
                compress_payload=compress_payload,
            ) if (compress or compress_payload) else (
                per_msg_spill if not combined else (4 + mdt + 4)
            )
            if not combined:
                d += int(E_cap * spill_per_msg)  # peak OMS: one dest's runs
            elif pipeline:
                d += int(P * spill_per_msg)  # peak inbox: one dest's groups
            return d

        disk = disk_for(False, False)
        if budget.disk_per_shard is not None and disk > budget.disk_per_shard:
            compress = True
            disk = disk_for(True, False)
            if disk > budget.disk_per_shard:
                compress_payload = True
                disk = disk_for(True, True)

        def net_for(compress: bool, compress_payload: bool) -> int:
            return estimate_net(
                "streamed", n_shards=n, P=P, E_cap=E_cap, msg_itemsize=mdt,
                combined=combined, compress=compress,
                compress_payload=compress_payload,
            )

        # network tier next: a shrinking net budget flips the wire codecs
        # on (positions first, then the payload channel) before anything is
        # declared infeasible
        net = net_for(compress, compress_payload)
        if budget.net_per_superstep is not None:
            if net > budget.net_per_superstep and not compress:
                compress = True
                net = net_for(compress, compress_payload)
            if net > budget.net_per_superstep and not compress_payload:
                compress_payload = True
                net = net_for(compress, compress_payload)
            disk = disk_for(compress, compress_payload)
        # knob ladders, first fit wins; ordering shrinks the cheap knobs
        # first (merge fan-in, then read/slice windows, then the in-flight
        # budget and batch width, then the edge staging chunk)
        fanin_ladder = sorted({16, max(2, n)}, reverse=True)
        infl_ladder = _INFLIGHT_LADDER if pipeline else (4,)
        # full duplex preferred; shedding it drops the receiver-staging
        # tier, so it sits between the batch ladder (cheapest to give up)
        # and the window/in-flight ladders. The multi-process transport IS
        # the full-duplex channel (workers digest peer runs as they land),
        # so launch='processes' pins the knob instead of laddering it
        if launch == "processes":
            duplex_ladder = (True,)
        else:
            duplex_ladder = (True, False) if pipeline else (True,)
        if combined:
            combos = itertools.product(
                _CHUNK_LADDER, infl_ladder, (4096,), (4096,), (16,),
                duplex_ladder, _BATCH_LADDER,
            )
        else:
            combos = itertools.product(
                _CHUNK_LADDER, infl_ladder, _SLICE_LADDER, _READ_LADDER,
                fanin_ladder, duplex_ladder, (1,),
            )
        chosen_model = chosen_knobs = None
        ram = 0
        for cb, infl, sc, rc, fanin, fd, gb in combos:
            model = estimate_memory(
                mode="streamed", pipeline=pipeline, compress=compress,
                compress_payload=compress_payload, full_duplex=fd,
                chunk_blocks=cb, depth=depth, group_batch=gb, slice_cap=sc,
                read_chunk=rc, merge_fanin=fanin, inflight=infl, **geom,
            )
            ram = ram_total(model, "streamed")
            chosen_model = model
            chosen_knobs = dict(chunk_blocks=cb, depth=depth, inflight=infl,
                                group_batch=gb, full_duplex=fd,
                                slice_cap=sc, read_chunk=rc,
                                merge_fanin=fanin)
            if budget.ram_per_shard is None or ram <= budget.ram_per_shard:
                break
        feasible, reason = True, ""
        if launch == "processes" and not pipeline:
            feasible = False
            reason = ("launch='processes' runs the pipelined full-duplex "
                      "channel only (the shared-filesystem transport is the "
                      "inbox-run-file channel; the unpipelined fold keeps "
                      "all n accumulators in one address space)")
        elif budget.ram_per_shard is not None and ram > budget.ram_per_shard:
            feasible = False
            reason = (f"ram {_fmt(ram)} > budget "
                      f"{_fmt(budget.ram_per_shard)} even at floor knobs "
                      + " ".join(f"{k}={_fmt(v)}"
                                 for k, v in chosen_model.items()
                                 if k != "streamed"))
        elif (budget.disk_per_shard is not None
              and disk > budget.disk_per_shard):
            feasible = False
            reason = (f"disk {_fmt(disk)} > budget "
                      f"{_fmt(budget.disk_per_shard)} even compressed")
        elif (budget.net_per_superstep is not None
              and net > budget.net_per_superstep):
            # inbox appends are local disk in emulation, but they model
            # cross-machine traffic in deployment — the budget applies
            feasible = False
            reason = (f"net {_fmt(net)}/superstep > budget "
                      f"{_fmt(budget.net_per_superstep)} even with the "
                      "position and payload codecs engaged")
        if feasible and budget.ram_per_shard is not None:
            # per-shard tier assignment: the RAM the floor knobs left unused
            # becomes this shard's hot_cache tier (streams/residency.py) —
            # capped at the decoded edge stream, past which the whole graph
            # fits and more cache is waste. Re-run the algebra so the tier
            # is modeled exactly where the engine will realize it.
            spare = int(budget.ram_per_shard) - ram
            cache = max(0, min(spare, n * E_cap * EDGE_SLOT_BYTES))
            if cache:
                ck = chosen_knobs
                chosen_model = estimate_memory(
                    mode="streamed", pipeline=pipeline, compress=compress,
                    compress_payload=compress_payload,
                    full_duplex=ck["full_duplex"],
                    chunk_blocks=ck["chunk_blocks"], depth=depth,
                    group_batch=ck["group_batch"],
                    slice_cap=ck["slice_cap"], read_chunk=ck["read_chunk"],
                    merge_fanin=ck["merge_fanin"], inflight=ck["inflight"],
                    cache_bytes=cache, **geom,
                )
                ram = ram_total(chosen_model, "streamed")
                chosen_knobs = dict(chosen_knobs, cache_bytes=cache)
        if compress:
            name += "+compress"
        if compress_payload:
            name += "+payload"
        return Candidate(name=name, mode="streamed", pipeline=pipeline,
                         compress=compress, feasible=feasible, chosen=False,
                         reason=reason, model=chosen_model,
                         ram_total=ram, disk_total=disk, net_total=net,
                         knobs=chosen_knobs,
                         compress_payload=compress_payload)

    candidates: list[Candidate] = []
    if combined:
        candidates.append(in_memory("recoded", "recoded"))
        candidates.append(in_memory(
            "recoded_compact", "recoded_compact",
            reason_veto="" if float_msgs
            else "needs float messages (bf16 wire rounds integers)",
        ))
        candidates.append(in_memory(
            "basic", "basic",
            reason_veto="dominated by recoded for combiner programs "
                        "(network and buffers ∝ |E| instead of |V|)",
        ))
    else:
        candidates.append(in_memory("basic", "basic"))
    candidates.append(streamed(pipeline=False))
    candidates.append(streamed(pipeline=True))

    if link_bytes_per_s is not None:
        for c in candidates:
            c.net_seconds = estimate_net_seconds(c.net_total,
                                                 link_bytes_per_s)

    winner = next((c for c in candidates if c.feasible), None)
    if winner is None:
        frugal = candidates[-1]
        breakdown = dict(budget=budget.to_json(), meta=meta.to_json(),
                         candidates=[c.to_json() for c in candidates])
        raise PlanInfeasible(
            f"no execution mode fits {budget}: the most frugal plan "
            f"({frugal.name} at floor knobs) still needs "
            f"{_fmt(frugal.ram_total)} RAM/shard ("
            + " ".join(f"{k}={_fmt(v)}" for k, v in frugal.model.items()
                       if k != "streamed")
            + f") and {_fmt(frugal.disk_total)} disk/shard; raise "
            f"ram_per_shard, add shards, or relax the disk budget.",
            breakdown,
        )
    winner.chosen = True
    for c in candidates:
        if c.feasible and not c.chosen and not c.reason:
            c.reason = f"feasible, but {winner.name} preferred (listed order)"

    k = winner.knobs
    cfg = EngineConfig(
        mode=winner.mode,
        stream=StreamConfig(chunk_blocks=k.get("chunk_blocks", 8),
                            depth=k.get("depth", depth),
                            group_batch=k.get("group_batch", 1),
                            cache_bytes=k.get("cache_bytes", 0)),
        spill=MessageSpillConfig(slice_cap=k.get("slice_cap", 4096),
                                 read_chunk=k.get("read_chunk", 4096),
                                 merge_fanin=k.get("merge_fanin", 16)),
        channel=ChannelConfig(pipeline=winner.pipeline,
                              compress=winner.compress,
                              compress_payload=winner.compress_payload,
                              full_duplex=bool(k.get("full_duplex", True)),
                              inflight=k.get("inflight", 4)),
        recovery=recovery or RecoveryConfig(),
    ).finalize()
    return ExecutionPlan(
        config=cfg, budget=budget, meta=meta, n_shards=n,
        edge_block=edge_block, vertex_pad=vertex_pad,
        model=winner.model, ram_total=winner.ram_total,
        disk_total=winner.disk_total, net_total=winner.net_total,
        alternatives=candidates, launch=launch, launch_opts=launch_opts,
    )
