"""GraphD core: the paper's contribution as a composable JAX module."""

from repro.core.api import (
    SUM, MIN, MAX, IMIN, IMAX, OR, Combiner, ShardContext, VertexProgram,
)
from repro.core.config import (
    ChannelConfig, ConfigError, EngineConfig, MessageSpillConfig,
    RecoveryConfig, StreamConfig,
)
from repro.core.engine import GraphDEngine, StepStats, SuperstepRecord, superstep_spmd
from repro.core.plan import (
    ExecutionPlan, GraphMeta, MemoryBudget, PlanInfeasible, estimate_memory,
    plan,
)
from repro.core.job import GraphDJob, JobResult
from repro.core.algorithms import (
    BFS, SSSP, DegreeSum, DistinctInLabels, HashMin, LabelSpread, PageRank,
    SecondMinLabel,
)

__all__ = [
    "SUM", "MIN", "MAX", "IMIN", "IMAX", "OR",
    "Combiner", "ShardContext", "VertexProgram",
    "EngineConfig", "StreamConfig", "MessageSpillConfig", "ChannelConfig",
    "RecoveryConfig", "ConfigError",
    "GraphDEngine", "StepStats", "SuperstepRecord", "superstep_spmd",
    "ExecutionPlan", "GraphMeta", "MemoryBudget", "PlanInfeasible",
    "estimate_memory", "plan",
    "GraphDJob", "JobResult",
    "PageRank", "HashMin", "SSSP", "BFS", "DegreeSum", "LabelSpread",
    "DistinctInLabels", "SecondMinLabel",
]
