"""GraphD core: the paper's contribution as a composable JAX module.

The public names are re-exported LAZILY (PEP 562): importing a light
submodule — ``repro.core.coordinator`` in particular — must not pay for
the engine's jax import. Worker processes of the multi-process launch
import the coordinator and start their liveness heartbeat *before* any
heavy import; an eager package ``__init__`` defeated that (three workers
cold-importing jax on a loaded single-core machine blew the heartbeat
grace window and tripped a false "worker dead" detection).
"""

#: public name -> submodule that defines it (resolved on first attribute
#: access; ``from repro.core import X`` goes through __getattr__ too)
_EXPORTS = {
    name: mod
    for mod, names in {
        "api": ("SUM", "MIN", "MAX", "IMIN", "IMAX", "OR",
                "Combiner", "ShardContext", "VertexProgram"),
        "config": ("ChannelConfig", "ConfigError", "EngineConfig",
                   "MessageSpillConfig", "RecoveryConfig", "StreamConfig"),
        "engine": ("GraphDEngine", "StepStats", "SuperstepRecord",
                   "superstep_spmd"),
        "plan": ("ExecutionPlan", "GraphMeta", "MemoryBudget",
                 "PlanInfeasible", "estimate_memory", "plan"),
        "job": ("GraphDJob", "JobResult"),
        "algorithms": ("BFS", "SSSP", "DegreeSum", "DistinctInLabels",
                       "HashMin", "LabelSpread", "PageRank",
                       "SecondMinLabel"),
    }.items()
    for name in names
}

# ``plan`` the FUNCTION collides with ``plan`` the submodule: whenever the
# submodule is (transitively) imported, the import machinery binds the
# module object as a package attribute, which would shadow the lazy export
# and never let __getattr__ fire. Bind the function eagerly instead — the
# submodule is jax-free, so this keeps worker startup light — and later
# submodule imports find it in sys.modules and leave this binding alone.
from repro.core.plan import plan  # noqa: E402

__all__ = [
    "SUM", "MIN", "MAX", "IMIN", "IMAX", "OR",
    "Combiner", "ShardContext", "VertexProgram",
    "EngineConfig", "StreamConfig", "MessageSpillConfig", "ChannelConfig",
    "RecoveryConfig", "ConfigError",
    "GraphDEngine", "StepStats", "SuperstepRecord", "superstep_spmd",
    "ExecutionPlan", "GraphMeta", "MemoryBudget", "PlanInfeasible",
    "estimate_memory", "plan",
    "GraphDJob", "JobResult",
    "PageRank", "HashMin", "SSSP", "BFS", "DegreeSum", "LabelSpread",
    "DistinctInLabels", "SecondMinLabel",
]


def __getattr__(name):
    import importlib

    mod = _EXPORTS.get(name)
    if mod is not None:
        value = getattr(importlib.import_module(f"{__name__}.{mod}"), name)
        globals()[name] = value  # cache: __getattr__ runs once per name
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
