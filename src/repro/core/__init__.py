"""GraphD core: the paper's contribution as a composable JAX module."""

from repro.core.api import (
    SUM, MIN, MAX, IMIN, IMAX, OR, Combiner, ShardContext, VertexProgram,
)
from repro.core.engine import GraphDEngine, StepStats, SuperstepRecord, superstep_spmd
from repro.core.algorithms import (
    BFS, SSSP, DegreeSum, DistinctInLabels, HashMin, LabelSpread, PageRank,
    SecondMinLabel,
)

__all__ = [
    "SUM", "MIN", "MAX", "IMIN", "IMAX", "OR",
    "Combiner", "ShardContext", "VertexProgram",
    "GraphDEngine", "StepStats", "SuperstepRecord", "superstep_spmd",
    "PageRank", "HashMin", "SSSP", "BFS", "DegreeSum", "LabelSpread",
    "DistinctInLabels", "SecondMinLabel",
]
