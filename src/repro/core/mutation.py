"""Topology mutation (paper §3.4).

Edge mutations rewrite the edge stream for the next superstep; vertex
additions append to the state array A with freshly recoded ids — existing
vertices never change their (shard, position), the invariant the paper's
intra-superstep recoding maintains. With dense JAX arrays, mutations are
applied *between* jitted superstep runs (a batched analogue of the paper's
"new edge stream for Step i+1"): extract-globals -> edit -> reassemble with
the same assembler as load time.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.elastic import extract_global
from repro.graph.partition import PartitionedGraph, build_partition


def mutate(
    pg: PartitionedGraph,
    values,
    active,
    *,
    add_edges=None,  # (src_gid, dst_gid[, weight]) rows over recoded ids
    remove_edges=None,  # (src_gid, dst_gid) rows
    add_vertices: int = 0,  # count of new vertices (appended, fresh gids)
    new_vertex_value=0,
):
    """Returns (pg', values', active', new_gids). Positions of existing
    vertices are preserved (same gids => same shard/pos for the same n)."""
    n = pg.n_shards
    g_real, old_real, val_real, act_real, src_g, dst_g, w_g = extract_global(
        pg, values, active
    )

    if remove_edges is not None and len(remove_edges):
        rem = {(int(a), int(b)) for a, b in np.asarray(remove_edges)}
        keep = np.array(
            [(int(a), int(b)) not in rem for a, b in zip(src_g, dst_g)]
        )
        src_g, dst_g, w_g = src_g[keep], dst_g[keep], w_g[keep]

    new_gids = np.zeros(0, dtype=np.int64)
    if add_vertices:
        # fresh ids continue each shard's position sequence (paper: new
        # vertices are appended to A; id = n*pos + i keeps holding)
        per_shard_next = np.zeros(n, dtype=np.int64)
        shards = g_real % n
        for i in range(n):
            mine = g_real[shards == i]
            per_shard_next[i] = (mine.max() // n + 1) if mine.size else 0
        outs = []
        for j in range(add_vertices):
            i = j % n  # round-robin like hash assignment
            outs.append(n * per_shard_next[i] + i)
            per_shard_next[i] += 1
        new_gids = np.asarray(outs, dtype=np.int64)
        g_real = np.concatenate([g_real, new_gids])
        old_real = np.concatenate(
            [old_real, -2 - np.arange(add_vertices, dtype=np.int64)]
        )  # synthetic old ids for dumped output
        val_real = np.concatenate(
            [val_real,
             np.full(add_vertices, new_vertex_value, val_real.dtype)]
        )
        act_real = np.concatenate(
            [act_real, np.ones(add_vertices, dtype=bool)]
        )

    if add_edges is not None and len(add_edges):
        ae = np.asarray(add_edges)
        src_g = np.concatenate([src_g, ae[:, 0].astype(np.int64)])
        dst_g = np.concatenate([dst_g, ae[:, 1].astype(np.int64)])
        w_new = (ae[:, 2].astype(np.float32) if ae.shape[1] > 2
                 else np.ones(len(ae), np.float32))
        w_g = np.concatenate([w_g, w_new])

    order = np.argsort(g_real)
    pg2 = build_partition(
        n, src_g, dst_g, w_g, g_real[order], old_real[order],
        edge_block=pg.edge_block,
    )
    vals2 = np.zeros((n, pg2.P), dtype=val_real.dtype)
    act2 = np.zeros((n, pg2.P), dtype=bool)
    vals2[g_real % n, g_real // n] = val_real
    act2[g_real % n, g_real // n] = act_real
    return pg2, jnp.asarray(vals2), jnp.asarray(act2), new_gids
