"""Elastic scaling: repartition a running job from n to n' shards.

The ID-recoding invariant (paper §5) makes this a pure index transform: a
global recoded id ``g`` maps to ``(shard, pos) = (g mod n', g // n')`` for
*any* shard count, so vertex state migrates with two integer ops per vertex
and no re-recoding. Edge groups are rebuilt host-side with the same assembler
used at load time (the paper's loading pass, §3.4), and the job resumes at
the same superstep — tested for bit-equivalence against an uninterrupted run.

This is what lets a 1000-node deployment shed or absorb machines between
checkpoints (scale on preemption, straggler replacement) without touching
algorithm state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.partition import PartitionedGraph, build_partition


def extract_global(pg: PartitionedGraph, values, active):
    """Flatten a partitioned job to global-id-indexed host arrays."""
    n = pg.n_shards
    gids = np.asarray(pg.gids)
    vmask = np.asarray(pg.vmask)
    old_ids = np.asarray(pg.old_ids)
    vals = np.asarray(values)
    act = np.asarray(active)

    g_real = gids[vmask]  # (V,)
    order = np.argsort(g_real)
    g_real = g_real[order]
    old_real = old_ids[vmask][order]
    val_real = vals[vmask][order]
    act_real = act[vmask][order]

    # edges: translate (shard, pos) -> global id via the gid table
    sp = np.asarray(pg.src_pos)  # (n, n, E)
    dp = np.asarray(pg.dst_pos)
    w = np.asarray(pg.eweight)
    srcs, dsts, ws = [], [], []
    for i in range(n):
        for k in range(n):
            m = sp[i, k] >= 0
            srcs.append(gids[i, sp[i, k][m]])
            dsts.append(gids[k, dp[i, k][m]])
            ws.append(w[i, k][m])
    src_g = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
    dst_g = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
    w_g = np.concatenate(ws) if ws else np.zeros(0, np.float32)
    return g_real, old_real, val_real, act_real, src_g, dst_g, w_g


def repartition(
    pg: PartitionedGraph,
    values,
    active,
    n_new: int,
    edge_block: int | None = None,
    vertex_pad: int = 8,
):
    """Rebuild the layout for ``n_new`` shards, migrating live vertex state.

    Returns (pg', values', active')."""
    edge_block = edge_block or pg.edge_block
    g_real, old_real, val_real, act_real, src_g, dst_g, w_g = extract_global(
        pg, values, active
    )
    pg2 = build_partition(
        n_new, src_g, dst_g, w_g, g_real, old_real,
        edge_block=edge_block, vertex_pad=vertex_pad,
    )
    # migrate values/active by (g mod n', g // n')
    vals2 = np.zeros((n_new, pg2.P), dtype=val_real.dtype)
    act2 = np.zeros((n_new, pg2.P), dtype=bool)
    vals2[g_real % n_new, g_real // n_new] = val_real
    act2[g_real % n_new, g_real // n_new] = act_real
    return pg2, jnp.asarray(vals2), jnp.asarray(act2)


def simulate_failure_and_rescale(pg, values, active, lost_shard: int, n_new: int):
    """Drop one shard's *device* (its state survives via checkpoint/logs — see
    core.checkpoint) and continue on n_new shards. Used by the failure drill
    in tests: checkpoint -> lose shard -> recover rows -> repartition."""
    return repartition(pg, values, active, n_new)
