"""Vertex-centric programming API (the Pregel surface of the paper, §2.1).

A ``VertexProgram`` specifies, vectorized over the per-shard state array ``A``:

* ``init``     — superstep-0 values and active flags,
* ``message``  — the value a source vertex sends along an out-edge
                 (what ``compute(.)`` emits in the paper),
* ``apply``    — how a vertex digests its (combined) incoming messages and
                 votes to halt (the body of ``compute(.)``),
* ``combiner`` — the message combiner (paper §2.1); the recoded fast path
                 (paper §5) requires one, with identity element ``e0``.

Programs whose semantics need *message lists* (no combiner) run in ``basic``
mode, where ``apply_list`` receives destination-sorted message runs.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Combiner:
    """A commutative, associative combine with identity ``e0`` (paper §5
    requires the identity so A_r / A_s slots can be pre-filled)."""

    name: str
    e0: Any  # scalar identity, cast to the message dtype
    combine: Callable[[jax.Array, jax.Array], jax.Array]

    def identity(self, shape, dtype) -> jax.Array:
        return jnp.full(shape, self.e0, dtype=dtype)

    def scatter(self, target: jax.Array, idx: jax.Array, msgs: jax.Array) -> jax.Array:
        """Scatter-combine msgs into target at idx (the in-memory A_s/A_r path)."""
        if self.name == "sum":
            return target.at[idx].add(msgs)
        if self.name == "min":
            return target.at[idx].min(msgs)
        if self.name == "max":
            return target.at[idx].max(msgs)
        if self.name == "or":
            return target.at[idx].max(msgs)  # bool-as-int max == or
        raise ValueError(self.name)

    def reduce(self, x: jax.Array, axis: int = 0) -> jax.Array:
        """Reduce an array of stacked message buffers along ``axis``."""
        if self.name == "sum":
            return jnp.sum(x, axis)
        if self.name == "min":
            return jnp.min(x, axis)
        if self.name in ("max", "or"):
            return jnp.max(x, axis)
        raise ValueError(self.name)


SUM = Combiner("sum", 0, lambda a, b: a + b)
MIN = Combiner("min", jnp.inf, jnp.minimum)
MAX = Combiner("max", -jnp.inf, jnp.maximum)
IMIN = Combiner("min", 2**31 - 1, jnp.minimum)  # int messages
IMAX = Combiner("max", -(2**31), jnp.maximum)
OR = Combiner("or", 0, jnp.logical_or)


class VertexProgram:
    """Base class. Subclasses define the per-vertex behaviour, vectorized."""

    #: message combiner; required for mode="recoded"/"basic_sc".
    combiner: Combiner | None = None
    value_dtype: Any = jnp.float32
    msg_dtype: Any = jnp.float32
    #: kernels/edge_combine message kind for the Pallas backend
    #: ("div_deg" | "add_w" | "add_1" | "copy" | "deg" | None = jnp only)
    msg_kind: str | None = None

    # ---- superstep 0 -------------------------------------------------------
    def init(self, shard_ctx: "ShardContext") -> tuple[jax.Array, jax.Array]:
        """Return (values (P,), active (P,)) for this shard."""
        raise NotImplementedError

    # ---- scatter phase -----------------------------------------------------
    def message(
        self, value: jax.Array, degree: jax.Array, weight: jax.Array,
        step: jax.Array,
    ) -> jax.Array:
        """Message an active source vertex sends along one out-edge."""
        raise NotImplementedError

    # ---- gather/apply phase ------------------------------------------------
    def apply(
        self,
        value: jax.Array,
        degree: jax.Array,
        msg: jax.Array,
        has_msg: jax.Array,
        active: jax.Array,
        step: jax.Array,
        ctx: "ShardContext",
    ) -> tuple[jax.Array, jax.Array]:
        """Digest combined messages; return (new_value, new_active).

        ``new_active`` marks vertices that send messages next superstep.
        Vertices outside ``active | has_msg`` must keep their value (Pregel
        halted semantics); helpers below make that easy.
        """
        raise NotImplementedError

    # ---- message-list apply (non-combiner programs, paper §3.3.2) ----------
    def apply_list(
        self,
        value: jax.Array,
        degree: jax.Array,
        sorted_dst: jax.Array,  # (M,) destination positions, ascending;
        #                          P = "no message" sentinel (padding)
        sorted_msg: jax.Array,  # (M,) payloads, grouped by destination —
        #                          exactly the merge-sorted IMS of §3.3.2
        has_msg: jax.Array,
        active: jax.Array,
        step: jax.Array,
        ctx: "ShardContext",
    ) -> tuple[jax.Array, jax.Array]:
        """Digest *message lists* (algorithms with no combiner). The engine
        hands the destination-sorted message runs; segment helpers below
        turn them into per-vertex reductions that combiners can't express
        (e.g. counting distinct payloads)."""
        raise NotImplementedError

    # ---- optional aggregator (paper §2.1) ----------------------------------
    def aggregate(
        self, value: jax.Array, new_value: jax.Array, has_msg: jax.Array
    ) -> jax.Array | None:
        return None

    # fixed superstep budget (e.g. PageRank); None = run to quiescence
    num_supersteps: int | None = None


@jax.tree_util.register_dataclass
@dataclass
class ShardContext:
    """Per-shard slice of the state array A handed to programs."""

    shard: jax.Array  # scalar int32: this shard's index i
    n_shards: int = dataclasses.field(metadata=dict(static=True))
    n_vertices: int = dataclasses.field(metadata=dict(static=True))
    P: int = dataclasses.field(metadata=dict(static=True))
    degree: jax.Array = None  # (P,) int32
    vmask: jax.Array = None  # (P,) bool
    old_ids: jax.Array = None  # (P,) int64
    gids: jax.Array = None  # (P,) int64 recoded global id (-1 for holes)

    @property
    def new_ids(self) -> jax.Array:
        """Dense recoded global id of every position (n*pos + i at build time;
        stable across elastic repartitioning). Holes carry a large sentinel so
        min-label algorithms never pick them."""
        hole = jnp.asarray(2**31 - 1, self.gids.dtype)
        return jnp.where(self.gids >= 0, self.gids, hole)


def keep_halted(new_value, value, compute_mask):
    """Pregel halted semantics: untouched vertices keep their value."""
    return jnp.where(compute_mask, new_value, value)


# ---------------------------------------------------------------------------
# segment helpers over destination-sorted message runs (for apply_list)
# ---------------------------------------------------------------------------

def segment_count_distinct(sorted_dst, sorted_msg, P: int):
    """Per-destination count of DISTINCT payloads — the canonical
    not-expressible-with-a-combiner reduction. Inputs are the sorted IMS
    (runs grouped by dst; dst == P means padding). O(M) vector ops."""
    # secondary sort by payload within runs so duplicates are adjacent
    import jax.numpy as jnp
    from jax import lax

    d2, m2 = lax.sort((sorted_dst, sorted_msg), num_keys=2)
    valid = d2 < P
    first = jnp.concatenate([
        valid[:1],
        valid[1:] & ((d2[1:] != d2[:-1]) | (m2[1:] != m2[:-1])),
    ])
    return (
        jnp.zeros((P,), jnp.int32)
        .at[jnp.where(valid, d2, P)]
        .add(first.astype(jnp.int32), mode="drop")
    )


def segment_sum(sorted_dst, sorted_msg, P: int):
    import jax.numpy as jnp

    valid = sorted_dst < P
    return (
        jnp.zeros((P,), sorted_msg.dtype)
        .at[jnp.where(valid, sorted_dst, P)]
        .add(jnp.where(valid, sorted_msg, 0), mode="drop")
    )


def segment_second_min(sorted_dst, sorted_msg, P: int, sentinel):
    """Per-destination SECOND-smallest distinct payload (``sentinel`` where
    fewer than two distinct payloads arrived). Needs two ordered passes over
    the message list, so no single commutative combiner can express it —
    the other canonical apply_list-only reduction. O(M) vector ops."""
    import jax.numpy as jnp

    valid = sorted_dst < P
    big = jnp.asarray(sentinel, sorted_msg.dtype)
    idx = jnp.where(valid, sorted_dst, P)
    m1 = (
        jnp.full((P,), big)
        .at[idx]
        .min(jnp.where(valid, sorted_msg, big), mode="drop")
    )
    gt = valid & (sorted_msg > m1[jnp.clip(sorted_dst, 0, P - 1)])
    return (
        jnp.full((P,), big)
        .at[jnp.where(gt, sorted_dst, P)]
        .min(jnp.where(gt, sorted_msg, big), mode="drop")
    )
