"""Fault tolerance (paper §3.4).

Two mechanisms, both stream-native as in the paper:

1. **Checkpointing** — the vertex states (and edge streams, once, at job
   start) are backed up; every K supersteps the current state is saved. Files
   are written per shard (modelling per-machine local dumps backed by HDFS)
   with an atomic manifest rename, so a torn checkpoint is never visible.

2. **Message-log fast recovery** (Shen et al. [19], which the paper supports
   "straightforwardly" because OMSs already persist outgoing messages):
   with ``log_outgoing`` enabled, every shard logs its per-destination
   combined outgoing buffers ``A_s`` each superstep. When a single shard
   fails, *only that shard* recomputes: it reloads its checkpoint rows and
   replays supersteps forward, combining the peers' logged ``A_s(i→j)`` with
   its own locally-regenerated ``A_s(j→j)`` — surviving shards do no work.
   Logs are garbage-collected when a newer checkpoint lands, exactly the
   paper's "keep OMSs until a new checkpoint is written".
"""

from __future__ import annotations

import json
import os
import shutil
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import ShardContext, VertexProgram
from repro.graph.partition import PartitionedGraph


class Checkpointer:
    """Shard-file checkpoints with an atomic manifest."""

    def __init__(self, directory: str, every: int = 5, keep: int = 2):
        self.dir = directory
        self.every = every
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------------
    def maybe_save(self, step: int, values, active, meta=None):
        if self.every and step % self.every == 0:
            self.save(step, values, active, meta=meta)

    def save(self, step: int, values, active, meta=None):
        """``meta`` (JSON-able) is recorded in the manifest; the streamed
        engine passes the edge-stream store signature so recovery can refuse
        to restore vertex state against mismatched edge streams."""
        vals = np.asarray(values)
        act = np.asarray(active)
        tmp = os.path.join(self.dir, f".tmp-step-{step:06d}")
        final = os.path.join(self.dir, f"step-{step:06d}")
        os.makedirs(tmp, exist_ok=True)
        for i in range(vals.shape[0]):
            np.savez(os.path.join(tmp, f"shard-{i}.npz"),
                     values=vals[i], active=act[i])
        manifest = dict(step=step, n_shards=int(vals.shape[0]),
                        P=int(vals.shape[1]), dtype=str(vals.dtype),
                        meta=meta)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:06d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                out.append(int(name.split("-")[1]))
        return sorted(out)

    def latest(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, expected_meta=None):
        """Manifest-aware restore: when ``expected_meta`` is given and the
        checkpoint recorded a (non-null) meta, the two must match — a
        checkpoint written against different edge streams is unusable state,
        not a silent wrong answer."""
        step = step if step is not None else self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step-{step:06d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        got = manifest.get("meta")
        if expected_meta is not None and got is not None and got != expected_meta:
            raise ValueError(
                f"checkpoint step-{step:06d} was written against different "
                f"edge streams: manifest meta {got} != expected {expected_meta}"
            )
        vals, acts = [], []
        for i in range(manifest["n_shards"]):
            z = np.load(os.path.join(d, f"shard-{i}.npz"))
            vals.append(z["values"])
            acts.append(z["active"])
        return jnp.asarray(np.stack(vals)), jnp.asarray(np.stack(acts)), step

    def restore_shard(self, shard: int, step: int | None = None):
        step = step if step is not None else self.latest()
        d = os.path.join(self.dir, f"step-{step:06d}")
        z = np.load(os.path.join(d, f"shard-{shard}.npz"))
        return jnp.asarray(z["values"]), jnp.asarray(z["active"]), step


class MessageLog:
    """Per-superstep outgoing-message logs (the persisted OMSs of [19])."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, A_s_all, cnt_all):
        """A_s_all: (n_src, n_dest, P) combined outgoing buffers; cnt counts."""
        A = np.asarray(A_s_all)
        C = np.asarray(cnt_all)
        d = os.path.join(self.dir, f"step-{step:06d}")
        os.makedirs(d, exist_ok=True)
        for i in range(A.shape[0]):
            np.savez(os.path.join(d, f"shard-{i}.npz"), A_s=A[i], cnt=C[i])

    def load_for_dest(self, step: int, dest: int, n_shards: int, skip_shard: int):
        """Collect logged A_s(i→dest) from all surviving shards i != skip."""
        d = os.path.join(self.dir, f"step-{step:06d}")
        parts = []
        for i in range(n_shards):
            if i == skip_shard:
                continue
            z = np.load(os.path.join(d, f"shard-{i}.npz"))
            parts.append((z["A_s"][dest], z["cnt"][dest]))
        return parts

    def gc_before(self, step: int):
        """Paper §3.4: drop OMS logs once a newer checkpoint is durable."""
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step-") and int(name.split("-")[1]) < step:
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)


def recover_shard(
    pg: PartitionedGraph,
    program: VertexProgram,
    failed: int,
    ckpt: Checkpointer,
    log: MessageLog,
    target_step: int,
):
    """Message-log fast recovery of a single failed shard ([19] / paper §3.4).

    Re-executes supersteps ckpt..target for shard ``failed`` only. Incoming
    messages at step t = combine(peers' logged A_s(i→failed, t),
    locally regenerated A_s(failed→failed, t)).
    Returns (values_row, active_row) at ``target_step``.
    """
    # local imports to avoid a module cycle
    from repro.core.engine import _combine_scatter, _contrib_dense

    comb = program.combiner
    v_j, a_j, start = ckpt.restore_shard(failed)
    pg_j = jax.tree.map(lambda a: a[failed], pg)  # this shard's slice
    ctx = ShardContext(
        shard=jnp.int32(failed), n_shards=pg.n_shards,
        n_vertices=pg.n_vertices, P=pg.P,
        degree=pg_j.degree, vmask=pg_j.vmask, old_ids=pg_j.old_ids,
        gids=pg_j.gids,
    )

    @jax.jit
    def replay_step(v_j, a_j, peer_A, peer_cnt, step):
        own_A, own_cnt = _contrib_dense(
            program, pg_j, v_j, a_j, step, jnp.int32(failed), _combine_scatter
        )
        A_r, cnt = own_A, own_cnt
        for pA, pc in zip(peer_A, peer_cnt):
            A_r = comb.combine(A_r, pA)
            cnt = cnt + pc
        has_msg = (cnt > 0) & pg_j.vmask
        nv, na = program.apply(v_j, pg_j.degree, A_r, has_msg, a_j, step, ctx)
        return nv.astype(program.value_dtype), na & pg_j.vmask

    for t in range(start, target_step):
        parts = log.load_for_dest(t, failed, pg.n_shards, skip_shard=failed)
        peer_A = tuple(jnp.asarray(p[0]) for p in parts)
        peer_cnt = tuple(jnp.asarray(p[1]) for p in parts)
        v_j, a_j = replay_step(v_j, a_j, peer_A, peer_cnt, jnp.int32(t))
    return v_j, a_j
