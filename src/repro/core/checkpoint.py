"""Fault tolerance (paper §3.4).

Two mechanisms, both stream-native as in the paper:

1. **Checkpointing** — the vertex states (and edge streams, once, at job
   start) are backed up; every K supersteps the current state is saved. Files
   are written per shard (modelling per-machine local dumps backed by HDFS)
   with an atomic manifest rename, so a torn checkpoint is never visible.

2. **Message-log fast recovery** (Shen et al. [19], which the paper supports
   "straightforwardly" because OMSs already persist outgoing messages):
   with ``log_outgoing`` enabled, every shard logs its per-destination
   combined outgoing buffers ``A_s`` each superstep. When a single shard
   fails, *only that shard* recomputes: it reloads its checkpoint rows and
   replays supersteps forward, combining the peers' logged ``A_s(i→j)`` with
   its own locally-regenerated ``A_s(j→j)`` — surviving shards do no work.
   Logs are garbage-collected when a newer checkpoint lands, exactly the
   paper's "keep OMSs until a new checkpoint is written".
"""

from __future__ import annotations

import json
import os
import re
import shutil
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import ShardContext, VertexProgram
from repro.graph.partition import PartitionedGraph

_STEP_DIR = re.compile(r"^step-(\d+)$")


class Checkpointer:
    """Shard-file checkpoints with an atomic manifest."""

    def __init__(self, directory: str, every: int = 5, keep: int = 2):
        self.dir = directory
        self.every = every
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        # a crash between makedirs(tmp) and the atomic rename in save()
        # leaves a .tmp-step-* behind; sweep them so they can't pile up
        for name in os.listdir(directory):
            if name.startswith(".tmp-step-"):
                shutil.rmtree(os.path.join(directory, name),
                              ignore_errors=True)

    # -- write ---------------------------------------------------------------
    def maybe_save(self, step: int, values, active, meta=None) -> bool:
        """Save if ``step`` is on the cadence; True iff a checkpoint landed
        (the engine GCs message logs only after a durable save)."""
        if self.every and step % self.every == 0:
            self.save(step, values, active, meta=meta)
            return True
        return False

    def save(self, step: int, values, active, meta=None):
        """``meta`` (JSON-able) is recorded in the manifest; the streamed
        engine passes the edge-stream store signature so recovery can refuse
        to restore vertex state against mismatched edge streams."""
        vals = np.asarray(values)
        act = np.asarray(active)
        tmp = os.path.join(self.dir, f".tmp-step-{step:06d}")
        final = os.path.join(self.dir, f"step-{step:06d}")
        os.makedirs(tmp, exist_ok=True)
        for i in range(vals.shape[0]):
            np.savez(os.path.join(tmp, f"shard-{i}.npz"),
                     values=vals[i], active=act[i])
        manifest = dict(step=step, n_shards=int(vals.shape[0]),
                        P=int(vals.shape[1]), dtype=str(vals.dtype),
                        meta=meta)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())  # recovery trusts any step dir it can see;
            # the manifest must be durable before the rename publishes it
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:06d}"),
                          ignore_errors=True)

    # -- read ----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        """Published checkpoint steps; non-``step-NNNNNN`` entries (stray
        files, foreign directories, malformed names) are ignored rather than
        crashing every reader."""
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_DIR.match(name)
            if m and os.path.isdir(os.path.join(self.dir, name)):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, expected_meta=None):
        """Manifest-aware restore: when ``expected_meta`` is given and the
        checkpoint recorded a (non-null) meta, the two must match — a
        checkpoint written against different edge streams is unusable state,
        not a silent wrong answer."""
        step = step if step is not None else self.latest()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step-{step:06d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        got = manifest.get("meta")
        if expected_meta is not None and got is not None and got != expected_meta:
            raise ValueError(
                f"checkpoint step-{step:06d} was written against different "
                f"edge streams: manifest meta {got} != expected {expected_meta}"
            )
        vals, acts = [], []
        for i in range(manifest["n_shards"]):
            z = np.load(os.path.join(d, f"shard-{i}.npz"))
            vals.append(z["values"])
            acts.append(z["active"])
        return jnp.asarray(np.stack(vals)), jnp.asarray(np.stack(acts)), step

    def restore_shard(self, shard: int, step: int | None = None):
        step = step if step is not None else self.latest()
        d = os.path.join(self.dir, f"step-{step:06d}")
        z = np.load(os.path.join(d, f"shard-{shard}.npz"))
        return jnp.asarray(z["values"]), jnp.asarray(z["active"]), step


class MessageLog:
    """Per-superstep outgoing-message logs (the persisted OMSs of [19])."""

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, A_s_all, cnt_all):
        """A_s_all: (n_src, n_dest, P) combined outgoing buffers; cnt counts."""
        A = np.asarray(A_s_all)
        C = np.asarray(cnt_all)
        d = os.path.join(self.dir, f"step-{step:06d}")
        os.makedirs(d, exist_ok=True)
        for i in range(A.shape[0]):
            np.savez(os.path.join(d, f"shard-{i}.npz"), A_s=A[i], cnt=C[i])

    def load_for_dest(self, step: int, dest: int, n_shards: int, skip_shard: int):
        """Collect logged A_s(i→dest) from all surviving shards i != skip."""
        d = os.path.join(self.dir, f"step-{step:06d}")
        parts = []
        for i in range(n_shards):
            if i == skip_shard:
                continue
            z = np.load(os.path.join(d, f"shard-{i}.npz"))
            parts.append((z["A_s"][dest], z["cnt"][dest]))
        return parts

    def gc_before(self, step: int):
        """Paper §3.4: drop OMS logs once a newer checkpoint is durable."""
        for name in sorted(os.listdir(self.dir)):
            m = _STEP_DIR.match(name)
            if m and int(m.group(1)) < step:
                shutil.rmtree(os.path.join(self.dir, name), ignore_errors=True)


class RunFileMessageLog(MessageLog):
    """Message logs backed by the ``streams.msgstore`` run files — the
    persisted OMSs of the paper, usable by ``mode="streamed"`` because they
    are written *incrementally* (never materializing an (n, n, P) buffer).

    Two content shapes share one on-disk format (a ``MessageRunStore`` per
    superstep under ``step-NNNNNN/``):

    * combiner path: one sorted run per (src→dest) group holding the
      *combined* A_s as sparse ``(dst_pos, msg, cnt)`` triples, appended by
      :meth:`save_group` as the streamed fold finishes each group — or by
      the pipelined engine's channel sender, whose inbox store IS this
      log's per-step store (transmitted messages are persisted OMSs);
    * combiner-less path: the engine's raw OMS spill store for the superstep
      is simply created under this directory (``open_step``) — the runs the
      external merge consumes ARE the log, exactly §3.4's "keep OMSs on
      local disk until a new checkpoint is written".

    The engine calls :meth:`configure` with the program geometry; a log
    reopened for recovery reads it back from the per-step run indexes.
    """

    def __init__(self, directory: str):
        super().__init__(directory)
        self._n_shards = None
        self._P = None
        self._msg_dtype = None
        self._e0 = 0
        self._combined = True
        self._compress = False
        self._compress_payload = False
        self._open_stores: dict[int, "object"] = {}

    def configure(self, n_shards: int, P: int, msg_dtype, e0=0,
                  combined: bool = True, compress: bool = False,
                  compress_payload=False):
        self._n_shards = int(n_shards)
        self._P = int(P)
        self._msg_dtype = np.dtype(msg_dtype)
        self._e0 = e0
        self._combined = bool(combined)
        self._compress = bool(compress)
        self._compress_payload = compress_payload or False

    def step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step-{step:06d}")

    def open_step(self, step: int):
        """Fresh (truncated) per-step run store for the engine to spill
        into; re-running a crashed superstep starts its OMS over."""
        from repro.streams.msgstore import MessageRunStore

        store = MessageRunStore(
            self.step_dir(step), self._n_shards, self._P, self._msg_dtype,
            with_counts=self._combined, compress=self._compress,
            compress_payload=self._compress_payload,
        )
        self._open_stores[step] = store
        return store

    def _store_for(self, step: int):
        from repro.streams.msgstore import MessageRunStore

        store = self._open_stores.get(step)
        if store is None:
            store = MessageRunStore.open(self.step_dir(step))
            self._open_stores[step] = store
        return store

    # -- writes (combiner path) ----------------------------------------------
    def save_group(self, step: int, src: int, dest: int, A_s: np.ndarray,
                   cnt: np.ndarray):
        """Persist one combined outgoing buffer A_s(src→dest) as a sparse
        sorted run; positions with no messages are dropped (they are the
        combiner identity by construction)."""
        if self._n_shards is None:
            raise ValueError(
                "RunFileMessageLog is unconfigured; the engine calls "
                "configure() from its constructor — do the same before "
                "logging directly (the combiner identity e0 cannot be "
                "guessed: densifying with the wrong one corrupts recovery)"
            )
        store = self._open_stores.get(step)
        if store is None:
            store = self.open_step(step)
        store.append_combined(dest, A_s, cnt, tag=src)

    def save(self, step: int, A_s_all, cnt_all):
        """Compatibility with the in-memory logged driver: fan the dense
        (n_src, n_dest, P) buffers out into per-group runs."""
        A = np.asarray(A_s_all)
        C = np.asarray(cnt_all)
        for i in range(A.shape[0]):
            for k in range(A.shape[1]):
                self.save_group(step, i, k, A[i, k], C[i, k])
        self.close_step(step)  # publish the index once per superstep

    # -- reads ----------------------------------------------------------------
    def load_for_dest(self, step: int, dest: int, n_shards: int,
                      skip_shard: int):
        """Densify the logged runs back into (A_s, cnt) pairs per surviving
        source shard (groups the §3.2 skip() test pruned contributed the
        identity and simply have no run)."""
        store = self._store_for(step)
        if not store.with_counts:
            raise ValueError(
                "this log holds raw combiner-less OMS runs; dense (A_s, cnt)"
                " reads only apply to combined logs — recover with "
                "recover_shard_streamed, which merge-streams the runs"
            )
        e0 = self._e0 if self._e0 is not None else 0
        return [
            store.read_combined(dest, seg, e0)
            for seg in store.runs(dest) if seg.tag != skip_shard
        ]

    def close_step(self, step: int):
        """Publish the step's run index once (save_group defers it — a full
        JSON rewrite per group would be O(n²) redundant I/O per superstep),
        release the write handles, and forget the in-memory store — keeping
        one per superstep would grow host memory by O(|V|) ints per step.
        Later reads reopen lazily from the saved index."""
        store = self._open_stores.pop(step, None)
        if store is not None:
            store.save_index()
            store.close()

    def gc_before(self, step: int):
        for s in list(self._open_stores):
            if s < step:
                self._open_stores.pop(s).close()
        super().gc_before(step)


def recover_shard_streamed(
    pg: PartitionedGraph,
    program: VertexProgram,
    failed: int,
    ckpt: Checkpointer,
    log: RunFileMessageLog,
    store,  # streams.EdgeStreamStore
    target_step: int,
):
    """Single-shard fast recovery for ``mode="streamed"`` ([19] / §3.4).

    Only shard ``failed`` recomputes: its vertex rows reload from the latest
    checkpoint and supersteps replay forward. Incoming messages at step t
    are the peers' logged OMSs for destination ``failed`` plus the shard's
    own (failed→failed) contribution, regenerated by streaming that one edge
    group back off disk — survivors do no work and the edge streams of other
    groups are never read.

    Handles both program classes: with a combiner the logged runs are
    densified and combined; without one the peers' raw sorted runs are
    merge-streamed together with the regenerated own-messages runs through
    the same destination-aligned apply_list slicing the engine uses.
    """
    from repro.core.config import EngineConfig
    from repro.core.engine import GraphDEngine
    from repro.streams.msgstore import MessageRunStore

    eng = GraphDEngine(pg, program, config=EngineConfig(mode="streamed"),
                       stream_store=store, message_log=log)
    comb = program.combiner
    v_j, a_j, start = ckpt.restore_shard(failed)
    n, P = pg.n_shards, pg.P
    reader = eng._stream_reader

    for t in range(start, target_step):
        step = jnp.int32(t)
        prefix = np.concatenate(
            [[0], np.cumsum(np.asarray(a_j).astype(np.int64))]
        )
        own_ids = store.active_blocks(failed, failed, prefix)
        own_schedule = [(failed, failed, own_ids)] if own_ids.size else []
        if comb is not None:
            # regenerate the failed shard's own combined group A_s(j→j)
            # chunk-wise — exactly the live fold's sequence
            own_A = comb.identity((P,), program.msg_dtype)
            own_cnt = jnp.zeros((P,), jnp.int32)
            for chunk in reader.stream(own_schedule):
                own_A, own_cnt = eng._stream_fold(
                    own_A, own_cnt, v_j, pg.degree[failed], a_j,
                    chunk.sp, chunk.dp, chunk.w, step,
                )
                jax.block_until_ready(own_cnt)
            # digest peers' logged groups AND the regenerated own group in
            # ascending source order — the live engine's transmit order —
            # so replay is bit-identical even for float-SUM combiners
            # (reassociating the digest would legally drift the last ulp)
            store_t = log._store_for(t)
            parts = [
                (seg.tag, *(jnp.asarray(x) for x in
                            store_t.read_combined(failed, seg,
                                                  program.combiner.e0)))
                for seg in store_t.runs(failed) if seg.tag != failed
            ]
            parts.append((failed, own_A, own_cnt))
            parts.sort(key=lambda p: p[0])
            A_r = comb.identity((P,), program.msg_dtype)
            cnt = jnp.zeros((P,), jnp.int32)
            for _, pA, pc in parts:
                A_r = comb.combine(A_r, pA)
                cnt = cnt + pc
            v_j, a_j, _, _, _ = eng._stream_apply(
                v_j, pg.degree[failed], pg.vmask[failed], pg.old_ids[failed],
                pg.gids[failed], A_r, cnt, a_j, step, jnp.int32(failed),
            )
        else:
            # rebuild a merge-ready store: peers' logged runs (re-chunked —
            # chunking a sorted run yields sorted runs) + regenerated own
            logged = log._store_for(t)
            tmp = MessageRunStore(
                os.path.join(eng.msg_spill_dir, f"recover-{t:06d}"), n, P,
                np.dtype(program.msg_dtype),
            )
            try:
                peer_segs: dict[int, list] = {}
                for seg in logged.runs(failed):
                    if seg.tag != failed:
                        peer_segs.setdefault(seg.tag, []).append(seg)
                # rebuild in ascending source order — the live spill's run-
                # table order — so the k-way merge's equal-dp tie-breaking
                # (and with it any message-order-sensitive apply_list)
                # replays exactly like an uninterrupted run
                for tag in sorted(set(peer_segs) | {failed}):
                    if tag == failed:
                        # regenerated own messages, never trusted from disk
                        for chunk in reader.stream(own_schedule):
                            msg, dp, valid = eng._stream_msgs(
                                v_j, pg.degree[failed], a_j,
                                chunk.sp, chunk.dp, chunk.w, step,
                            )
                            msg, dp, valid = map(np.asarray,
                                                 (msg, dp, valid))
                            tmp.append_raw(failed, dp, msg, valid,
                                           tag=failed)
                    else:
                        # chunked copy (a chunk of a sorted run is a sorted
                        # run) keeps recovery at the same O(read_chunk)
                        # bound as normal execution even after compaction
                        # made peer runs O(messages-per-source) long
                        for seg in peer_segs[tag]:
                            for part in logged.iter_run(failed, seg,
                                                        eng.msg_read_chunk):
                                tmp.append_run(failed, part[0], part[1],
                                               tag=tag)
                    # re-collapse so the final merge holds one cursor per
                    # source, not one per copied chunk
                    tmp.compact_tag(failed, tag, eng.msg_merge_fanin,
                                    eng.msg_read_chunk)
                # identical merge/apply slicing as normal execution — shared
                # helper, so recovered results can never drift from a rerun
                v_j, a_j, _ = eng._apply_list_merged(
                    tmp, failed, v_j, a_j, step
                )
            finally:
                tmp.delete()
    return v_j, a_j


def recover_shard(
    pg: PartitionedGraph,
    program: VertexProgram,
    failed: int,
    ckpt: Checkpointer,
    log: MessageLog,
    target_step: int,
):
    """Message-log fast recovery of a single failed shard ([19] / paper §3.4).

    Re-executes supersteps ckpt..target for shard ``failed`` only. Incoming
    messages at step t = combine(peers' logged A_s(i→failed, t),
    locally regenerated A_s(failed→failed, t)).
    Returns (values_row, active_row) at ``target_step``.
    """
    # local imports to avoid a module cycle
    from repro.core.engine import _combine_scatter, _contrib_dense

    comb = program.combiner
    v_j, a_j, start = ckpt.restore_shard(failed)
    pg_j = jax.tree.map(lambda a: a[failed], pg)  # this shard's slice
    ctx = ShardContext(
        shard=jnp.int32(failed), n_shards=pg.n_shards,
        n_vertices=pg.n_vertices, P=pg.P,
        degree=pg_j.degree, vmask=pg_j.vmask, old_ids=pg_j.old_ids,
        gids=pg_j.gids,
    )

    @jax.jit
    def replay_step(v_j, a_j, peer_A, peer_cnt, step):
        own_A, own_cnt = _contrib_dense(
            program, pg_j, v_j, a_j, step, jnp.int32(failed), _combine_scatter
        )
        A_r, cnt = own_A, own_cnt
        for pA, pc in zip(peer_A, peer_cnt):
            A_r = comb.combine(A_r, pA)
            cnt = cnt + pc
        has_msg = (cnt > 0) & pg_j.vmask
        nv, na = program.apply(v_j, pg_j.degree, A_r, has_msg, a_j, step, ctx)
        return nv.astype(program.value_dtype), na & pg_j.vmask

    for t in range(start, target_step):
        parts = log.load_for_dest(t, failed, pg.n_shards, skip_shard=failed)
        peer_A = tuple(jnp.asarray(p[0]) for p in parts)
        peer_cnt = tuple(jnp.asarray(p[1]) for p in parts)
        v_j, a_j = replay_step(v_j, a_j, peer_A, peer_cnt, jnp.int32(t))
    return v_j, a_j
