"""`GraphDJob` — the one-call session facade over the full job lifecycle.

The paper's promise is "very large graphs on ordinary resources" without the
user hand-wiring the physical plan. Before this module an out-of-core run
took five manual steps (partition+spill, edge store, message log,
checkpointer, engine — each with its own knobs); now:

    from repro.core import GraphDJob, MemoryBudget, PageRank

    result = GraphDJob(
        PageRank(supersteps=10), graph,
        budget=MemoryBudget(ram_per_shard=64 << 10, n_shards=8),
        workdir="/data/job",
    ).run()

The job owns, under one ``workdir``:

* the plan (``core.plan.plan`` — or an explicit ``plan=`` for experts),
* the partition, spilling edge groups to ``workdir/edges`` automatically
  when the plan picked the out-of-core mode (``partition_for_plan``),
* the recovery wiring (``workdir/ckpt`` checkpoints + ``workdir/logs``
  message logs, built from the plan's RecoveryConfig),
* the engine, the superstep loop, single-shard fast recovery, and elastic
  rescaling (state migrates by original vertex id, so it works for every
  mode including vertex-only streamed partitions),

and returns a structured :class:`JobResult` carrying the final values, the
superstep history, and the realized-vs-planned memory model.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
from dataclasses import dataclass

import numpy as np

from repro.core.checkpoint import (
    Checkpointer, MessageLog, RunFileMessageLog, recover_shard,
    recover_shard_streamed,
)
from repro.core.config import RecoveryConfig, validate_launch_opts
from repro.core.engine import GraphDEngine, SuperstepRecord
from repro.core.plan import (
    ExecutionPlan, GraphMeta, MemoryBudget, plan as make_plan, ram_total,
)
from repro.graph.partition import partition_for_plan


@dataclass
class JobResult:
    """What a run produced, plus the audit trail: what was planned and what
    it actually cost. ``summary()`` is JSON-able for benchmarks/CI artifacts."""

    values: dict[int, object]  # {original vertex id: final value}
    history: list[SuperstepRecord]
    plan: ExecutionPlan
    realized_model: dict[str, int]
    realized_ram: int
    workdir: str

    @property
    def planned_ram(self) -> int:
        return self.plan.ram_total

    @property
    def n_supersteps(self) -> int:
        return len(self.history)

    def summary(self) -> dict:
        """JSON-able record of the run (values excluded — they are the
        payload, not the audit trail; ``values`` stays on the object)."""
        ratio = (self.planned_ram / self.realized_ram
                 if self.realized_ram else float("inf"))
        return dict(
            mode=self.plan.mode,
            pipeline=self.plan.pipeline,
            compress=self.plan.compress,
            n_shards=self.plan.n_shards,
            n_vertices=len(self.values),
            n_supersteps=self.n_supersteps,
            halted_at=self.history[-1].step if self.history else None,
            planned=dict(ram=self.planned_ram, model=self.plan.model),
            realized=dict(ram=self.realized_ram, model=self.realized_model),
            planned_over_realized_ram=ratio,
            # semi-external residency behavior, observable without a
            # profiler: disk reads vs hot-cache hits vs skip()-elided blocks
            residency=dict(
                cache_bytes=self.plan.config.stream.cache_bytes,
                blocks_read=sum(r.blocks_read for r in self.history),
                cache_hits=sum(r.cache_hits for r in self.history),
                cache_evictions=sum(r.cache_evictions
                                    for r in self.history),
                blocks_skipped=sum(r.blocks_skipped for r in self.history),
            ),
            history=[dataclasses.asdict(r) for r in self.history],
        )

    def to_json(self) -> str:
        return json.dumps(self.summary())


class GraphDJob:
    """Plan → partition → run → recover/rescale, one object, one workdir.

    ``budget`` drives the planner; pass ``plan=`` instead to pin an exact
    physical plan (mutually exclusive — a plan already embeds its budget).
    ``checkpoint_every`` overrides the plan's RecoveryConfig and turns on
    message logging, enabling :meth:`recover_shard`. Without a ``workdir``
    the job creates (and owns) a temporary one; use the job as a context
    manager or call :meth:`close` to release it.
    """

    def __init__(
        self,
        program,
        graph,
        *,
        budget: MemoryBudget | None = None,
        plan: ExecutionPlan | None = None,
        workdir: str | None = None,
        checkpoint_every: int | None = None,
        edge_block: int = 512,
        vertex_pad: int = 8,
        launch: str = "threads",
        launch_opts: dict | None = None,
    ):
        if plan is not None and budget is not None:
            raise ValueError(
                "pass budget= (to plan) or plan= (pre-planned), not both — "
                "an ExecutionPlan already embeds the budget it was made for"
            )
        if launch not in ("threads", "processes"):
            raise ValueError(
                f"launch must be 'threads' or 'processes', got {launch!r}"
            )
        self.program = program
        self.graph = graph
        self.launch = launch
        # launch_opts tunes the deployment, not the plan: the message
        # transport ("files" | "sockets"), net timeouts, the coordinator's
        # liveness clock, retry budgets and chaos schedules — the documented
        # surface of config.LAUNCH_OPT_FIELDS, validated here (and merged
        # over any opts the plan itself pinned, job args winning)
        self.launch_opts = validate_launch_opts(launch_opts, launch)
        # expert plans are materialized verbatim; only budget-derived plans
        # get their knobs re-derived against the realized geometry
        self._auto_planned = plan is None
        if plan is None:
            plan = make_plan(program, GraphMeta.of(graph), budget,
                             edge_block=edge_block, vertex_pad=vertex_pad,
                             launch=launch)
        elif launch == "processes" and plan.mode != "streamed":
            raise ValueError(
                "launch='processes' needs a mode='streamed' plan (workers "
                f"stream their owner view from disk); got mode={plan.mode!r}"
                " — re-plan with plan(..., launch='processes')"
            )
        if (launch == "processes"
                and plan.config.channel.payload_scheme == "auto"):
            # the auto-pick's first-superstep sample is engine-local state:
            # n worker processes would each decide independently and their
            # wire formats could diverge. Downgrade to the fixed lossless
            # codec (keeping compression!) instead of rejecting the plan —
            # the planner-layer resolution of the conflict that
            # EngineConfig.finalize()/run_processes raise ConfigError for.
            plan = dataclasses.replace(plan, config=dataclasses.replace(
                plan.config, channel=dataclasses.replace(
                    plan.config.channel, compress_payload="lossless"),
            ))
        if plan.launch_opts:
            # plan-pinned deployment knobs are defaults; job args override
            self.launch_opts = {**plan.launch_opts, **self.launch_opts}
        if checkpoint_every is not None:
            # message logging (=> single-shard fast recovery) needs either a
            # combined A_s log or the streamed OMS run files; a combiner-less
            # in-memory plan has neither, so it gets checkpoints only
            log_ok = (plan.mode == "streamed"
                      or program.combiner is not None)
            plan = dataclasses.replace(plan, config=dataclasses.replace(
                plan.config,
                recovery=RecoveryConfig(
                    checkpoint_every=checkpoint_every,
                    log_messages=checkpoint_every > 0 and log_ok,
                ),
            ))
            plan.config.finalize()
        self.plan = plan
        self.budget = plan.budget
        self._tmp = workdir is None
        self.workdir = workdir or tempfile.mkdtemp(prefix="graphd-job-")
        os.makedirs(self.workdir, exist_ok=True)
        self._guard_workdir_identity()
        self._state = None  # (values, active) after a run / rescale
        self._next_step = 0
        self._closed = False
        try:
            self._build(tag="")
        except BaseException:
            # a failure between partition-spill and engine wiring must not
            # strand the workdir the job itself created: mark the job closed
            # and drop the temp dir (an explicit user workdir is kept, with
            # whatever partial spill is in it, for post-mortem)
            self._closed = True
            if self._tmp:
                shutil.rmtree(self.workdir, ignore_errors=True)
            raise

    def _guard_workdir_identity(self) -> None:
        """A reused workdir may hold another job's checkpoints; silently
        restoring them would hand this program a different program's state.
        The identity file pins (program, graph); a mismatch is an error, a
        match means resume is intended."""
        ident = dict(
            program=type(self.program).__name__,
            value_dtype=str(np.dtype(self.program.value_dtype)),
            n_vertices=self.plan.meta.n_vertices,
            n_edges=self.plan.meta.n_edges,
        )
        path = os.path.join(self.workdir, "job.json")
        if os.path.exists(path):
            with open(path) as f:
                existing = json.load(f)
            if existing != ident:
                raise ValueError(
                    f"workdir {self.workdir!r} belongs to a different job "
                    f"({existing}) than this one ({ident}); its checkpoints "
                    "would be restored as this program's state — use a "
                    "fresh workdir (or delete the old one)"
                )
        else:
            with open(path, "w") as f:
                json.dump(ident, f)

    # -- wiring ---------------------------------------------------------------
    def _dir(self, name: str, tag: str) -> str:
        return os.path.join(self.workdir, name + tag)

    def _build(self, tag: str) -> None:
        """Partition (spilling if planned) and wire store/log/ckpt/engine
        under ``workdir``; ``tag`` namespaces the layout after a rescale (the
        shard count changed, so checkpoints/logs/streams are a new lineage)."""
        self._tag = tag
        plan = self.plan
        self.pg, self.rmap, self.store = partition_for_plan(
            self.graph, plan, self._dir("edges", tag)
        )
        plan = self.plan = self._refine_plan(plan)
        rec = plan.config.recovery
        self.checkpointer = (
            Checkpointer(self._dir("ckpt", tag), every=rec.checkpoint_every,
                         keep=rec.keep)
            if rec.checkpoint_every else None
        )
        if rec.log_messages:
            if plan.mode != "streamed" and self.program.combiner is None:
                raise ValueError(
                    "recovery.log_messages needs combined A_s buffers (a "
                    "program combiner) or the streamed OMS tier; a "
                    "combiner-less in-memory plan has neither — tighten the "
                    "budget so the plan goes streamed, or drop log_messages "
                    "(checkpoint-only restarts still work)"
                )
            log_dir = self._dir("logs", tag)
            self.message_log = (RunFileMessageLog(log_dir)
                                if plan.mode == "streamed"
                                else MessageLog(log_dir))
        else:
            self.message_log = None
        self.engine = GraphDEngine(
            self.pg, self.program, config=plan.config,
            stream_store=self.store, message_log=self.message_log,
        )

    def _refine_plan(self, plan: ExecutionPlan) -> ExecutionPlan:
        """Re-run the knob ladder against the REALIZED partition geometry.

        The pre-partition plan estimates P as ceil(|V|/n); the hash
        partition's imbalance can realize a bigger max shard, and a ladder
        that spent the whole budget on optional knobs (batch lanes, the
        full-duplex receiver staging) against the estimate would overshoot
        it in realized bytes. Planning again with ``GraphMeta.of(pg)`` (the
        exact P rides along) re-derives the knobs the budget actually
        affords. Only adopted when the physical layout already on disk
        still matches (same mode/pipeline/codecs — the spill happened under
        the original plan); an infeasibility against the exact geometry
        falls back to the original best-effort plan."""
        from repro.core.plan import PlanInfeasible

        b = plan.budget
        if not self._auto_planned or plan.mode != "streamed" or (
            b.ram_per_shard is None and b.disk_per_shard is None
            and b.net_per_superstep is None
        ):
            return plan
        try:
            refined = make_plan(
                self.program, GraphMeta.of(self.pg), b,
                edge_block=plan.edge_block, vertex_pad=plan.vertex_pad,
                recovery=plan.config.recovery, launch=self.launch,
            )
        except PlanInfeasible:
            return plan
        same_layout = (
            refined.mode == plan.mode
            and refined.pipeline == plan.pipeline
            and refined.compress == plan.compress
            and bool(refined.compress_payload) == bool(plan.compress_payload)
        )
        return refined if same_layout else plan

    # -- lifecycle ------------------------------------------------------------
    def run(self, max_supersteps: int = 10_000, *,
            verbose: bool = False, on_step=None) -> JobResult:
        """Run (or continue, after :meth:`rescale`) to completion and return
        the structured result. With recovery enabled a step-0 checkpoint is
        saved before the first superstep so single-shard recovery always has
        a base to replay from. Re-running a job whose workdir already holds
        a finished run's checkpoint is a RESUME: the state restores and the
        result carries zero new supersteps (the identity file written at
        construction guards against resuming a different job's state)."""
        self._check_open()
        if (self.checkpointer is not None and self._state is None
                and self.checkpointer.latest() is None):
            meta = (self.store.signature()
                    if self.store is not None else None)
            self.checkpointer.save(0, *self.engine.init(), meta=meta)
        try:
            if self.launch == "processes":
                from repro.launch.procs import run_processes

                (values, active), history = run_processes(
                    self, max_supersteps, verbose=verbose, on_step=on_step,
                )
            else:
                (values, active), history = self.engine.run(
                    max_supersteps=max_supersteps, state=self._state,
                    start_step=self._next_step, verbose=verbose,
                    checkpointer=self.checkpointer, on_step=on_step,
                )
        finally:
            # success or failure, leave no half-written superstep scratch
            # (inbox runs, OMS spills, outbox/announce records) behind
            self._sweep_scratch()
        self._state = (values, active)
        if history:
            self._next_step = history[-1].step + 1
        realized = self.engine.memory_model()
        return JobResult(
            values=self.engine.gather_values(values),
            history=history,
            plan=self.plan,
            realized_model=realized,
            realized_ram=ram_total(realized, self.plan.mode),
            workdir=self.workdir,
        )

    def recover_shard(self, failed: int, target_step: int | None = None):
        """Single-shard fast recovery ([19]/§3.4): only ``failed`` recomputes
        from the latest checkpoint + peers' logged messages. Returns that
        shard's ``(values_row, active_row)`` at ``target_step`` (default: the
        last completed superstep)."""
        self._check_open()
        if self.checkpointer is None or self.message_log is None:
            raise RuntimeError(
                "recovery needs checkpoints + message logs: construct the "
                "job with checkpoint_every= (or a RecoveryConfig on the "
                "plan) before run()"
            )
        target = self._next_step if target_step is None else target_step
        if self.plan.mode == "streamed":
            log = self.message_log
            if self.launch == "processes":
                # each worker process logs into its own lineage
                # (logs/shard-w) — one run-file index per writer. The failed
                # shard's log holds every run addressed to it (its own
                # included: the transport routes w→w through the outbox
                # too), so replay reads just that lineage
                comb = self.program.combiner
                ch = self.plan.config.channel
                log = RunFileMessageLog(
                    os.path.join(self._dir("logs", self._tag),
                                 f"shard-{failed}"))
                log.configure(
                    self.pg.n_shards, self.pg.P,
                    np.dtype(self.program.msg_dtype),
                    e0=comb.e0 if comb is not None else 0,
                    combined=comb is not None, compress=ch.compress,
                    compress_payload=ch.compress_payload,
                )
            return recover_shard_streamed(
                self.pg, self.program, failed, self.checkpointer,
                log, self.store, target,
            )
        return recover_shard(self.pg, self.program, failed,
                             self.checkpointer, self.message_log, target)

    def rescale(self, n_shards: int) -> "GraphDJob":
        """Elastic rescale: re-plan for ``n_shards`` under the same budget,
        rebuild the physical layout (respilling edge streams when streamed),
        and migrate live vertex state by original id — works for every mode,
        including vertex-only spilled partitions. The job then continues
        from the same superstep: ``job.rescale(12).run()``."""
        self._check_open()
        if self._state is None:
            raise RuntimeError("rescale() needs a prior run(): no live state")
        old_vals = np.asarray(self._state[0])
        old_act = np.asarray(self._state[1])
        vmask = np.asarray(self.pg.vmask)
        old_ids = np.asarray(self.pg.old_ids)[vmask]
        vals_real = old_vals[vmask]
        act_real = old_act[vmask]

        self.plan = make_plan(
            self.program, GraphMeta.of(self.graph),
            dataclasses.replace(self.budget, n_shards=n_shards),
            edge_block=self.plan.edge_block,
            vertex_pad=self.plan.vertex_pad,
            recovery=self.plan.config.recovery,
            launch=self.launch,
        )
        self.budget = self.plan.budget
        self._build(tag=f"-n{n_shards}")
        # migrate by original id: the new recode map decides (shard, pos)
        g_new = np.asarray(self.rmap.to_new(old_ids))
        import jax.numpy as jnp

        vals2 = np.zeros((n_shards, self.pg.P), dtype=old_vals.dtype)
        act2 = np.zeros((n_shards, self.pg.P), dtype=bool)
        vals2[g_new % n_shards, g_new // n_shards] = vals_real
        act2[g_new % n_shards, g_new // n_shards] = act_real
        self._state = (jnp.asarray(vals2), jnp.asarray(act2))
        # seed the new lineage with the migrated state: recovery replays
        # from the latest checkpoint, and the rescaled ckpt dir would
        # otherwise stay empty until a cadence boundary happens to be
        # crossed — recover_shard() right after a rescale must still work
        if self.checkpointer is not None:
            meta = self.store.signature() if self.store is not None else None
            self.checkpointer.save(self._next_step, *self._state, meta=meta)
        return self

    # -- teardown -------------------------------------------------------------
    def _sweep_scratch(self) -> None:
        """Drop per-superstep scratch (NOT checkpoints, logs, or streams):
        the engine's inbox/OMS step dirs and the multi-process transport's
        outbox/announce/per-worker-inbox dirs. Run on both the success and
        the failure path so a crash mid-superstep cannot strand half-written
        run files in a user-owned workdir."""
        eng = getattr(self, "engine", None)
        for d in (getattr(eng, "_inbox_dir", None),
                  getattr(eng, "msg_spill_dir", None)):
            if d and os.path.isdir(d):
                for name in os.listdir(d):
                    if name.startswith(("step-", "recover-")):
                        shutil.rmtree(os.path.join(d, name),
                                      ignore_errors=True)
        procs_dir = self._dir("procs", getattr(self, "_tag", ""))
        if os.path.isdir(procs_dir):
            # live control plane of the finished launch: exchange dirs, the
            # coordinator WAL, its address record, and recover/abort
            # requests. Post-mortem artifacts survive until the NEXT run's
            # pre-spawn sweep: failure-summary.json, failures/, coord.log,
            # and quarantined (.quarantine) stores stay readable after a
            # failed run returns.
            for sub in ("outbox", "announce", "coord-wal"):
                shutil.rmtree(os.path.join(procs_dir, sub),
                              ignore_errors=True)
            for name in os.listdir(procs_dir):
                if name.startswith("shard-"):
                    for sub in ("inbox", "outbox"):
                        shutil.rmtree(os.path.join(procs_dir, name, sub),
                                      ignore_errors=True)
                elif (name.startswith("recover-")
                      or name in ("coord-addr.json", "abort-request.json")):
                    try:
                        os.unlink(os.path.join(procs_dir, name))
                    except OSError:
                        pass

    def close(self, delete: bool | None = None) -> None:
        """Release the workdir. ``delete`` defaults to True only when the
        job created a temporary one; an explicit user workdir is kept."""
        if self._closed:
            return
        self._closed = True
        if delete if delete is not None else self._tmp:
            shutil.rmtree(self.workdir, ignore_errors=True)
        else:
            self._sweep_scratch()

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError("job is closed (workdir released)")

    def __enter__(self) -> "GraphDJob":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
