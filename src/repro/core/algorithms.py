"""The paper's evaluated Pregel algorithms (§6) plus extras, as VertexPrograms.

* PageRank   — Tables 2–4 (dense workload, SUM combiner, fixed supersteps)
* Hash-Min   — Tables 5–6 (connected components, shrinking workload, MIN)
* SSSP / BFS — Tables 7–8 (sparse frontier, the skip() stress case, MIN)
* DegreeSum / LabelSpread — extra coverage for MAX/SUM semantics
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.api import IMIN, MIN, SUM, ShardContext, VertexProgram, keep_halted


class PageRank(VertexProgram):
    """a(v) = 0.15/|V| + 0.85 * sum(messages); msg = a(v)/d(v) (paper §2.1).

    Runs a fixed number of supersteps like the paper's experiments
    (10 on WebUK/Twitter, 5 on ClueWeb)."""

    combiner = SUM
    value_dtype = jnp.float32
    msg_dtype = jnp.float32
    msg_kind = "div_deg"

    def __init__(self, supersteps: int = 10, damping: float = 0.85):
        self.num_supersteps = supersteps
        self.damping = damping

    def init(self, ctx: ShardContext):
        v = jnp.full((ctx.P,), 1.0 / ctx.n_vertices, jnp.float32)
        return v, jnp.ones((ctx.P,), bool)

    def message(self, value, degree, weight, step):
        return value / jnp.maximum(degree, 1).astype(jnp.float32)

    def apply(self, value, degree, msg, has_msg, active, step, ctx):
        n = ctx.n_vertices
        new = 0.15 / n + self.damping * msg
        # every vertex recomputes each superstep (dense workload)
        new_active = jnp.full_like(active, step + 1 < self.num_supersteps)
        return new, new_active

    def aggregate(self, value, new_value, has_msg):
        return jnp.abs(new_value - value)  # L1 delta (convergence monitor)


class HashMin(VertexProgram):
    """Connected components by min-label flooding (Yan et al. [23]).

    Label = recoded vertex id; every vertex starts active broadcasting its
    label; a vertex re-broadcasts only when its label shrinks."""

    combiner = IMIN
    value_dtype = jnp.int32
    msg_dtype = jnp.int32
    msg_kind = "copy"
    num_supersteps = None

    def init(self, ctx: ShardContext):
        return ctx.new_ids.astype(jnp.int32), jnp.ones((ctx.P,), bool)

    def message(self, value, degree, weight, step):
        return value

    def apply(self, value, degree, msg, has_msg, active, step, ctx):
        compute = active | has_msg
        cand = jnp.where(has_msg, jnp.minimum(value, msg), value)
        new = keep_halted(cand, value, compute)
        return new, new < value  # re-broadcast iff label shrank


class SSSP(VertexProgram):
    """Single-source shortest paths; BFS when all weights are 1 (paper §6).

    The most challenging workload for out-of-core systems: the frontier is a
    thin slice of V each superstep, which is what skip() (§3.2) exists for."""

    combiner = MIN
    value_dtype = jnp.float32
    msg_dtype = jnp.float32
    msg_kind = "add_w"
    num_supersteps = None

    def __init__(self, source_new_id: int):
        # source is identified by its *recoded* id (n*pos + shard)
        self.source = source_new_id

    def init(self, ctx: ShardContext):
        dist = jnp.where(
            ctx.new_ids == self.source, 0.0, jnp.inf
        ).astype(jnp.float32)
        return dist, ctx.new_ids == self.source

    def message(self, value, degree, weight, step):
        return value + weight

    def apply(self, value, degree, msg, has_msg, active, step, ctx):
        cand = jnp.where(has_msg, jnp.minimum(value, msg), value)
        return cand, cand < value  # moved vertices enter the frontier


class BFS(SSSP):
    """BFS levels = SSSP over unit weights (paper runs SSSP with weight 1)."""

    msg_kind = "add_1"

    def message(self, value, degree, weight, step):
        return value + 1.0


class DegreeSum(VertexProgram):
    """Each vertex computes the sum of its in-neighbours' out-degrees.
    One-superstep sanity algorithm exercising SUM over int-ish floats."""

    combiner = SUM
    value_dtype = jnp.float32
    msg_dtype = jnp.float32
    msg_kind = "deg"
    num_supersteps = 1

    def init(self, ctx: ShardContext):
        return jnp.zeros((ctx.P,), jnp.float32), jnp.ones((ctx.P,), bool)

    def message(self, value, degree, weight, step):
        return degree.astype(jnp.float32)

    def apply(self, value, degree, msg, has_msg, active, step, ctx):
        return jnp.where(has_msg, msg, 0.0), jnp.zeros_like(active)


class DistinctInLabels(VertexProgram):
    """Count DISTINCT labels among in-neighbours — the canonical reduction
    a message combiner cannot express (paper §3.3: algorithms without
    combiners run on the sorted IMS / message-list path).

    Superstep 0: every vertex broadcasts its community label (here: its
    recoded id modulo `n_groups`). Superstep 1: each vertex counts distinct
    incoming labels via the destination-sorted message runs. With
    ``rounds > 1`` the distinct count becomes the next round's label and
    every vertex re-broadcasts — a multi-superstep combiner-less workload
    (exercises per-superstep OMS spill + gc in the streamed engine)."""

    combiner = None  # forces the message-list path (basic / streamed OMS)
    value_dtype = jnp.int32
    msg_dtype = jnp.int32

    def __init__(self, n_groups: int = 16, rounds: int = 1):
        self.n_groups = n_groups
        self.num_supersteps = rounds

    def init(self, ctx: ShardContext):
        labels = (ctx.new_ids % self.n_groups).astype(jnp.int32)
        return labels, jnp.ones((ctx.P,), bool)

    def message(self, value, degree, weight, step):
        return value

    def apply_list(self, value, degree, sorted_dst, sorted_msg, has_msg,
                   active, step, ctx):
        from repro.core.api import segment_count_distinct

        distinct = segment_count_distinct(sorted_dst, sorted_msg, ctx.P)
        new_active = jnp.full_like(active, step + 1 < self.num_supersteps)
        return distinct, new_active


class SecondMinLabel(VertexProgram):
    """Second-smallest DISTINCT incoming label (SENTINEL when fewer than two
    arrive). Needs two ordered passes over each vertex's message list, so no
    single combiner expresses it — a second combiner-less workload for the
    OMS/IMS message-list path."""

    combiner = None
    value_dtype = jnp.int32
    msg_dtype = jnp.int32
    num_supersteps = 1
    SENTINEL = 2**31 - 1

    def init(self, ctx: ShardContext):
        return ctx.new_ids.astype(jnp.int32), jnp.ones((ctx.P,), bool)

    def message(self, value, degree, weight, step):
        return value

    def apply_list(self, value, degree, sorted_dst, sorted_msg, has_msg,
                   active, step, ctx):
        from repro.core.api import segment_second_min

        m2 = segment_second_min(sorted_dst, sorted_msg, ctx.P, self.SENTINEL)
        return jnp.where(has_msg, m2, self.SENTINEL), jnp.zeros_like(active)


class LabelSpread(VertexProgram):
    """Max-label flooding (HashMin dual) — exercises the MAX semiring."""

    from repro.core.api import IMAX as _imax

    combiner = _imax
    value_dtype = jnp.int32
    msg_dtype = jnp.int32
    num_supersteps = None

    def init(self, ctx: ShardContext):
        return ctx.new_ids.astype(jnp.int32), jnp.ones((ctx.P,), bool)

    def message(self, value, degree, weight, step):
        return value

    def apply(self, value, degree, msg, has_msg, active, step, ctx):
        compute = active | has_msg
        cand = jnp.where(has_msg, jnp.maximum(value, msg), value)
        new = keep_halted(cand, value, compute)
        return new, new > value
