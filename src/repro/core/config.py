"""Typed engine configuration (the declarative half of the job API).

``GraphDEngine`` grew ~20 mode-dependent keyword arguments with their
validation scattered through ``__init__``. This module replaces that surface
with small dataclasses that *own* their validation:

* :class:`StreamConfig`       — the out-of-core edge tier (reader staging),
* :class:`MessageSpillConfig` — the combiner-less OMS tier (merge windows),
* :class:`ChannelConfig`      — the §4 sender pipeline (overlap/compression),
* :class:`RecoveryConfig`     — checkpoint cadence + message logging policy
  (consumed by :class:`repro.core.job.GraphDJob`, which owns the lifecycle),

composed into one :class:`EngineConfig`. Field-local checks live in each
``validate()``; cross-config invariants (e.g. "pipeline is a streamed-mode
knob") live in :meth:`EngineConfig.finalize`, which every consumer calls
before use. Checks that need the *program* or the *partition* (combiner
requirements, store geometry) stay in the engine — a config cannot know them.

The legacy ``GraphDEngine(pg, prog, mode=..., stream_chunk_blocks=..., ...)``
flat-kwarg surface is gone: its one-release deprecation window (PR 4) is
over, and ``GraphDEngine`` now raises :class:`ConfigError` for any flat
kwarg or positional mode string. Build an :class:`EngineConfig`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

#: engine execution modes (mirrors GraphDEngine.MODES; kept here so configs
#: can validate without importing the engine)
MODES = ("recoded", "recoded_compact", "basic", "basic_sc", "streamed")


class ConfigError(ValueError):
    """A config field (or a combination of fields) is invalid."""


#: documented ``launch_opts`` surface for ``launch="processes"``. One row per
#: key: (default, doc). Timeouts/poll intervals are seconds. Everything here
#: used to be a hard-coded constant in ``launch/net.py``; promoting the knobs
#: lets chaos drills and slow CI machines tune them without editing source.
LAUNCH_OPT_FIELDS = {
    "transport": ("files", "message exchange: 'files' (shared-FS run files) "
                  "or 'sockets' (PR 8 TCP transport)"),
    "heartbeat_interval": (0.25, "worker heartbeat cadence"),
    "heartbeat_timeout": (10.0, "heartbeat silence before a worker is "
                          "presumed dead (> heartbeat_interval)"),
    "handshake_timeout": (5.0, "socket timeout on HELLO/CHELLO handshakes "
                          "(PeerServer accept + CoordServer serve)"),
    "connect_timeout": (5.0, "per-attempt peer data-socket connect timeout"),
    "send_timeout": (60.0, "blocking-send cap on established data sockets"),
    "coord_connect_timeout": (10.0, "per-attempt worker -> coordinator "
                              "connect timeout"),
    "retry": (None, "RetryPolicy overrides for every reconnect/respawn path:"
              " dict of max_attempts/base_delay/max_delay/deadline/jitter/"
              "seed (see repro.fault.RetryPolicy)"),
    "faults": (None, "deterministic chaos schedule: {'seed': int, 'events': "
               "[...]} (see repro.fault.FaultSchedule); disarmed on respawn"),
    "coord_restart_limit": (3, "max coordinator respawns before the launcher "
                            "aborts the run (sockets transport)"),
    "coord_kill": (None, "drill: SIGKILL the coordinator process mid-barrier "
                   "at {'step': s[, 'after_arrivals': m]} (sockets "
                   "transport; fires in incarnation 0 only)"),
    "kill": (None, "drill: SIGKILL a worker whole-process at "
             "{'shard': w, 'step': s} (files transport)"),
    "kill_net": (None, "deprecated alias for a faults= net.send torn_kill "
                 "event: {'shard': w, 'step': s, 'after_frames': k} "
                 "(sockets transport)"),
}


def validate_launch_opts(opts: dict | None, launch: str = "processes") -> dict:
    """Validate a ``launch_opts`` dict against the documented surface.

    Returns a shallow copy. Unknown keys, wrong types, and incoherent
    combinations raise :class:`ConfigError` *at job construction* — not ten
    minutes into a multi-process launch. Sub-structures (``retry``,
    ``faults``) are validated by constructing their ``repro.fault`` types.
    """
    opts = dict(opts or {})
    if not opts:
        return opts
    if launch != "processes":
        raise ConfigError(
            f"launch_opts apply to launch='processes' (got launch={launch!r})"
        )
    unknown = set(opts) - set(LAUNCH_OPT_FIELDS)
    if unknown:
        raise ConfigError(
            f"unknown launch_opts keys {sorted(unknown)}; known: "
            f"{sorted(LAUNCH_OPT_FIELDS)}"
        )
    transport = opts.get("transport", "files")
    if transport not in ("files", "sockets"):
        raise ConfigError(
            f"launch_opts['transport'] must be 'files' or 'sockets', "
            f"got {transport!r}"
        )
    for key in ("heartbeat_interval", "heartbeat_timeout", "handshake_timeout",
                "connect_timeout", "send_timeout", "coord_connect_timeout"):
        if key in opts:
            try:
                val = float(opts[key])
            except (TypeError, ValueError):
                raise ConfigError(
                    f"launch_opts[{key!r}] must be seconds (a number), "
                    f"got {opts[key]!r}"
                ) from None
            if val <= 0:
                raise ConfigError(f"launch_opts[{key!r}] must be > 0 seconds")
            opts[key] = val
    hb_i = opts.get("heartbeat_interval", LAUNCH_OPT_FIELDS["heartbeat_interval"][0])
    hb_t = opts.get("heartbeat_timeout", LAUNCH_OPT_FIELDS["heartbeat_timeout"][0])
    if hb_t <= hb_i:
        raise ConfigError(
            f"launch_opts['heartbeat_timeout'] ({hb_t}) must exceed "
            f"heartbeat_interval ({hb_i}) or every worker looks dead"
        )
    if "coord_restart_limit" in opts:
        if not isinstance(opts["coord_restart_limit"], int) or \
                opts["coord_restart_limit"] < 0:
            raise ConfigError(
                "launch_opts['coord_restart_limit'] must be an int >= 0"
            )
    if opts.get("retry") is not None:
        from repro.fault import RetryPolicy

        try:
            RetryPolicy.from_opts(opts["retry"])
        except (TypeError, ValueError) as e:
            raise ConfigError(f"launch_opts['retry']: {e}") from None
    if opts.get("faults") is not None:
        from repro.fault import FaultSchedule

        try:
            FaultSchedule.from_opts(opts["faults"])
        except (TypeError, ValueError) as e:
            raise ConfigError(f"launch_opts['faults']: {e}") from None
    for drill, need in (("kill", "files"), ("kill_net", "sockets"),
                        ("coord_kill", "sockets")):
        if opts.get(drill) is not None and transport != need:
            raise ConfigError(
                f"launch_opts[{drill!r}] is a {need}-transport drill "
                f"(transport={transport!r})"
            )
    if opts.get("coord_kill") is not None:
        ck = opts["coord_kill"]
        if not isinstance(ck, dict) or "step" not in ck or \
                set(ck) - {"step", "after_arrivals"}:
            raise ConfigError(
                "launch_opts['coord_kill'] must be "
                "{'step': s[, 'after_arrivals': m]}"
            )
    return opts


@dataclass
class StreamConfig:
    """Out-of-core edge tier: the prefetching reader's staging pool.

    RAM cost: ``(depth + 1) * chunk_blocks * edge_block`` staged slots —
    a compiled-in constant, never O(|E|).
    """

    chunk_blocks: int = 8  # edge blocks staged per chunk
    depth: int = 2  # prefetch depth (2 = double buffering)
    # small destination groups (<= one staged chunk) are folded in padded
    # multi-group jitted dispatches of this many lanes, amortizing the
    # Python/dispatch overhead on graphs with many small groups; 1 disables
    group_batch: int = 4
    # adaptive semi-external tier (streams/residency.py): per-shard byte
    # budget for pinning hot edge blocks in RAM; 0 = pure streaming. The
    # planner sizes this from the MemoryBudget's leftover RAM (the
    # ``hot_cache`` tier of estimate_memory()); results are bit-identical
    # at any budget — the cache changes where a block is read FROM, never
    # what is computed
    cache_bytes: int = 0

    def validate(self) -> None:
        if self.chunk_blocks < 1:
            raise ConfigError("stream.chunk_blocks must be >= 1")
        if self.depth < 1:
            raise ConfigError("stream.depth must be >= 1 (2 = double buffering)")
        if self.group_batch < 1:
            raise ConfigError("stream.group_batch must be >= 1 (1 disables)")
        if self.cache_bytes < 0:
            raise ConfigError("stream.cache_bytes must be >= 0 (0 disables)")


@dataclass
class MessageSpillConfig:
    """Combiner-less OMS tier (§3.3.1): merge-window and apply-slice sizing.

    RAM cost: ``max(merge_fanin, n_shards) * read_chunk`` merge-cursor slots
    plus one ``slice_cap`` apply slice — the dominant term of the measured
    combiner-less ceiling, which the planner now sizes from the budget
    instead of these compiled-in defaults.
    """

    slice_cap: int = 4096  # messages per destination-aligned apply slice
    read_chunk: int = 4096  # messages staged per merge-cursor refill
    merge_fanin: int = 16  # max runs held open by the external merge
    spill_dir: str | None = None  # OMS spill dir (default: <store>/oms)

    def validate(self) -> None:
        if self.slice_cap < 1 or self.read_chunk < 1:
            raise ConfigError(
                "spill.slice_cap and spill.read_chunk must be >= 1"
            )
        if self.merge_fanin < 2:
            raise ConfigError("spill.merge_fanin must be >= 2")


@dataclass
class ChannelConfig:
    """§4 full-duplex pipeline: background transmit + receiver digest
    channels, plus wire compression for both the position and the payload
    columns."""

    pipeline: bool = False  # overlap transmit with the next group's fold
    compress: bool = False  # varint-delta the message runs' dp channel
    # payload codec on the wire: False off; True/"lossless" byte-shuffle +
    # DEFLATE on the msg (+cnt) channels (bit-exact round-trip); "bf16"
    # additionally rounds float32 messages to bfloat16 on the wire
    # (recoded_compact's trick — float-message programs only); "auto" spills
    # the first superstep raw, measures the lossless codec on a sample of
    # those runs, and picks lossless vs raw PER CHANNEL for the rest of the
    # run (streams/codec.PayloadAutoPicker; the choice is recorded in
    # ChannelStats.payload_choice)
    compress_payload: Any = False
    # overlap the receiver digest with the next group's fold (U_r ∥ U_c);
    # only meaningful with pipeline=True (False = PR-3's sender-only
    # half-duplex pipeline, kept for A/B benchmarking)
    full_duplex: bool = True
    inflight: int = 4  # bounded in-flight packets (O(1) RAM budget)
    fault: Any = None  # sender-side FaultPoint (fault drills only)
    recv_fault: Any = None  # receiver-side FaultPoint (fault drills only)

    def validate(self) -> None:
        from repro.streams.codec import normalize_payload_scheme

        if self.inflight < 1:
            raise ConfigError("channel.inflight must be >= 1")
        try:
            normalize_payload_scheme(self.compress_payload, allow_auto=True)
        except ValueError as e:
            raise ConfigError(f"channel.compress_payload: {e}") from None

    @property
    def payload_scheme(self) -> str | None:
        """None when off, else the codec scheme name — or "auto", which the
        engine resolves from a first-superstep sample (the codec's
        normalization is the single source of truth)."""
        from repro.streams.codec import normalize_payload_scheme

        return normalize_payload_scheme(self.compress_payload,
                                        allow_auto=True)


@dataclass
class RecoveryConfig:
    """Checkpoint cadence and message-log policy (paper §3.4).

    The engine itself does not consume this — checkpointers are passed to
    ``run()`` — but :class:`repro.core.job.GraphDJob` builds the
    ``Checkpointer`` / ``MessageLog`` wiring from it, and the planner carries
    it through so a plan fully describes a job.
    """

    checkpoint_every: int = 0  # supersteps between checkpoints; 0 = off
    keep: int = 2  # checkpoints retained
    log_messages: bool = False  # persist OMSs for single-shard fast recovery

    def validate(self) -> None:
        if self.checkpoint_every < 0:
            raise ConfigError("recovery.checkpoint_every must be >= 0")
        if self.keep < 1:
            raise ConfigError("recovery.keep must be >= 1")
        if self.log_messages and not self.checkpoint_every:
            raise ConfigError(
                "recovery.log_messages needs a checkpoint cadence: message "
                "logs are replayed from the latest checkpoint (§3.4) and "
                "GC'd when a newer one lands — without checkpoints they "
                "would grow forever"
            )


@dataclass
class EngineConfig:
    """Everything the engine needs to know that is not the program, the
    partition, or a live object (mesh / store / log)."""

    mode: str = "recoded"
    backend: str = "jnp"  # "jnp" | "pallas" (kernels/, §5 fast path)
    kernel_windows: int = 512
    sparse_cap_frac: float = 0.25  # skip(): max gathered blocks fraction
    adapt_threshold: float = 0.125  # dense->sparse dispatch density
    stream: StreamConfig = field(default_factory=StreamConfig)
    spill: MessageSpillConfig = field(default_factory=MessageSpillConfig)
    channel: ChannelConfig = field(default_factory=ChannelConfig)
    recovery: RecoveryConfig = field(default_factory=RecoveryConfig)

    # -- validation ----------------------------------------------------------
    def finalize(self) -> "EngineConfig":
        """Validate every sub-config, then the cross-config invariants.
        Returns ``self`` so call sites can write ``cfg = cfg.finalize()``."""
        if self.mode not in MODES:
            raise ConfigError(
                f"unknown mode={self.mode!r}; pick one of {MODES}"
            )
        if self.backend not in ("jnp", "pallas"):
            raise ConfigError(
                f"unknown backend={self.backend!r}; pick 'jnp' or 'pallas'"
            )
        for sub in (self.stream, self.spill, self.channel, self.recovery):
            sub.validate()
        if not 0 < self.sparse_cap_frac <= 1:
            raise ConfigError("sparse_cap_frac must be in (0, 1]")
        if self.kernel_windows < 8:
            raise ConfigError("kernel_windows must be >= 8")
        ch = self.channel
        if self.mode != "streamed" and (
            ch.pipeline or ch.compress or ch.compress_payload
            or ch.fault is not None or ch.recv_fault is not None
        ):
            raise ConfigError(
                "pipeline=/compress=/compress_payload=/channel faults are "
                "streamed-mode knobs (the in-memory modes already overlap "
                "on-device, §5/C3)"
            )
        if self.mode != "streamed" and self.stream.cache_bytes:
            raise ConfigError(
                "stream.cache_bytes is a streamed-mode knob: the hot-block "
                "cache is the semi-external tier between RAM and the edge "
                "stream; the in-memory modes are fully resident already"
            )
        if ch.payload_scheme == "auto" and self.recovery.log_messages:
            # a run-file message log fixes its wire format at configure();
            # the auto-pick resolves the codec only after the first
            # superstep's sample, and a recovery replay could not re-derive
            # the same mid-run switch point. Catch the conflict here — at
            # plan/job construction — instead of deep inside engine wiring.
            raise ConfigError(
                "channel.compress_payload='auto' conflicts with "
                "recovery.log_messages=True: the auto-pick resolves the "
                "wire codec from a first-superstep sample, but a message "
                "log needs a fixed wire format for bit-identical replay — "
                "pass 'lossless' (or False) explicitly"
            )
        if self.backend == "pallas" and self.mode != "recoded":
            raise ConfigError("backend='pallas' needs mode='recoded'")
        if self.mode == "streamed" and self.backend != "jnp":
            raise ConfigError(
                "mode='streamed' is host-driven: backend='jnp' only"
            )
        return self

    # -- serialization -------------------------------------------------------
    def to_json(self) -> dict:
        """JSON-able dict. The channel fault points (live objects) are
        recorded only by presence — fault injection is a test harness, not
        job state."""
        out = dataclasses.asdict(self)
        out["channel"]["fault"] = (
            None if self.channel.fault is None else "<FaultPoint>"
        )
        out["channel"]["recv_fault"] = (
            None if self.channel.recv_fault is None else "<FaultPoint>"
        )
        return out

    @classmethod
    def from_json(cls, d: dict) -> "EngineConfig":
        d = dict(d)
        ch = dict(d.get("channel", {}))
        for key in ("fault", "recv_fault"):
            if ch.get(key) is not None:
                ch[key] = None  # fault points do not round-trip
        return cls(
            mode=d.get("mode", "recoded"),
            backend=d.get("backend", "jnp"),
            kernel_windows=d.get("kernel_windows", 512),
            sparse_cap_frac=d.get("sparse_cap_frac", 0.25),
            adapt_threshold=d.get("adapt_threshold", 0.125),
            stream=StreamConfig(**d.get("stream", {})),
            spill=MessageSpillConfig(**d.get("spill", {})),
            channel=ChannelConfig(**ch),
            recovery=RecoveryConfig(**d.get("recovery", {})),
        )
