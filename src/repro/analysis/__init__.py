"""Repo-specific static analysis: the invariants the type system cannot see.

GraphD's correctness rests on protocol discipline, not types: background
sender/receiver threads must be joined on every close path (PR 6), liveness
must be judged by monotonic clocks and in-record progress, never wall time
or mtime (PR 8), counters and manifests must publish only after the bytes
they describe are flushed (PR 5), cross-thread state must be lock-guarded
or explicitly reviewed, frame encoders must stay symmetric with their
decoders, the pre-heartbeat worker import path must stay jax-free (PR 6),
and reconnect loops must be bounded by a RetryPolicy instead of spinning
forever (PR 10). Each of those regression classes is one AST pass here;
the suite
runs in CI over ``src/`` and fails on any unsuppressed finding.

Run locally::

    PYTHONPATH=src python -m repro.analysis src/

Suppression, in reviewed-preference order: fix the code; or annotate the
line (or the line above) with ``# analysis: allow[<pass-id>] <why>``; or
add the finding's key to ``analysis-baseline.json`` with a reason.
"""

from repro.analysis.base import (
    AnalysisConfig, Baseline, Finding, Source, collect_sources, run_analysis,
)
from repro.analysis.clocks import LivenessClockPass
from repro.analysis.imports import ImportHygienePass
from repro.analysis.publish import AtomicPublishPass
from repro.analysis.races import SharedStateRacePass
from repro.analysis.retry import RetryDisciplinePass
from repro.analysis.threads import ThreadLifecyclePass
from repro.analysis.wire import WireSymmetryPass

#: the suite, in bug-history order (PR 6, PR 8, PR 5, PR 5, PR 8, PR 6,
#: PR 10)
ALL_PASSES = (
    ThreadLifecyclePass(),
    LivenessClockPass(),
    AtomicPublishPass(),
    SharedStateRacePass(),
    WireSymmetryPass(),
    ImportHygienePass(),
    RetryDisciplinePass(),
)

__all__ = [
    "ALL_PASSES",
    "AnalysisConfig",
    "AtomicPublishPass",
    "Baseline",
    "Finding",
    "ImportHygienePass",
    "LivenessClockPass",
    "RetryDisciplinePass",
    "SharedStateRacePass",
    "Source",
    "ThreadLifecyclePass",
    "WireSymmetryPass",
    "collect_sources",
    "run_analysis",
]
