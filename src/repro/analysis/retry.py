"""retry-discipline: reconnect loops must be bounded by a RetryPolicy.

PR 10's chaos drills exposed the failure shape: a worker whose peer (or
coordinator) dies reconnects in a bare ``while True:`` loop and hangs the
run forever — no backoff, no deadline, no structured failure for the
supervisor to act on. The repo-wide rule since: **every reconnect loop
iterates ``RetryPolicy.attempts(site)`` (repro.fault.retry)**, which
sleeps with jittered exponential backoff and degrades to a loud
``RetryExhausted`` (a structured failure summary) when the peer is really
gone.

The pass flags every ``while True:`` (or ``while 1:``) loop whose body
calls a connect-ish API — a call whose final dotted segment is
``connect``, ``create_connection``, ``connect_ex`` or ``accept`` — unless
the loop body already shows retry discipline: it references a ``retry``
identifier/attribute or iterates an ``.attempts(...)`` generator.

Blind spots, documented: the check is per-loop and syntactic. A loop
bounded by an outer deadline, or a connect call hidden behind a helper
the loop calls, is invisible — annotate those with
``# analysis: allow[retry-discipline] <why>``. Accept loops gated on a
close flag (``while not self._closed:``) are not constant-true and are
never flagged.
"""

from __future__ import annotations

import ast

from repro.analysis.base import (
    AnalysisConfig, Finding, Pass, Source, call_name, enclosing_scope_map,
)

HINT = ("bound the loop with `for attempt in retry.attempts(site):` "
        "(repro.fault.RetryPolicy) so a dead peer degrades to a loud "
        "RetryExhausted instead of a hang; if the loop is bounded by an "
        "outer deadline, annotate: # analysis: allow[retry-discipline] "
        "<why>")

#: final dotted segments that establish a (re)connection attempt
CONNECTISH = ("connect", "create_connection", "connect_ex", "accept")


def _const_true(test: ast.AST) -> bool:
    return isinstance(test, ast.Constant) and bool(test.value)


def _last_segment(name: str) -> str:
    return name.rsplit(".", 1)[-1]


class RetryDisciplinePass(Pass):
    pass_id = "retry-discipline"

    def run(self, sources: list[Source],
            config: AnalysisConfig) -> list[Finding]:
        findings = []
        for src in sources:
            scopes = enclosing_scope_map(src.tree)
            for node in ast.walk(src.tree):
                if not isinstance(node, ast.While) or \
                        not _const_true(node.test):
                    continue
                connects: list[tuple[ast.Call, str]] = []
                disciplined = False
                for stmt in node.body:
                    for sub in ast.walk(stmt):
                        if isinstance(sub, ast.Name) and \
                                "retry" in sub.id.lower():
                            disciplined = True
                        elif isinstance(sub, ast.Attribute) and \
                                "retry" in sub.attr.lower():
                            disciplined = True
                        elif isinstance(sub, ast.Call):
                            name = call_name(sub) or ""
                            seg = _last_segment(name)
                            if seg == "attempts":
                                disciplined = True
                            elif seg in CONNECTISH:
                                connects.append((sub, seg))
                if disciplined or not connects:
                    continue
                for call, seg in connects:
                    findings.append(Finding(
                        pass_id=self.pass_id, path=src.path,
                        line=call.lineno,
                        scope=scopes.get(call.lineno, "<module>"),
                        detail=seg,
                        message=f"bare `while True:` loop retries "
                                f"{seg}() without a RetryPolicy — a dead "
                                "peer hangs this loop forever",
                        hint=HINT,
                    ))
        return findings
