"""wire-symmetry: encoders and decoders must not drift apart.

The socket transport's compatibility story is that ``launch/net.py`` and
``streams/codec.py`` each keep their pack and unpack sides in the same
module, so a format change that touches only one side is a reviewable
drift, not a silent wire break discovered by a peer. Two mechanical
rules, per module:

1. **Struct symmetry** — every ``struct.Struct("<fmt>")`` bound to a
   module-level name must have both a ``NAME.pack``/``pack_into`` use
   and a ``NAME.unpack``/``unpack_from`` use somewhere in the module;
   likewise every literal format string passed to bare ``struct.pack``
   must appear in some ``struct.unpack`` call and vice versa. A
   one-sided format means the other direction lives elsewhere (or
   nowhere) and can drift.
2. **Header-field symmetry** — for each same-module ``encode_X`` /
   ``decode_X`` name pair, the string keys the decoder reads
   (``hdr["k"]`` subscripts and ``hdr.get("k")`` calls) must be a
   subset of the keys the encoder writes (``dict(...)`` keywords and
   ``{"k": ...}`` literal keys). Subset, not equality: callers may read
   envelope fields (step/seq/tag) outside the decode helper, but a
   decoder key the encoder never writes is a guaranteed KeyError/None
   on a live socket.

Blind spots: formats built by string concatenation and keys routed
through variables are invisible — the transport deliberately uses
literal formats and literal keys to stay inside this checkable subset.
"""

from __future__ import annotations

import ast

from repro.analysis.base import (
    AnalysisConfig, Finding, Pass, Source, call_name,
)

STRUCT_HINT = ("keep pack and unpack of one wire format in the same "
               "module; if the other side is intentionally remote, "
               "annotate why")
FIELD_HINT = ("add the key to the encoder's header dict (and bump the "
              "frame version if the wire format changes), or stop "
              "reading it in the decoder")


def _struct_defs(tree: ast.Module):
    """module-level ``NAME = struct.Struct(<const fmt>)`` assignments."""
    out = {}
    for node in tree.body:
        if (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)
                and call_name(node.value) in ("struct.Struct", "Struct")
                and node.value.args
                and isinstance(node.value.args[0], ast.Constant)
                and isinstance(node.value.args[0].value, str)):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out[t.id] = (node.lineno, node.value.args[0].value)
    return out


def _name_method_uses(tree: ast.Module, names):
    """name -> set of methods called on it (pack/unpack/...)."""
    uses: dict = {n: set() for n in names}
    for node in ast.walk(tree):
        if (isinstance(node, ast.Attribute)
                and isinstance(node.value, ast.Name)
                and node.value.id in uses):
            uses[node.value.id].add(node.attr)
        # also catch aliased uses: cls-level or self._HDR = _HEADER then
        # self._HDR.pack(...) is NOT tracked — modules keep these global.
    return uses


def _bare_struct_fmts(tree: ast.Module):
    """(packed fmts, unpacked fmts) passed literally to struct.pack/unpack."""
    packed, unpacked = {}, {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node) or ""
        if name in ("struct.pack", "struct.pack_into"):
            bucket = packed
        elif name in ("struct.unpack", "struct.unpack_from"):
            bucket = unpacked
        else:
            continue
        if node.args and isinstance(node.args[0], ast.Constant) \
                and isinstance(node.args[0].value, str):
            bucket.setdefault(node.args[0].value, node.lineno)
    return packed, unpacked


def _encoder_keys(fn: ast.FunctionDef) -> set:
    """Keys the encoder writes: dict(...) keywords + {"k": ...} literals."""
    keys = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and call_name(node) == "dict":
            keys.update(kw.arg for kw in node.keywords if kw.arg)
        elif isinstance(node, ast.Dict):
            for k in node.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    keys.add(k.value)
    return keys


def _decoder_keys(fn: ast.FunctionDef):
    """(key, line) pairs the decoder reads: x["k"] and x.get("k")."""
    out = []
    for node in ast.walk(fn):
        if (isinstance(node, ast.Subscript)
                and isinstance(node.slice, ast.Constant)
                and isinstance(node.slice.value, str)):
            out.append((node.slice.value, node.lineno))
        elif (isinstance(node, ast.Call)
                and (call_name(node) or "").endswith(".get")
                and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            out.append((node.args[0].value, node.lineno))
    return out


class WireSymmetryPass(Pass):
    pass_id = "wire-symmetry"

    def run(self, sources: list[Source],
            config: AnalysisConfig) -> list[Finding]:
        findings = []
        for src in sources:
            findings.extend(self._structs(src))
            findings.extend(self._codec_pairs(src))
        return findings

    def _structs(self, src: Source) -> list:
        findings = []
        defs = _struct_defs(src.tree)
        uses = _name_method_uses(src.tree, defs)
        for name, (line, fmt) in defs.items():
            methods = uses[name]
            has_pack = bool(methods & {"pack", "pack_into"})
            has_unpack = bool(methods & {"unpack", "unpack_from"})
            if has_pack != has_unpack:
                side = "pack" if has_pack else "unpack"
                findings.append(Finding(
                    pass_id=self.pass_id, path=src.path, line=line,
                    scope="<module>", detail=name,
                    message=(f"struct format {name} ({fmt!r}) is only ever "
                             f"used to {side} in this module — the other "
                             "direction can drift"),
                    hint=STRUCT_HINT,
                ))
        packed, unpacked = _bare_struct_fmts(src.tree)
        for fmt, line in packed.items():
            if fmt not in unpacked:
                findings.append(Finding(
                    pass_id=self.pass_id, path=src.path, line=line,
                    scope="<module>", detail=fmt,
                    message=(f"struct.pack format {fmt!r} has no matching "
                             "struct.unpack in this module"),
                    hint=STRUCT_HINT,
                ))
        for fmt, line in unpacked.items():
            if fmt not in packed:
                findings.append(Finding(
                    pass_id=self.pass_id, path=src.path, line=line,
                    scope="<module>", detail=fmt,
                    message=(f"struct.unpack format {fmt!r} has no matching "
                             "struct.pack in this module"),
                    hint=STRUCT_HINT,
                ))
        return findings

    def _codec_pairs(self, src: Source) -> list:
        findings = []
        fns = {}
        for node in ast.walk(src.tree):
            if isinstance(node, ast.FunctionDef):
                fns[node.name] = node
        for name, enc in fns.items():
            if not name.startswith("encode_"):
                continue
            dec = fns.get("decode_" + name[len("encode_"):])
            if dec is None:
                continue
            written = _encoder_keys(enc)
            if not written:
                continue  # encoder builds no literal dict; out of scope
            for key, line in _decoder_keys(dec):
                if key not in written:
                    findings.append(Finding(
                        pass_id=self.pass_id, path=src.path, line=line,
                        scope=dec.name, detail=key,
                        message=(f"{dec.name} reads header key {key!r} "
                                 f"that {enc.name} never writes — "
                                 "guaranteed decode failure on a live "
                                 "connection"),
                        hint=FIELD_HINT,
                    ))
        return findings
