"""thread-lifecycle: every background thread must have a join-on-close path.

Python cannot kill a thread. A sender/receiver thread that outlives its
owner keeps file descriptors and sockets open, keeps mutating shared
stores, and turns "close() returned" into a lie — the PR 6 regression
class. The reviewed idiom (``ChannelSender.close``) is::

    self._thread.join(timeout=10.0)
    if self._thread.is_alive():
        raise ChannelError("... failed to stop")

This pass finds every ``threading.Thread(...)`` construction and accepts
it only if one of two shapes holds:

* **scoped lifetime** — the constructing function itself joins with a
  timeout, checks ``is_alive()``, and raises (the ``prefetch_iter``
  idiom, where the thread never escapes the function); or
* **owner lifetime** — the enclosing class has a close-path method
  (``close``/``stop``/``shutdown``/``abort``/``__exit__``, closed over
  the private ``self._x()`` helpers it calls) that joins with a timeout,
  checks ``is_alive()``, and raises.

Blind spots: the pass proves a join *exists on the close path*, not that
it joins *this* thread, and not that close() is always called — tests
own those. Daemon threads that are deliberately fire-and-forget must
carry ``# analysis: allow[thread-lifecycle] <why>``.
"""

from __future__ import annotations

import ast

from repro.analysis.base import (
    AnalysisConfig, Finding, Pass, Source, call_name,
)

CLOSE_NAMES = {"close", "stop", "shutdown", "abort", "__exit__"}

HINT = (
    "give the owner a close()/stop() that does thread.join(timeout=...), "
    "checks thread.is_alive() and raises on leak (the ChannelSender "
    "contract), or annotate why this thread may outlive its owner"
)


def _is_thread_ctor(node: ast.Call) -> bool:
    name = call_name(node)
    return name in ("threading.Thread", "Thread")


def _discipline_bits(fn: ast.AST):
    """(join-with-timeout, is_alive, raise) present in ``fn``'s body."""
    join_with_timeout = alive = raises = False
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = call_name(node) or ""
            if name.endswith(".join") and (node.args or node.keywords):
                join_with_timeout = True
            if name.endswith(".is_alive"):
                alive = True
        elif isinstance(node, ast.Raise):
            raises = True
    return join_with_timeout, alive, raises


def _join_discipline(fns) -> bool:
    """True if join-with-timeout + is_alive + raise all appear across
    ``fns`` (one function, or a close-path closure — the idiom splits
    the three across ``close()`` and its ``_check_stopped()`` helper)."""
    if not isinstance(fns, (list, tuple)):
        fns = [fns]
    bits = (False, False, False)
    for fn in fns:
        bits = tuple(a or b for a, b in zip(bits, _discipline_bits(fn)))
    return all(bits)


def _method_closure(cls: ast.ClassDef, roots) -> list[ast.FunctionDef]:
    """Close ``roots`` over ``self._x()`` calls (one class, fixpoint)."""
    methods = {m.name: m for m in cls.body
               if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}
    seen: set = set()
    frontier = [m for m in roots if m.name in methods]
    out = []
    while frontier:
        m = frontier.pop()
        if m.name in seen:
            continue
        seen.add(m.name)
        out.append(m)
        for node in ast.walk(m):
            if isinstance(node, ast.Call):
                name = call_name(node) or ""
                if name.startswith("self."):
                    callee = methods.get(name[len("self."):])
                    if callee is not None and callee.name not in seen:
                        frontier.append(callee)
    return out


class ThreadLifecyclePass(Pass):
    pass_id = "thread-lifecycle"

    def run(self, sources: list[Source],
            config: AnalysisConfig) -> list[Finding]:
        findings = []
        for src in sources:
            findings.extend(self._run_file(src))
        return findings

    def _run_file(self, src: Source) -> list[Finding]:
        findings = []
        # index: class node -> whether its close-path closure joins properly
        class_ok: dict[int, bool] = {}

        def close_path_ok(cls: ast.ClassDef) -> bool:
            if id(cls) not in class_ok:
                roots = [m for m in cls.body
                         if isinstance(m, ast.FunctionDef)
                         and m.name in CLOSE_NAMES]
                closure = _method_closure(cls, roots)
                class_ok[id(cls)] = _join_discipline(closure)
            return class_ok[id(cls)]

        # walk with an explicit (class, function) context stack
        def visit(node, cls, fn):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.ClassDef):
                    visit(child, child, None)
                elif isinstance(child, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    visit(child, cls, fn if fn is not None else child)
                else:
                    if isinstance(child, ast.Call) and _is_thread_ctor(child):
                        check(child, cls, fn)
                    visit(child, cls, fn)

        def check(call: ast.Call, cls, fn):
            # fn here is the OUTERMOST function — a thread constructed
            # inside a nested closure still belongs to the method's scope
            if fn is not None and _join_discipline(fn):
                return  # scoped lifetime: joined before the function returns
            if cls is not None and close_path_ok(cls):
                return  # owner lifetime: close path joins + raises on leak
            scope = []
            if cls is not None:
                scope.append(cls.name)
            if fn is not None:
                scope.append(fn.name)
            where = ".".join(scope) or "<module>"
            findings.append(Finding(
                pass_id=self.pass_id, path=src.path, line=call.lineno,
                scope=where, detail="Thread",
                message=("thread started here is not reachable from a "
                         "close()/stop() path that joins with a timeout "
                         "and raises on leak"),
                hint=HINT,
            ))

        visit(src.tree, None, None)
        return findings
