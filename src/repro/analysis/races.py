"""shared-state-race: cross-thread attributes are locked or declared.

For every class that starts a thread on one of its methods (or a nested
function), compute:

* **thread code** — the target methods, closed over ``self._x()`` calls;
* **T_w** — ``self.<attr>`` names written from thread code (plain,
  augmented, subscripted, or nested like ``self.stats.sent += 1``);
* **public reads** — ``self.<attr>`` loads in public-named methods that
  are *not* part of thread code, transitively closed over the private
  helpers they call (so ``collect() -> self._raise() -> self._exc`` is a
  public read of ``_exc``).

Every attribute in both sets must be either

* read under ``with self.<lock>:`` where ``<lock>`` is an attribute
  assigned from ``threading.Lock/RLock/Condition/...`` (sync objects and
  ``queue.Queue`` themselves are exempt — they are the safe channels), or
* declared in a class-level ``_LOCKED_FIELDS = frozenset({...})`` — the
  reviewed register of fields relying on GIL-atomic access (write-once
  ``_exc``, monotonic stats scalars). The declaration is the point:
  the reviewer sees the full list, and a new unprotected field trips
  the pass instead of silently joining the pile.

Blind spots: reads via ``getattr``, aliasing through locals, and
happens-before established by ``join()`` are invisible; declare those
fields. Reads *inside* thread code are not scanned (the thread owns its
own writes).
"""

from __future__ import annotations

import ast

from repro.analysis.base import (
    AnalysisConfig, Finding, Pass, Source, assign_target_attr, call_name,
    self_attr,
)

SYNC_CTORS = {
    "threading.Event", "threading.Condition", "threading.Lock",
    "threading.RLock", "threading.Semaphore", "threading.BoundedSemaphore",
    "threading.Thread", "queue.Queue", "Event", "Condition", "Lock",
    "RLock", "Semaphore", "BoundedSemaphore", "Thread", "Queue",
    "queue.SimpleQueue", "SimpleQueue",
}

HINT = ("guard the read with the class lock/condition, or declare the "
        "field in _LOCKED_FIELDS = frozenset({...}) with a comment saying "
        "why GIL-atomic access is sufficient (write-once, monotonic stat)")


def _methods(cls: ast.ClassDef) -> dict:
    return {m.name: m for m in cls.body
            if isinstance(m, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _locked_fields(cls: ast.ClassDef) -> set:
    """Names in a class-level ``_LOCKED_FIELDS = frozenset({...})``."""
    out: set = set()
    for node in cls.body:
        if isinstance(node, ast.Assign):
            names = [t.id for t in node.targets if isinstance(t, ast.Name)]
            if "_LOCKED_FIELDS" not in names:
                continue
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Constant) and \
                        isinstance(sub.value, str):
                    out.add(sub.value)
    return out


def _sync_attrs(cls: ast.ClassDef) -> set:
    """Attrs assigned from sync-object constructors anywhere in the class."""
    out = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            name = call_name(node.value) or ""
            if name in SYNC_CTORS:
                for t in node.targets:
                    attr = self_attr(t)
                    if attr:
                        out.add(attr)
    return out


def _thread_targets(cls: ast.ClassDef, methods: dict):
    """(method nodes, nested function nodes) used as Thread targets."""
    target_methods, nested_fns = [], []
    for node in ast.walk(cls):
        if not (isinstance(node, ast.Call)
                and call_name(node) in ("threading.Thread", "Thread")):
            continue
        for kw in node.keywords:
            if kw.arg != "target":
                continue
            attr = self_attr(kw.value)
            if attr and attr in methods:
                target_methods.append(methods[attr])
            elif isinstance(kw.value, ast.Name):
                # nested function defined in the constructing method
                for fn in ast.walk(cls):
                    if isinstance(fn, ast.FunctionDef) and \
                            fn.name == kw.value.id and fn.name not in methods:
                        nested_fns.append(fn)
    return target_methods, nested_fns


def _close_over_self_calls(roots, methods: dict, private_only=False):
    """Fixpoint of ``self.m()`` calls starting from ``roots``."""
    seen, out, frontier = set(), [], list(roots)
    while frontier:
        m = frontier.pop()
        key = getattr(m, "name", id(m))
        if key in seen:
            continue
        seen.add(key)
        out.append(m)
        for node in ast.walk(m):
            if isinstance(node, ast.Call):
                name = call_name(node) or ""
                if name.startswith("self."):
                    mn = name[len("self."):]
                    if private_only and not mn.startswith("_"):
                        continue
                    callee = methods.get(mn)
                    if callee is not None and callee.name not in seen:
                        frontier.append(callee)
    return out


def _written_attrs(fns) -> set:
    out = set()
    for fn in fns:
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    attr = assign_target_attr(t)
                    if attr:
                        out.add(attr)
    return out


def _guarded_spans(fn: ast.FunctionDef, sync_attrs: set):
    """Line spans inside ``with self.<sync_attr>:`` blocks."""
    spans = []
    for node in ast.walk(fn):
        if isinstance(node, ast.With):
            for item in node.items:
                attr = self_attr(item.context_expr)
                if attr is None and isinstance(item.context_expr, ast.Call):
                    attr = self_attr(item.context_expr.func)
                if attr in sync_attrs:
                    spans.append((node.lineno,
                                  getattr(node, "end_lineno", node.lineno)))
                    break
    return spans


class SharedStateRacePass(Pass):
    pass_id = "shared-state-race"

    def run(self, sources: list[Source],
            config: AnalysisConfig) -> list[Finding]:
        findings = []
        for src in sources:
            for node in ast.walk(src.tree):
                if isinstance(node, ast.ClassDef):
                    findings.extend(self._check_class(src, node))
        return findings

    def _check_class(self, src: Source, cls: ast.ClassDef) -> list:
        methods = _methods(cls)
        target_methods, nested_fns = _thread_targets(cls, methods)
        if not target_methods and not nested_fns:
            return []
        sync_attrs = _sync_attrs(cls)
        locked = _locked_fields(cls)

        thread_code = _close_over_self_calls(
            target_methods, methods, private_only=False) + nested_fns
        thread_names = {getattr(m, "name", None) for m in thread_code}
        written = _written_attrs(thread_code) - sync_attrs

        findings = []
        public_roots = [m for m in methods.values()
                        if not m.name.startswith("_")
                        and m.name not in thread_names]
        # public surface closes over the private helpers it calls, but a
        # helper shared with the thread closure is skipped (thread-owned)
        surface = [m for m in
                   _close_over_self_calls(public_roots, methods)
                   if m.name not in thread_names]
        for m in surface:
            guarded = _guarded_spans(m, sync_attrs)
            for node in ast.walk(m):
                if not (isinstance(node, ast.Attribute)
                        and isinstance(node.ctx, ast.Load)):
                    continue
                attr = self_attr(node)
                if attr is None or attr not in written:
                    continue
                if attr in locked or attr in sync_attrs:
                    continue
                if any(lo <= node.lineno <= hi for lo, hi in guarded):
                    continue
                findings.append(Finding(
                    pass_id=self.pass_id, path=src.path, line=node.lineno,
                    scope=f"{cls.name}.{m.name}", detail=attr,
                    message=(f"self.{attr} is written from a background "
                             f"thread of {cls.name} and read here without "
                             "a lock or a _LOCKED_FIELDS declaration"),
                    hint=HINT,
                ))
        return findings
