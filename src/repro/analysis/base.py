"""Framework shared by every analysis pass: parsed sources, findings with
stable suppression keys, inline allow-comments, and the reviewed baseline.

Design constraints, in order:

* **Stable keys.** A finding's identity must survive unrelated edits, or
  the committed baseline churns on every PR. Keys are
  ``pass:path:scope:detail`` (scope = dotted class/function path, detail =
  the offending symbol), never line numbers.
* **Zero dependencies.** The suite runs in CI before anything is
  installed; ``ast`` + stdlib only.
* **Mechanical, documented blind spots.** Every pass is a conservative
  approximation of the invariant it enforces; what it cannot see is
  written in its docstring, and the escape hatch is a *reviewed*
  suppression (inline comment or baseline entry), never a weaker check.
"""

from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass, field

ALLOW_RE = re.compile(r"#\s*analysis:\s*allow\[([a-z0-9-]+)\]")


@dataclass(frozen=True)
class Finding:
    """One rule violation: where, what, and how to fix it."""

    pass_id: str
    path: str  # repo-relative posix path
    line: int
    scope: str  # dotted enclosing Class.method chain, or "<module>"
    detail: str  # the offending symbol (attr/func/const name)
    message: str
    hint: str = ""

    @property
    def key(self) -> str:
        """Line-independent suppression key (what the baseline stores)."""
        return f"{self.pass_id}:{self.path}:{self.scope}:{self.detail}"

    def render(self) -> str:
        out = f"{self.path}:{self.line}: [{self.pass_id}] {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        out += f"\n    key:  {self.key}"
        return out

    def to_json(self) -> dict:
        return dict(pass_id=self.pass_id, path=self.path, line=self.line,
                    scope=self.scope, detail=self.detail, key=self.key,
                    message=self.message, hint=self.hint)


@dataclass
class Source:
    """One parsed file + the inline allow-comments it carries."""

    path: str  # repo-relative posix path (finding identity)
    abspath: str
    text: str
    tree: ast.Module
    #: line -> pass ids allowed on that line (and the line below)
    allows: dict[int, set] = field(default_factory=dict)

    @classmethod
    def parse(cls, abspath: str, relpath: str) -> "Source":
        with open(abspath, encoding="utf-8") as f:
            text = f.read()
        tree = ast.parse(text, filename=abspath)
        allows: dict[int, set] = {}
        for i, line in enumerate(text.splitlines(), start=1):
            for m in ALLOW_RE.finditer(line):
                allows.setdefault(i, set()).add(m.group(1))
        return cls(path=relpath.replace(os.sep, "/"), abspath=abspath,
                   text=text, tree=tree, allows=allows)

    def allowed(self, pass_id: str, line: int) -> bool:
        """True if the line (or the line above it) carries an allow-comment
        for ``pass_id`` — the inline suppression surface."""
        return (pass_id in self.allows.get(line, ())
                or pass_id in self.allows.get(line - 1, ()))


def collect_sources(paths, root: str | None = None) -> list[Source]:
    """Parse every ``*.py`` under ``paths`` (files or directories).

    ``root`` anchors the repo-relative paths findings carry; default is the
    common parent of ``paths`` resolved against the cwd. A file that fails
    to parse becomes a synthetic ``parse`` finding at run time rather than
    killing the whole suite (see :func:`run_analysis`).
    """
    files: list[str] = []
    for p in paths:
        p = os.path.abspath(p)
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(
                    d for d in dirnames
                    if d != "__pycache__" and not d.startswith(".")
                )
                files.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames) if f.endswith(".py"))
        else:
            files.append(p)
    root = os.path.abspath(root) if root else os.getcwd()
    out = []
    for f in files:
        rel = os.path.relpath(f, root)
        out.append(Source.parse(f, rel))
    return out


@dataclass
class AnalysisConfig:
    """Knobs the passes read; defaults encode THIS repo's audit surface.

    Tests (and future repos) override fields instead of editing passes.
    Module matching is by posix-path suffix, so configs survive both
    ``src/repro/...`` and bare ``repro/...`` checkouts.
    """

    #: modules whose size/counter/run-table mutations must follow a flush
    counter_modules: tuple = ("streams/msgstore.py",)
    #: the published-counter attribute names those modules guard
    counter_attrs: tuple = ("_sizes", "_blob_bytes", "_runs")
    #: source-path substrings accepted as temp-publish patterns
    tmp_markers: tuple = ("tmp", ".vacuum")
    #: helpers reviewed to fsync-then-rename internally: a call site that
    #: delegates publishing to one of these needs no local fsync
    publish_helpers: tuple = ("atomic_write_json", "_save_npz_atomic")
    #: import-hygiene roots: modules on the pre-heartbeat worker path
    worker_roots: tuple = ("repro.launch.procs", "repro.core.coordinator",
                           "repro.launch.net")
    #: import prefixes the worker path must not reach eagerly
    forbidden_imports: tuple = ("jax", "jaxlib")


class Pass:
    """Base class: ``run`` returns raw findings; inline allows are applied
    by the driver so passes stay oblivious to suppression mechanics."""

    pass_id = "abstract"

    def run(self, sources: list[Source],
            config: AnalysisConfig) -> list[Finding]:
        raise NotImplementedError


@dataclass
class Baseline:
    """The committed suppression file: reviewed finding keys + reasons.

    Format (``analysis-baseline.json``)::

        {"suppressions": [{"key": "<finding key>", "reason": "...",
                           "reviewed_by": "..."}]}
    """

    entries: dict[str, dict] = field(default_factory=dict)
    path: str | None = None

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
        entries = {}
        for e in doc.get("suppressions", []):
            if not e.get("key") or not e.get("reason"):
                raise ValueError(
                    f"{path}: every suppression needs 'key' and 'reason'"
                )
            entries[e["key"]] = e
        return cls(entries=entries, path=path)

    def match(self, finding: Finding) -> bool:
        return finding.key in self.entries

    def unused(self, findings: list[Finding]) -> list[str]:
        hit = {f.key for f in findings}
        return sorted(k for k in self.entries if k not in hit)


def run_analysis(sources: list[Source], config: AnalysisConfig | None = None,
                 passes=None, baseline: Baseline | None = None):
    """Run ``passes`` over ``sources``; returns ``(open, suppressed)``.

    ``open`` findings fail the suite; ``suppressed`` were matched by an
    inline allow-comment or a baseline entry (kept for the report — a
    suppression is a decision, not an absence)."""
    from repro import analysis as _pkg

    config = config or AnalysisConfig()
    passes = _pkg.ALL_PASSES if passes is None else passes
    raw: list[Finding] = []
    for p in passes:
        raw.extend(p.run(sources, config))
    raw.sort(key=lambda f: (f.path, f.line, f.pass_id, f.detail))
    by_path = {s.path: s for s in sources}
    open_findings, suppressed = [], []
    for f in raw:
        src = by_path.get(f.path)
        if src is not None and src.allowed(f.pass_id, f.line):
            suppressed.append(f)
        elif baseline is not None and baseline.match(f):
            suppressed.append(f)
        else:
            open_findings.append(f)
    return open_findings, suppressed


# -- shared AST helpers (used by several passes) ----------------------------

def dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def self_attr(node: ast.AST) -> str | None:
    """``x`` when ``node`` is exactly ``self.x``, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def assign_target_attr(target: ast.AST) -> str | None:
    """The ``self.<attr>`` a (possibly subscripted/nested) assignment
    target ultimately mutates: ``self.x = / self.x[i] = / self.x.y = ``
    all report ``x``."""
    node = target
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        got = self_attr(node)
        if got is not None:
            return got
        node = node.value
    return None


def func_scopes(tree: ast.Module):
    """Yield ``(scope, func_node)`` for every function/method, with scope
    the dotted Class.method path — the scope component of finding keys."""

    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scope = f"{prefix}.{child.name}" if prefix else child.name
                yield scope, child
                yield from walk(child, scope)
            elif isinstance(child, ast.ClassDef):
                scope = f"{prefix}.{child.name}" if prefix else child.name
                yield from walk(child, scope)
            else:
                yield from walk(child, prefix)

    yield from walk(tree, "")


def enclosing_scope_map(tree: ast.Module) -> dict[int, str]:
    """line -> innermost enclosing scope name (best-effort, for labeling)."""
    spans: list[tuple[int, int, str]] = []
    for scope, fn in func_scopes(tree):
        end = getattr(fn, "end_lineno", fn.lineno)
        spans.append((fn.lineno, end, scope))
    spans.sort(key=lambda t: (t[0], -(t[1])))
    out: dict[int, str] = {}
    for lo, hi, scope in spans:
        for ln in range(lo, hi + 1):
            out[ln] = scope  # later (inner) spans overwrite outer ones
    return out


def call_name(node: ast.Call) -> str | None:
    """Dotted name of the callee, if it is a plain name/attribute chain."""
    return dotted(node.func)
