"""import-hygiene: the pre-heartbeat worker path stays jax-free.

A freshly spawned worker must reach its first heartbeat before the
coordinator's grace window expires. Importing jax eagerly on that path
adds seconds of import time (and, on a GPU box, device init) before the
first beat — the PR 6 "false dead" regression: workers were declared
crashed while still importing. The launch modules therefore import jax
lazily, inside the functions that need it, and the package ``__init__``s
on the worker path are lazy (PEP 562) or jax-free.

This pass rebuilds the *eager module-level* import graph from source:

* module names are derived from paths (``src/repro/launch/net.py`` ->
  ``repro.launch.net``), honouring the ``repro`` namespace root;
* only module-level imports count — imports inside function bodies are
  lazy by construction and skipped (class bodies DO count: they execute
  at import time);
* importing ``a.b.c`` executes ``a/__init__`` and ``a.b/__init__`` too,
  so edges to every package prefix are added — this is what catches an
  eager ``jax`` import smuggled into ``repro/streams/__init__.py``,
  which IS executed by ``import repro.streams.store``;
* relative imports are resolved against the importer's package.

From the configured worker roots it BFSes the graph; reaching any module
whose name starts with a forbidden prefix (``jax``, ``jaxlib``) is a
finding, anchored at the first import of the chain with the full chain
in the message.

Blind spots: ``importlib.import_module`` and ``__import__`` with
computed names are invisible; conditional module-level imports
(``if TYPE_CHECKING`` is honoured and skipped, other conditions count
as eager — a worker may take that branch).
"""

from __future__ import annotations

import ast
from collections import deque

from repro.analysis.base import AnalysisConfig, Finding, Pass, Source

HINT = ("move the jax import inside the function that needs it (the "
        "launch-path idiom), or make the package __init__ lazy via "
        "module __getattr__ (PEP 562)")


def module_name(path: str) -> str | None:
    """``.../src/repro/launch/net.py`` -> ``repro.launch.net``."""
    parts = path.replace("\\", "/").split("/")
    if "repro" not in parts:
        return None
    parts = parts[parts.index("repro"):]
    if parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _is_type_checking_guard(node: ast.If) -> bool:
    t = node.test
    return (isinstance(t, ast.Name) and t.id == "TYPE_CHECKING") or (
        isinstance(t, ast.Attribute) and t.attr == "TYPE_CHECKING")


def eager_imports(tree: ast.Module, pkg: str):
    """(imported module name, line) pairs executed at import time.

    ``pkg`` is the importer's package (for resolving relative imports).
    Function/lambda bodies are lazy and skipped; class bodies and
    conditional module-level code are eager.
    """
    out = []

    def walk(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            if isinstance(child, ast.If) and _is_type_checking_guard(child):
                # the else branch still executes at runtime
                for sub in child.orelse:
                    walk_stmt(sub)
                continue
            walk_stmt(child)

    def walk_stmt(child):
        if isinstance(child, ast.Import):
            for alias in child.names:
                out.append((alias.name, child.lineno))
        elif isinstance(child, ast.ImportFrom):
            if child.level:
                base = pkg.split(".")
                # level 1 = current package, each extra level pops one
                base = base[:len(base) - (child.level - 1)]
                prefix = ".".join(base)
                mod = f"{prefix}.{child.module}" if child.module else prefix
            else:
                mod = child.module or ""
            if mod:
                out.append((mod, child.lineno))
                # `from a.b import c` may bind submodule a.b.c — resolved
                # against the graph later (edge added only if c is a module)
                for alias in child.names:
                    if alias.name != "*":
                        out.append((f"{mod}.{alias.name}", child.lineno))
        else:
            walk(child)

    walk(tree)
    return out


class ImportHygienePass(Pass):
    pass_id = "import-hygiene"

    def run(self, sources: list[Source],
            config: AnalysisConfig) -> list[Finding]:
        # module -> (source, its eager imports)
        mods: dict = {}
        for src in sources:
            name = module_name(src.path)
            if name is None:
                continue
            pkg = name if src.path.endswith("__init__.py") else \
                name.rsplit(".", 1)[0] if "." in name else name
            mods[name] = (src, eager_imports(src.tree, pkg))

        known = set(mods)

        def edges(name):
            """(target module, line) eager edges out of ``name``."""
            src, imps = mods[name]
            out = []
            for target, line in imps:
                # importing a.b.c executes a/__init__ and a.b/__init__
                parts = target.split(".")
                for i in range(1, len(parts) + 1):
                    prefix = ".".join(parts[:i])
                    if prefix in known or i == len(parts):
                        out.append((prefix, line))
            return out

        findings = []
        forbidden = tuple(config.forbidden_imports)
        for root in config.worker_roots:
            if root not in mods:
                continue
            # BFS, remembering the chain for the report
            parent: dict = {root: None}
            q = deque([root])
            while q:
                cur = q.popleft()
                for target, line in edges(cur):
                    bad = any(target == f or target.startswith(f + ".")
                              for f in forbidden)
                    if bad:
                        chain = []
                        node = cur
                        while node is not None:
                            chain.append(node)
                            node = parent[node][0] if parent[node] else None
                        chain.reverse()
                        via = " -> ".join(chain + [target])
                        anchor_src, anchor_line = mods[cur][0], line
                        findings.append(Finding(
                            pass_id=self.pass_id, path=anchor_src.path,
                            line=anchor_line, scope=cur, detail=target,
                            message=(f"worker import path reaches {target} "
                                     f"eagerly: {via} — jax import cost "
                                     "lands before the first heartbeat"),
                            hint=HINT,
                        ))
                        continue
                    if target in known and target not in parent:
                        parent[target] = (cur, line)
                        q.append(target)
        # dedupe identical (path, scope, detail) chains found via both
        # parent-package and direct edges
        seen, unique = set(), []
        for f in findings:
            if f.key in seen:
                continue
            seen.add(f.key)
            unique.append(f)
        return unique
