"""CLI: ``python -m repro.analysis [--json] [--baseline FILE] paths...``

Exit status is the contract CI relies on: 0 when every finding is
suppressed (inline allow-comment or baseline entry), 1 when any finding
is open, 2 on usage/configuration errors. Unused baseline entries warn
but do not fail — a fixed finding should not break the build, it should
prompt a baseline cleanup in the same PR.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis import (
    ALL_PASSES, AnalysisConfig, Baseline, collect_sources, run_analysis,
)

DEFAULT_BASELINE = "analysis-baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis: concurrency, "
                    "durability and wire-format invariants")
    ap.add_argument("paths", nargs="*", default=[],
                    help="files or directories to analyze (default: src/)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit findings as JSON on stdout")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help=f"suppression file (default: ./{DEFAULT_BASELINE} "
                         "if present)")
    ap.add_argument("--list-passes", action="store_true",
                    help="list pass ids and exit")
    args = ap.parse_args(argv)

    if args.list_passes:
        for p in ALL_PASSES:
            doc = (type(p).__module__ and
                   (sys.modules[type(p).__module__].__doc__ or ""))
            first = doc.strip().splitlines()[0] if doc.strip() else ""
            print(f"{p.pass_id:20s} {first}")
        return 0

    paths = args.paths or ["src"]
    missing = [p for p in paths if not os.path.exists(p)]
    if missing:
        print(f"error: no such path: {', '.join(missing)}", file=sys.stderr)
        return 2

    baseline = None
    bpath = args.baseline
    if bpath is None and os.path.exists(DEFAULT_BASELINE):
        bpath = DEFAULT_BASELINE
    if bpath is not None:
        try:
            baseline = Baseline.load(bpath)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"error: bad baseline {bpath}: {e}", file=sys.stderr)
            return 2

    try:
        sources = collect_sources(paths)
    except SyntaxError as e:
        print(f"error: cannot parse {e.filename}:{e.lineno}: {e.msg}",
              file=sys.stderr)
        return 2

    open_findings, suppressed = run_analysis(
        sources, config=AnalysisConfig(), baseline=baseline)
    unused = baseline.unused(open_findings + suppressed) if baseline else []

    if args.as_json:
        print(json.dumps({
            "open": [f.to_json() for f in open_findings],
            "suppressed": [f.to_json() for f in suppressed],
            "unused_suppressions": unused,
            "files": len(sources),
            "passes": [p.pass_id for p in ALL_PASSES],
        }, indent=2))
    else:
        for f in open_findings:
            print(f.render())
        print(f"\n{len(sources)} files, {len(ALL_PASSES)} passes: "
              f"{len(open_findings)} open, {len(suppressed)} suppressed"
              + (f", {len(unused)} unused baseline entries" if unused
                 else ""))
        for k in unused:
            print(f"  warning: unused baseline suppression: {k}",
                  file=sys.stderr)

    return 1 if open_findings else 0


if __name__ == "__main__":
    sys.exit(main())
