"""atomic-publish: bytes are flushed before anything points at them.

Two rules, both from the PR 5 regression class (a counter published a
run extent before the run's bytes were flushed; a reader mapped garbage):

1. **Rename discipline** (all files): every ``os.replace``/``os.rename``
   must (a) take its source from a temp path — the source-argument
   subtree must mention a configured temp marker (``"tmp"``,
   ``".vacuum"``) in a string constant or variable name — and (b) live
   in a function that calls ``os.fsync`` on an earlier line, or
   delegates to a reviewed publish helper (``atomic_write_json``,
   ``_save_npz_atomic``). Rename-without-fsync publishes a name that can
   point at unwritten bytes after a crash.

2. **Counter-after-flush** (configured modules only, default
   ``streams/msgstore.py``): within any function, a mutation of a
   published counter attribute (``self._sizes`` / ``self._blob_bytes`` /
   ``self._runs`` — plain, augmented or subscripted assignment) that has
   a ``.write(...)`` call before it must also have a ``.flush()`` /
   ``os.fsync`` / ``.close()`` between the last write and the mutation.
   Once the counter is visible, readers may map the extent it describes;
   the flush must dominate the publish.

Blind spots: both rules are per-function and line-ordered — cross-
function write/publish splits and loops that reorder dynamically are
invisible; the msgstore keeps publishes and their writes in one method
precisely so this stays checkable.
"""

from __future__ import annotations

import ast

from repro.analysis.base import (
    AnalysisConfig, Finding, Pass, Source, assign_target_attr, call_name,
    func_scopes,
)

RENAME_HINT = ("publish via tmp-write -> flush -> os.fsync -> os.replace "
               "(or route through atomic_write_json / _save_npz_atomic)")
COUNTER_HINT = ("flush (and fsync, if the extent is read cross-process) the "
                "data handles BEFORE mutating the counter that makes the "
                "extent visible to readers")


def _mentions_marker(node: ast.AST, markers) -> bool:
    """Does the argument subtree name a temp path (const or variable)?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            if any(m in sub.value for m in markers):
                return True
        elif isinstance(sub, ast.Name):
            if any(m.strip(".") in sub.id for m in markers):
                return True
        elif isinstance(sub, ast.Attribute):
            if any(m.strip(".") in sub.attr for m in markers):
                return True
    return False


class AtomicPublishPass(Pass):
    pass_id = "atomic-publish"

    def run(self, sources: list[Source],
            config: AnalysisConfig) -> list[Finding]:
        findings = []
        for src in sources:
            findings.extend(self._renames(src, config))
            if any(src.path.endswith(m) for m in config.counter_modules):
                findings.extend(self._counters(src, config))
        return findings

    # -- rule 1: rename discipline --------------------------------------

    def _renames(self, src: Source, config: AnalysisConfig) -> list[Finding]:
        findings = []
        for scope, fn in func_scopes(src.tree):
            renames = []
            fsync_lines = []
            helper_lines = []
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = call_name(node) or ""
                if name in ("os.replace", "os.rename"):
                    renames.append(node)
                elif name == "os.fsync" or name.endswith(".fsync"):
                    fsync_lines.append(node.lineno)
                elif any(name.split(".")[-1] == h
                         for h in config.publish_helpers):
                    helper_lines.append(node.lineno)
            for rn in renames:
                if not rn.args or not _mentions_marker(rn.args[0],
                                                       config.tmp_markers):
                    findings.append(Finding(
                        pass_id=self.pass_id, path=src.path, line=rn.lineno,
                        scope=scope, detail="rename-source",
                        message="rename source is not a recognizable temp "
                                "path — publish must go through a tmp file",
                        hint=RENAME_HINT,
                    ))
                if not any(ln < rn.lineno for ln in fsync_lines):
                    findings.append(Finding(
                        pass_id=self.pass_id, path=src.path, line=rn.lineno,
                        scope=scope, detail="rename-fsync",
                        message="rename publishes a name with no os.fsync "
                                "earlier in this function — after a crash "
                                "the name may point at unwritten bytes",
                        hint=RENAME_HINT,
                    ))
        # module-level renames (rare; scripts) — same rules, scope <module>
        return findings

    # -- rule 2: counter-after-flush ------------------------------------

    def _counters(self, src: Source, config: AnalysisConfig) -> list[Finding]:
        findings = []
        counter_attrs = set(config.counter_attrs)
        for scope, fn in func_scopes(src.tree):
            writes = []    # lines of .write(...) calls
            flushes = []   # lines of .flush()/.close()/os.fsync calls
            mutations = []  # (line, attr) of counter mutations
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    # use the attribute leaf, not the dotted chain: writes
                    # go through call results (self._handle(d, ch).write)
                    if isinstance(node.func, ast.Attribute):
                        leaf = node.func.attr
                    else:
                        leaf = (call_name(node) or "").split(".")[-1]
                    if leaf == "write":
                        writes.append(node.lineno)
                    elif leaf in ("flush", "fsync", "close"):
                        flushes.append(node.lineno)
                elif isinstance(node, (ast.Assign, ast.AugAssign)):
                    targets = (node.targets
                               if isinstance(node, ast.Assign)
                               else [node.target])
                    for t in targets:
                        attr = assign_target_attr(t)
                        if attr in counter_attrs:
                            mutations.append((node.lineno, attr))
            for mline, attr in mutations:
                prior_writes = [w for w in writes if w < mline]
                if not prior_writes:
                    continue
                last_write = max(prior_writes)
                if not any(last_write < f < mline for f in flushes):
                    findings.append(Finding(
                        pass_id=self.pass_id, path=src.path, line=mline,
                        scope=scope, detail=attr,
                        message=(f"self.{attr} mutated after a .write() "
                                 "with no flush/fsync in between — the "
                                 "counter publishes an extent whose bytes "
                                 "may still be buffered"),
                        hint=COUNTER_HINT,
                    ))
        return findings
