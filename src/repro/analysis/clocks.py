"""liveness-clock: liveness must never be judged by wall clocks or mtimes.

``time.time()`` jumps with NTP steps and suspend/resume; file mtimes
freeze on some filesystems and under clock skew look arbitrarily stale.
PR 8's false-kill bug was exactly this: heartbeat staleness judged by
``st_mtime`` declared live workers dead on mtime-frozen filesystems. The
repo-wide rule since: **staleness, grace windows, timeouts and backoff
use ``time.monotonic()``; durations use ``time.perf_counter()``; seq
progress in the record is the liveness signal**. Wall clocks are for
reporting only, and every such use is annotated.

The pass therefore flags *every* occurrence of:

* ``time.time()`` (any call whose dotted name ends in ``time.time``),
* ``st_mtime`` / ``st_mtime_ns`` attribute access and
  ``os.path.getmtime(...)``,
* naive ``datetime.now()`` / ``datetime.utcnow()``.

Wall-clock *reporting* (log timestamps, run manifests) is legitimate —
annotate it with ``# analysis: allow[liveness-clock] <why>``. Keeping
the rule total and pushing intent into the annotation beats any
heuristic for "is this line liveness code": the heuristic would rot,
the annotation is reviewed.
"""

from __future__ import annotations

import ast

from repro.analysis.base import (
    AnalysisConfig, Finding, Pass, Source, call_name, enclosing_scope_map,
)

HINT = ("use time.monotonic() for staleness/timeout/backoff, "
        "time.perf_counter() for durations; if this really is wall-clock "
        "reporting, annotate: # analysis: allow[liveness-clock] <why>")


class LivenessClockPass(Pass):
    pass_id = "liveness-clock"

    def run(self, sources: list[Source],
            config: AnalysisConfig) -> list[Finding]:
        findings = []
        for src in sources:
            scopes = enclosing_scope_map(src.tree)

            def emit(node, detail, what):
                findings.append(Finding(
                    pass_id=self.pass_id, path=src.path, line=node.lineno,
                    scope=scopes.get(node.lineno, "<module>"), detail=detail,
                    message=f"{what} — wall clocks and mtimes must not "
                            "drive liveness/timeout decisions",
                    hint=HINT,
                ))

            for node in ast.walk(src.tree):
                if isinstance(node, ast.Call):
                    name = call_name(node) or ""
                    if name == "time.time" or name.endswith(".time.time"):
                        emit(node, "time.time", "time.time() call")
                    elif name in ("os.path.getmtime", "getmtime"):
                        emit(node, "getmtime", "os.path.getmtime() call")
                    elif name.endswith("datetime.now") or \
                            name.endswith("datetime.utcnow"):
                        emit(node, "datetime", f"{name}() call")
                elif isinstance(node, ast.Attribute) and \
                        node.attr in ("st_mtime", "st_mtime_ns"):
                    emit(node, node.attr, f".{node.attr} access")
        return findings
