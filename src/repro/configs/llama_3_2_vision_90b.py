"""Llama 3.2 Vision 90B [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

100L text backbone (d_model 8192, 64 heads, kv 8, d_ff 28672, vocab
128256): every 5th layer cross-attends to image patch embeddings. The
vision tower is a STUB: input_specs() provides precomputed patch
embeddings (B, 1600, d_model). long_500k SKIPPED (full attention).
"""

from repro.models.config import LayerSpec, ModelConfig

_SELF = LayerSpec(kind="attn", ffn="dense")
_CROSS = LayerSpec(kind="cross", ffn="dense")

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    pattern=(_SELF, _SELF, _SELF, _SELF, _CROSS),
    n_media_tokens=1600,
)
