"""Hymba 1.5B — hybrid parallel attention+SSM heads [arXiv:2411.13676; hf].

32L, d_model 1600, 25 heads (kv 5, head_dim 64), d_ff 5504, vocab 32001,
ssm_state 16. Every layer runs attention and a Mamba branch in parallel
(learned per-channel mix). Hymba uses full attention on 3 layers and
sliding-window elsewhere; we approximate the {first, middle, last} global
placement with a period-8 pattern (globals at layers 8,16,24,32 — noted in
DESIGN.md). long_500k RUNS (windowed attention + O(1) SSM state).
"""

from repro.models.config import LayerSpec, ModelConfig

_SWA = LayerSpec(kind="hybrid", window=1024, ffn="dense")
_GLB = LayerSpec(kind="hybrid", window=None, ffn="dense")

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    pattern=(_SWA, _SWA, _SWA, _SWA, _SWA, _SWA, _SWA, _GLB),
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    tie_embeddings=True,
)
