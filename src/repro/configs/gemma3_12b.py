"""Gemma 3 12B [hf:google/gemma-3-1b-pt; unverified].

48L, d_model 3840, 16 heads (kv 8), head_dim 256, d_ff 15360,
vocab 262144. 5:1 local:global attention (sliding window 1024), 128k
context. long_500k RUNS for this arch: 5/6 of layers are sub-quadratic
sliding-window and global layers decode linearly per token; local layers
use ring-buffer KV caches of length `window`.
"""

from repro.models.config import LayerSpec, ModelConfig

_LOCAL = LayerSpec(kind="attn", window=1024, ffn="dense")
_GLOBAL = LayerSpec(kind="attn", window=None, ffn="dense")

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)
