"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B; hf].

94L, d_model 4096, 64 heads (GQA kv 4, head_dim 128), per-expert d_ff 1536,
vocab 151936. 128 experts, top-8, no shared experts.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab=151936,
    pattern=(LayerSpec(kind="attn", ffn="moe"),),
    n_experts=128,
    topk=8,
    moe_dff=1536,
)
