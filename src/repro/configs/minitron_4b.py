"""Minitron 4B — pruned Nemotron [arXiv:2407.14679; hf].

Dense GQA decoder. 32L, d_model 3072, 24 heads (kv 8), d_ff 9216,
vocab 256000.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256000,
    pattern=(LayerSpec(kind="attn", ffn="dense"),),
)
