"""Architecture registry: the 10 assigned configs + the GraphD job config."""

from repro.configs import (
    command_r_plus_104b,
    minitron_4b,
    deepseek_67b,
    gemma3_12b,
    mamba2_2_7b,
    qwen3_moe_235b_a22b,
    deepseek_v2_lite_16b,
    hymba_1_5b,
    whisper_large_v3,
    llama_3_2_vision_90b,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in [
        command_r_plus_104b,
        minitron_4b,
        deepseek_67b,
        gemma3_12b,
        mamba2_2_7b,
        qwen3_moe_235b_a22b,
        deepseek_v2_lite_16b,
        hymba_1_5b,
        whisper_large_v3,
        llama_3_2_vision_90b,
    ]
}


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choices: {sorted(ARCHS)}")
    return ARCHS[name]


# (arch, shape) cells skipped in the dry-run, with reasons (DESIGN.md
# §Arch-applicability): long_500k needs sub-quadratic attention.
LONG_CONTEXT_ARCHS = {"mamba2-2.7b", "hymba-1.5b", "gemma3-12b"}

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, "pure full attention — O(S^2) at 500k; skipped per spec"
    return True, ""
