"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-v01; unverified].

Dense GQA decoder, no biases. 64L, d_model 12288, 96 heads (kv 8),
d_ff 33792, vocab 256000.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    pattern=(LayerSpec(kind="attn", ffn="dense"),),
    rope_theta=75_000_000.0,
)
