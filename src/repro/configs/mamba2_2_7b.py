"""Mamba2 2.7B — SSD, attention-free [arXiv:2405.21060; unverified].

64L, d_model 2560, ssm_state 128, expand 2 (d_inner 5120, 80 heads of 64),
vocab 50280. No FFN (Mamba blocks only). long_500k RUNS: O(1)/token state.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,   # attention-free; attn fields unused
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    pattern=(LayerSpec(kind="ssm", ffn="none"),),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
)
