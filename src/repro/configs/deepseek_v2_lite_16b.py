"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf].

27L, d_model 2048, 16 heads, MLA (kv_lora 512, rope_dim 64, head_dim 128),
vocab 102400. Layer 1 dense (d_ff 10944); layers 2-27 MoE with 64 routed
experts (d_ff 1408) + 2 shared, top-6.

NOTE: the assignment line says "2 shared+160 routed top-6" — 160 routed is
the full-V2 figure; we follow the line's own "MoE 64e top-6" (the actual
V2-Lite config). Recorded in DESIGN.md §Arch-applicability.
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=10944,  # dense prologue layer width
    vocab=102400,
    prologue=(LayerSpec(kind="mla", ffn="dense"),),
    pattern=(LayerSpec(kind="mla", ffn="moe"),),
    mla_kv_lora=512,
    mla_rope_dim=64,
    n_experts=64,
    n_shared_experts=2,
    topk=6,
    moe_dff=1408,
)
