"""Whisper large-v3 — encoder-decoder ASR [arXiv:2212.04356; unverified].

32 encoder + 32 decoder layers, d_model 1280, 20 heads (kv 20, MHA),
d_ff 5120, vocab 51866. The conv mel frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, frames, d_model); frames =
seq_len of the shape cell. Decode shapes = decoder steps whose cross-KV
cache covers the `seq_len` encoder frames with a 448-token causal
self-cache (the semantically right reading for enc-dec — DESIGN.md).
long_500k SKIPPED (quadratic encoder).
"""

from repro.models.config import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,  # decoder
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    pattern=(LayerSpec(kind="attn", ffn="dense"),),
    act="gelu",
    n_media_tokens=1500,  # 30 s window after conv stride 2 (default)
)
