"""Kernel block layout: the TPU-native organization of the edge streams.

Mosaic (Pallas TPU) has no vector scatter and only a narrow dynamic-gather,
so the paper's gather(values[src]) / scatter-combine(A_s[dst]) hot loop is
re-tiled for the MXU (see DESIGN.md §2):

* per (shard, dest) group, edges are sorted by ``(dst_window, src)`` and cut
  into fixed ``BLK``-edge blocks such that
    - every source position in a block lies in ONE aligned ``SRC_WIN`` window
      (so the kernel can pull values/degree/active for the block as a single
      contiguous VMEM window via a scalar-prefetched BlockSpec index), and
    - every destination position lies in ONE ``DST_WIN`` window (so combining
      is a one-hot matmul into a window accumulator that persists in VMEM
      across the window's run of blocks);
* every destination window owns >= 1 block (possibly an empty placeholder) so
  each output window is initialized by its first block — Pallas output blocks
  are undefined until written;
* ``blk_lo/blk_hi`` keep the per-block source range for the skip() test, and
  block order preserves window contiguity, so the skip-compacted list keeps
  windows contiguous too.

This trades bounded padding (reported by ``layout_stats``) for a kernel with
zero unsupported ops: streams blocks HBM->VMEM (double-buffered by the Pallas
pipeline = the paper's streaming buffer B), gathers via one-hot MXU matvec,
combines via one-hot matmul / masked reduce (= in-memory A_s combining, §5).

The on-disk stream layout (``repro/streams/store.py``, engine mode
``streamed``) reuses the same block abstraction and ``blk_lo``/``blk_hi``
skip() contract (``graph.partition.block_ranges``), applied at the
disk->host boundary instead of HBM->VMEM; its per-superstep read plan lives
in ``repro/streams/schedule.py``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.partition import PartitionedGraph


@jax.tree_util.register_dataclass
@dataclass
class KernelLayout:
    """Per-shard kernel-ready edge layout. Leading axis = shard."""

    n_shards: int = dataclasses.field(metadata=dict(static=True))
    P: int = dataclasses.field(metadata=dict(static=True))
    BLK: int = dataclasses.field(metadata=dict(static=True))  # edges per block
    SRC_WIN: int = dataclasses.field(metadata=dict(static=True))
    DST_WIN: int = dataclasses.field(metadata=dict(static=True))
    NB: int = dataclasses.field(metadata=dict(static=True))  # blocks per group

    sp: jax.Array  # (n, n, NB, BLK) i32 src pos, -1 pad
    dp: jax.Array  # (n, n, NB, BLK) i32 dst pos (absolute)
    w: jax.Array  # (n, n, NB, BLK) f32
    blk_swin: jax.Array  # (n, n, NB) i32 aligned source-window index
    blk_dwin: jax.Array  # (n, n, NB) i32 destination-window index
    blk_lo: jax.Array  # (n, n, NB) i32 min src pos (P if empty)
    blk_hi: jax.Array  # (n, n, NB) i32 max src pos (-1 if empty)

    @property
    def n_src_windows(self) -> int:
        return self.P // self.SRC_WIN

    @property
    def n_dst_windows(self) -> int:
        return self.P // self.DST_WIN


def _cut_group(sp, dp, w, P, BLK, SRC_WIN, DST_WIN):
    """Cut one group's edge list into kernel blocks. Returns block arrays."""
    n_dwin = P // DST_WIN
    order = np.lexsort((sp, dp // DST_WIN))
    sp, dp, w = sp[order], dp[order], w[order]
    dwin_of = dp // DST_WIN

    blocks = []  # (sp_blk, dp_blk, w_blk, swin, dwin)
    for dwin in range(n_dwin):
        sel = dwin_of == dwin
        s, d, ww = sp[sel], dp[sel], w[sel]
        if s.size == 0:
            blocks.append((None, None, None, 0, dwin))  # placeholder
            continue
        # greedy cut: a block ends when full or when the next edge's source
        # leaves the current aligned SRC_WIN window
        start = 0
        base = s[0] // SRC_WIN
        count = 0
        for j in range(s.size):
            jwin = s[j] // SRC_WIN
            if count == BLK or jwin != base:
                blocks.append((s[start:j], d[start:j], ww[start:j], base, dwin))
                start, base, count = j, jwin, 0
            count += 1
        blocks.append((s[start:], d[start:], ww[start:], base, dwin))
    return blocks


def build_kernel_layout(
    pg: PartitionedGraph,
    BLK: int = 512,
    SRC_WIN: int = 512,
    DST_WIN: int = 512,
) -> KernelLayout:
    """Host-side re-tiling of a PartitionedGraph for the Pallas engine."""
    n, P = pg.n_shards, pg.P
    if P % SRC_WIN or P % DST_WIN:
        raise ValueError(f"P={P} must be a multiple of SRC_WIN/DST_WIN")
    sp_all = np.asarray(pg.src_pos)
    dp_all = np.asarray(pg.dst_pos)
    w_all = np.asarray(pg.eweight)

    per_group = {}
    NB = 1
    for i in range(n):
        for k in range(n):
            m = sp_all[i, k] >= 0
            blocks = _cut_group(
                sp_all[i, k][m], dp_all[i, k][m], w_all[i, k][m],
                P, BLK, SRC_WIN, DST_WIN,
            )
            per_group[(i, k)] = blocks
            NB = max(NB, len(blocks))

    sp = np.full((n, n, NB, BLK), -1, dtype=np.int32)
    dp = np.zeros((n, n, NB, BLK), dtype=np.int32)
    w = np.zeros((n, n, NB, BLK), dtype=np.float32)
    swin = np.zeros((n, n, NB), dtype=np.int32)
    dwin = np.zeros((n, n, NB), dtype=np.int32)
    lo = np.full((n, n, NB), P, dtype=np.int32)
    hi = np.full((n, n, NB), -1, dtype=np.int32)
    for (i, k), blocks in per_group.items():
        for b, (s, d, ww, sw, dw) in enumerate(blocks):
            swin[i, k, b] = sw
            dwin[i, k, b] = dw
            if s is not None and s.size:
                c = s.size
                sp[i, k, b, :c] = s
                dp[i, k, b, :c] = d
                w[i, k, b, :c] = ww
                lo[i, k, b] = s.min()
                hi[i, k, b] = s.max()
        # tail padding blocks re-accumulate identity into the last real window
        nb_real = len(blocks)
        if nb_real < NB:
            dwin[i, k, nb_real:] = dwin[i, k, nb_real - 1]
    return KernelLayout(
        n_shards=n, P=P, BLK=BLK, SRC_WIN=SRC_WIN, DST_WIN=DST_WIN, NB=NB,
        sp=jnp.asarray(sp), dp=jnp.asarray(dp), w=jnp.asarray(w),
        blk_swin=jnp.asarray(swin), blk_dwin=jnp.asarray(dwin),
        blk_lo=jnp.asarray(lo), blk_hi=jnp.asarray(hi),
    )


def layout_stats(kl: KernelLayout) -> dict:
    """Padding overhead accounting (reported in benchmarks)."""
    sp = np.asarray(kl.sp)
    real = int((sp >= 0).sum())
    slots = sp.size
    return dict(
        real_edges=real,
        edge_slots=slots,
        fill=real / max(slots, 1),
        blocks=kl.NB,
    )
