"""Graph substrate: generation, CSR, ID recoding, partitioning, edge blocks."""

from repro.graph.generate import rmat_graph, erdos_renyi_graph, chain_graph, star_graph
from repro.graph.csr import Graph, build_csr
from repro.graph.recode import recode_ids, RecodeMap
from repro.graph.partition import (
    PartitionedGraph, drop_edges, partition_graph, partition_graph_streamed,
    spill_partition,
)

__all__ = [
    "rmat_graph",
    "erdos_renyi_graph",
    "chain_graph",
    "star_graph",
    "Graph",
    "build_csr",
    "recode_ids",
    "RecodeMap",
    "PartitionedGraph",
    "partition_graph",
    "partition_graph_streamed",
    "spill_partition",
    "drop_edges",
]
