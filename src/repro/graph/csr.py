"""Graph container and CSR construction.

The on-host (pre-partitioning) representation mirrors the paper's HDFS input:
an edge list over *old* (possibly sparse) vertex IDs. ``build_csr`` produces the
indptr/indices arrays used by host-side preprocessing and by test oracles.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class Graph:
    """An in-memory edge-list graph over (possibly sparse) old vertex IDs."""

    src: np.ndarray  # (E,) int64 old ids
    dst: np.ndarray  # (E,) int64 old ids
    weight: np.ndarray  # (E,) float32
    directed: bool = True
    # All vertex old-ids present (sources, targets, and isolated vertices if given).
    vertex_ids: np.ndarray = field(default=None)  # type: ignore[assignment]

    def __post_init__(self):
        self.src = np.asarray(self.src, dtype=np.int64)
        self.dst = np.asarray(self.dst, dtype=np.int64)
        if self.weight is None:
            self.weight = np.ones(self.src.shape[0], dtype=np.float32)
        self.weight = np.asarray(self.weight, dtype=np.float32)
        if self.vertex_ids is None:
            self.vertex_ids = np.unique(np.concatenate([self.src, self.dst]))
        else:
            self.vertex_ids = np.unique(np.asarray(self.vertex_ids, dtype=np.int64))
        if not self.directed:
            # Undirected graphs store both directions (paper: Γ(v) = all neighbours).
            fwd = np.stack([self.src, self.dst], axis=0)
            bwd = np.stack([self.dst, self.src], axis=0)
            both = np.concatenate([fwd, bwd], axis=1)
            w = np.concatenate([self.weight, self.weight])
            # dedupe (u,v) pairs
            key = both[0] * (both.max() + 1) + both[1]
            _, idx = np.unique(key, return_index=True)
            self.src, self.dst = both[0][idx], both[1][idx]
            self.weight = w[idx]
            self.directed = True  # now stored as a symmetric directed graph

    @property
    def n_vertices(self) -> int:
        return int(self.vertex_ids.shape[0])

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])


def build_csr(n_vertices: int, src: np.ndarray, dst: np.ndarray, weight: np.ndarray):
    """CSR over dense ids 0..n-1. Returns (indptr, indices, weights), sorted by src.

    Pure-numpy oracle used by tests and host preprocessing.
    """
    order = np.argsort(src, kind="stable")
    src, dst, weight = src[order], dst[order], weight[order]
    counts = np.bincount(src, minlength=n_vertices)
    indptr = np.zeros(n_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, dst.astype(np.int64), weight.astype(np.float32)
