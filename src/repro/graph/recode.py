"""ID recoding (paper §5).

Establishes the one-to-one mapping between a vertex's dense new ID and its
position in the per-machine state array A:

    shard(g)    = g mod n          (hash(v) = id(v) modulo |W|)
    position(g) = g // n
    new_id(i, pos) = n * pos + i

The paper performs recoding as a 3-superstep Pregel job in normal mode. Here
the same dataflow (hash-partition by old id -> per-shard position assignment ->
adjacency-list translation via request/response messages) is executed as a
vectorized host-side preprocessing pass; ``recode_distributed`` re-expresses it
as the literal 3-superstep message exchange for validation.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _hash_old(ids: np.ndarray, n_shards: int) -> np.ndarray:
    """hash(.) on old ids — a mixing hash so sparse ids spread evenly (Lemma 1)."""
    x = ids.astype(np.uint64)
    x = (x ^ (x >> np.uint64(33))) * np.uint64(0xFF51AFD7ED558CCD)
    x = (x ^ (x >> np.uint64(33))) * np.uint64(0xC4CEB9FE1A85EC53)
    x = x ^ (x >> np.uint64(33))
    return (x % np.uint64(n_shards)).astype(np.int64)


@dataclass
class RecodeMap:
    """old id <-> new dense id mapping produced by the recoding pre-pass.

    New ids are dense *per shard* (shard i holds n*0+i, n*1+i, ...); globally
    the new-id space is 0..n*P-1 where P = max shard size, with holes at the
    tails of smaller shards (hash partitioning is only balanced w.h.p. —
    Lemma 1 gives P < 2|V|/n). ``old_for_new`` marks holes with -1.
    """

    n_shards: int
    max_positions: int  # P: max vertices on any shard
    old_sorted: np.ndarray  # (V,) old ids, sorted — lookup key
    new_for_old_sorted: np.ndarray  # (V,) new id of old_sorted[j]
    old_for_new: np.ndarray  # (n*P,) old id of new id g, -1 for holes

    @property
    def n_vertices(self) -> int:
        return int(self.old_sorted.shape[0])

    def to_new(self, old_ids: np.ndarray) -> np.ndarray:
        j = np.searchsorted(self.old_sorted, old_ids)
        if not np.all(self.old_sorted[j] == old_ids):
            raise KeyError("unknown old vertex id in recode lookup")
        return self.new_for_old_sorted[j]

    def to_old(self, new_ids: np.ndarray) -> np.ndarray:
        return self.old_for_new[new_ids]


def recode_ids(vertex_ids: np.ndarray, n_shards: int) -> RecodeMap:
    """Assign dense new ids: vertices hashed to shard i, ordered by old id within
    the shard (= their order in A), get new id n*pos + i."""
    vertex_ids = np.unique(np.asarray(vertex_ids, dtype=np.int64))
    shard = _hash_old(vertex_ids, n_shards)
    new_ids = np.empty(vertex_ids.shape[0], dtype=np.int64)
    max_pos = 0
    for i in range(n_shards):
        members = np.flatnonzero(shard == i)  # already sorted by old id
        pos = np.arange(members.shape[0], dtype=np.int64)
        new_ids[members] = n_shards * pos + i
        max_pos = max(max_pos, members.shape[0])
    old_for_new = np.full(n_shards * max_pos, -1, dtype=np.int64)
    old_for_new[new_ids] = vertex_ids
    return RecodeMap(
        n_shards=n_shards,
        max_positions=max_pos,
        old_sorted=vertex_ids,
        new_for_old_sorted=new_ids,
        old_for_new=old_for_new,
    )


def recode_distributed(
    src_old: np.ndarray, dst_old: np.ndarray, vertex_ids: np.ndarray, n_shards: int
):
    """The paper's 3-superstep recoding, message-for-message (directed graph):

    Step 1: every v sends id_old(v) to each out-neighbour u, asking for id_new(u).
    Step 2: u responds to each requester with id_new(u).
    Step 3: v appends received new ids to S^E_rec.

    Vectorized but preserving the message dataflow; used by tests to check the
    fast path (``recode_ids`` + direct translation) produces identical streams.
    Returns (src_new, dst_new) with edge order preserved per source.
    """
    rmap = recode_ids(vertex_ids, n_shards)
    # Step 1 messages: (dst_old <- src_old asks). Step 2 response routes back by
    # the old id (hash(.) takes the old ID, paper §5). Step 3 appends in the
    # order responses arrive; we keep input edge order which a FIFO channel
    # per (requester, responder) pair guarantees for the per-source runs.
    src_new = rmap.to_new(src_old)
    dst_new = rmap.to_new(dst_old)
    return src_new, dst_new, rmap
