"""Hash partitioning + per-destination edge groups (the OMS layout) + blocks.

Layout produced per shard (= per "machine" in the paper):

* the in-memory state array ``A``: ``values/active/degree/vmask/old_ids``,
  padded to ``P = ceil(|V|/n)`` (rounded to ``vertex_pad``) entries,
* the edge stream ``S^E`` organized into ``n`` per-destination groups (the
  outgoing-message-stream layout of §3.3.1): group ``(i, k)`` holds shard i's
  edges whose destination lives on shard k, sorted by source position and
  padded to a common capacity ``E_cap`` (a multiple of ``edge_block``),
* per-block source ranges ``blk_lo/blk_hi`` — the skip() metadata of §3.2:
  because groups are sorted by source position, a block can be skipped iff no
  vertex in ``[blk_lo, blk_hi]`` is active (checked with a prefix sum over the
  active bitmap at runtime).

Padded edge slots carry ``src_pos = -1`` and scatter the combiner identity to
position 0, so they are compute-neutral in every mode.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import numpy as np
import jax.numpy as jnp

from repro.graph.csr import Graph
from repro.graph.recode import RecodeMap, recode_ids


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def block_ranges(sp_blocks: np.ndarray, P: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-block [min, max] source range over the trailing axis — the skip()
    metadata of §3.2, shared by the device layout and the on-disk stream
    layout (streams/store.py). Sentinels (P, -1) mark empty blocks."""
    valid = sp_blocks >= 0
    lo = np.where(valid, sp_blocks, P).min(axis=-1).astype(np.int32)
    hi = np.where(valid, sp_blocks, -1).max(axis=-1).astype(np.int32)
    return lo, hi


@jax.tree_util.register_dataclass
@dataclass
class PartitionedGraph:
    """Device-resident partitioned graph. Leading axis of every array = shard."""

    # static metadata
    n_shards: int = dataclasses.field(metadata=dict(static=True))
    n_vertices: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))
    P: int = dataclasses.field(metadata=dict(static=True))  # padded verts/shard
    E_cap: int = dataclasses.field(metadata=dict(static=True))  # padded edges/group
    edge_block: int = dataclasses.field(metadata=dict(static=True))
    n_blocks: int = dataclasses.field(metadata=dict(static=True))

    # vertex state array A (paper Eq. 1 minus a(v), which the engine owns)
    degree: jax.Array  # (n, P) int32 — global out-degree d(v)
    vmask: jax.Array  # (n, P) bool — position holds a real vertex
    old_ids: jax.Array  # (n, P) int64 — original ids (for dumping results)
    gids: jax.Array  # (n, P) int64 — recoded global id (stable across elastic
    # repartitioning; equals n*pos + i at initial build, -1 for holes)

    # per-destination edge groups: [i, k, e]
    src_pos: jax.Array  # (n, n, E_cap) int32, -1 for padding
    dst_pos: jax.Array  # (n, n, E_cap) int32
    eweight: jax.Array  # (n, n, E_cap) float32

    # skip() block metadata
    blk_lo: jax.Array  # (n, n, n_blocks) int32 — min src_pos (P for empty)
    blk_hi: jax.Array  # (n, n, n_blocks) int32 — max src_pos (-1 for empty)

    @property
    def shape_summary(self) -> str:
        return (
            f"PartitionedGraph(n={self.n_shards}, |V|={self.n_vertices}, "
            f"|E|={self.n_edges}, P={self.P}, E_cap={self.E_cap}, "
            f"blocks={self.n_blocks}x{self.edge_block})"
        )


def build_partition(
    n: int,
    src_g: np.ndarray,  # (E,) edge sources, *global recoded* ids
    dst_g: np.ndarray,  # (E,) edge destinations, global recoded ids
    weight: np.ndarray,  # (E,)
    gids_real: np.ndarray,  # (V,) all real vertex global ids
    old_ids_real: np.ndarray,  # (V,) their original ids
    edge_block: int = 512,
    vertex_pad: int = 8,
) -> PartitionedGraph:
    """Assemble the device layout from global-recoded-id edge/vertex arrays.

    Global ids obey shard = g mod n, pos = g // n for ANY n — this is what
    makes elastic repartitioning (core/elastic.py) a pure index transform.
    """
    P = max(_round_up(int(gids_real.max()) // n + 1 if gids_real.size else 1,
                      vertex_pad), vertex_pad)
    src_shard, src_p = src_g % n, src_g // n
    dst_shard, dst_p = dst_g % n, dst_g // n

    # out-degree per global id (for PageRank's a(v)/d(v))
    deg_global = np.bincount(src_g, minlength=n * P).astype(np.int32)

    # group edges by (src_shard, dst_shard), sort each group by src position
    group_key = src_shard * n + dst_shard
    order = np.lexsort((src_p, group_key))
    gk, sp, dp, w = group_key[order], src_p[order], dst_p[order], weight[order]
    counts = np.bincount(gk, minlength=n * n)
    E_cap = max(_round_up(int(counts.max()) if counts.size else 0, edge_block),
                edge_block)
    n_blocks = E_cap // edge_block

    src_pos = np.full((n, n, E_cap), -1, dtype=np.int32)
    dst_pos = np.zeros((n, n, E_cap), dtype=np.int32)
    eweight = np.zeros((n, n, E_cap), dtype=np.float32)
    offs = np.zeros(n * n + 1, dtype=np.int64)
    np.cumsum(counts, out=offs[1:])
    for i in range(n):
        for k in range(n):
            a, b = offs[i * n + k], offs[i * n + k + 1]
            c = b - a
            src_pos[i, k, :c] = sp[a:b]
            dst_pos[i, k, :c] = dp[a:b]
            eweight[i, k, :c] = w[a:b]

    # block metadata: min/max src pos per block (P / -1 sentinels when empty)
    blk_lo, blk_hi = block_ranges(
        src_pos.reshape(n, n, n_blocks, edge_block), P
    )

    # state array A
    degree = np.zeros((n, P), dtype=np.int32)
    vmask = np.zeros((n, P), dtype=bool)
    old_ids = np.full((n, P), -1, dtype=np.int64)
    gid_arr = np.full((n, P), -1, dtype=np.int64)
    degree[gids_real % n, gids_real // n] = deg_global[gids_real]
    vmask[gids_real % n, gids_real // n] = True
    old_ids[gids_real % n, gids_real // n] = old_ids_real
    gid_arr[gids_real % n, gids_real // n] = gids_real

    return PartitionedGraph(
        n_shards=n,
        n_vertices=int(gids_real.shape[0]),
        n_edges=int(src_g.shape[0]),
        P=P,
        E_cap=E_cap,
        edge_block=edge_block,
        n_blocks=n_blocks,
        degree=jnp.asarray(degree),
        vmask=jnp.asarray(vmask),
        old_ids=jnp.asarray(old_ids),
        gids=jnp.asarray(gid_arr),
        src_pos=jnp.asarray(src_pos),
        dst_pos=jnp.asarray(dst_pos),
        eweight=jnp.asarray(eweight),
        blk_lo=jnp.asarray(blk_lo),
        blk_hi=jnp.asarray(blk_hi),
    )


def partition_graph(
    g: Graph,
    n_shards: int,
    edge_block: int = 512,
    vertex_pad: int = 8,
    recode: RecodeMap | None = None,
) -> tuple[PartitionedGraph, RecodeMap]:
    """Preprocess (host-side, the paper's loading + ID-recoding pass)."""
    rmap = recode if recode is not None else recode_ids(g.vertex_ids, n_shards)
    pg = build_partition(
        n_shards,
        rmap.to_new(g.src),
        rmap.to_new(g.dst),
        g.weight,
        rmap.new_for_old_sorted,
        rmap.old_sorted,
        edge_block=edge_block,
        vertex_pad=vertex_pad,
    )
    return pg, rmap


def drop_edges(pg: PartitionedGraph) -> PartitionedGraph:
    """Vertex-only view of a partition: the O(|V|/n) state array A survives,
    the O(|E|) edge groups are replaced by zero-length placeholders.

    Used after spilling the edge streams to disk (``spill_partition``): the
    static geometry (``E_cap``/``edge_block``/``n_blocks``) still describes
    the on-disk layout, but nothing edge-sized is resident. Such a partition
    only runs under ``mode="streamed"``.
    """
    n = pg.n_shards
    return dataclasses.replace(
        pg,
        src_pos=jnp.full((n, n, 0), -1, jnp.int32),
        dst_pos=jnp.zeros((n, n, 0), jnp.int32),
        eweight=jnp.zeros((n, n, 0), jnp.float32),
        blk_lo=jnp.zeros((n, n, 0), jnp.int32),
        blk_hi=jnp.zeros((n, n, 0), jnp.int32),
    )


def spill_partition(pg: PartitionedGraph, directory: str,
                    compress: bool = False, compress_payload: bool = False):
    """Write the edge groups of ``pg`` to an on-disk ``EdgeStreamStore`` and
    return ``(vertex_only_pg, store)`` — the paper's partition-time spill:
    edges are written once, sequentially, in the per-destination group
    layout, and streamed back every superstep. ``compress=True`` varint-delta
    encodes the position channels; ``compress_payload=True`` payload-encodes
    the weight channel (both streams/codec.py, both lossless)."""
    from repro.streams.store import EdgeStreamStore  # deferred: streams -> partition

    store = EdgeStreamStore.from_partition(
        pg, directory, compress=compress, compress_payload=compress_payload,
    )
    return drop_edges(pg), store


def partition_graph_streamed(
    g: Graph,
    n_shards: int,
    spill_dir: str,
    edge_block: int = 512,
    vertex_pad: int = 8,
    recode: RecodeMap | None = None,
    compress: bool = False,
    compress_payload: bool = False,
):
    """``partition_graph`` for the out-of-core path: partitions, spills the
    edge streams to ``spill_dir``, and returns ``(pg, rmap, store)`` where
    ``pg`` holds only the O(|V|/n) vertex arrays."""
    pg_full, rmap = partition_graph(
        g, n_shards, edge_block=edge_block, vertex_pad=vertex_pad,
        recode=recode,
    )
    pg, store = spill_partition(pg_full, spill_dir, compress=compress,
                                compress_payload=compress_payload)
    return pg, rmap, store


def partition_for_plan(g: Graph, plan, spill_dir: str,
                       recode: RecodeMap | None = None):
    """Materialize the physical layout an ``core.plan.ExecutionPlan`` chose:
    hash-partition with the plan's geometry knobs, and — when the plan picked
    the out-of-core mode — spill the edge groups to ``spill_dir`` (compressed
    iff the plan says so). Returns ``(pg, rmap, store)`` with ``store`` None
    for the in-memory modes; the one partitioning entry point
    ``core.job.GraphDJob`` builds every mode through."""
    if plan.mode == "streamed":
        return partition_graph_streamed(
            g, plan.n_shards, spill_dir, edge_block=plan.edge_block,
            vertex_pad=plan.vertex_pad, recode=recode,
            compress=plan.compress,
            compress_payload=bool(plan.compress_payload),
        )
    pg, rmap = partition_graph(
        g, plan.n_shards, edge_block=plan.edge_block,
        vertex_pad=plan.vertex_pad, recode=recode,
    )
    return pg, rmap, None


def abstract_partitioned_graph(
    n_shards: int,
    n_vertices: int,
    n_edges: int,
    edge_block: int = 4096,
    vertex_pad: int = 128,
    skew: float = 1.5,
) -> PartitionedGraph:
    """ShapeDtypeStruct-only PartitionedGraph for dry-runs (no allocation).

    ``skew`` models the per-group padding overhead (max/mean group size).
    """
    n = n_shards
    P = max(_round_up((n_vertices + n - 1) // n, vertex_pad), vertex_pad)
    mean_group = n_edges / (n * n)
    E_cap = max(_round_up(int(mean_group * skew), edge_block), edge_block)
    n_blocks = E_cap // edge_block
    s = jax.ShapeDtypeStruct
    return PartitionedGraph(
        n_shards=n, n_vertices=n_vertices, n_edges=n_edges, P=P,
        E_cap=E_cap, edge_block=edge_block, n_blocks=n_blocks,
        degree=s((n, P), jnp.int32),
        vmask=s((n, P), jnp.bool_),
        old_ids=s((n, P), jnp.int64),
        gids=s((n, P), jnp.int64),
        src_pos=s((n, n, E_cap), jnp.int32),
        dst_pos=s((n, n, E_cap), jnp.int32),
        eweight=s((n, n, E_cap), jnp.float32),
        blk_lo=s((n, n, n_blocks), jnp.int32),
        blk_hi=s((n, n, n_blocks), jnp.int32),
    )
