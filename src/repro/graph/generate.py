"""Deterministic graph generators (scaled-down stand-ins for Table 1 datasets).

The paper evaluates on web graphs (WebUK, ClueWeb), social networks (Twitter,
Friendster) and an RDF graph (BTC). We generate structurally similar graphs:
RMAT (power-law, web/social-like), Erdős–Rényi (uniform), chains/stars
(worst-case diameter / hub skew). All generators are seeded and pure numpy.
"""

from __future__ import annotations

import numpy as np

from repro.graph.csr import Graph


def rmat_graph(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    directed: bool = True,
    weights: str = "unit",
    sparse_ids: bool = False,
) -> Graph:
    """RMAT power-law graph with 2**scale vertices and edge_factor * n edges.

    ``sparse_ids=True`` remaps vertices to sparse 64-bit ids (to exercise the
    ID-recoding preprocessing, mirroring the paper's non-dense inputs).
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for level in range(scale):
        r = rng.random(m)
        # quadrant probabilities a, b, c, d
        go_right = (r >= a) & (r < a + b) | (r >= a + b + c)
        go_down = r >= a + b
        src |= go_down.astype(np.int64) << level
        dst |= go_right.astype(np.int64) << level
    # drop self loops, dedupe
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src * n + dst
    _, idx = np.unique(key, return_index=True)
    src, dst = src[idx], dst[idx]
    if weights == "unit":
        w = np.ones(src.shape[0], dtype=np.float32)
    else:
        w = rng.uniform(0.5, 2.0, size=src.shape[0]).astype(np.float32)
    ids = np.arange(n, dtype=np.int64)
    if sparse_ids:
        # strictly increasing sparse relabel keeps determinism
        gaps = rng.integers(1, 1000, size=n, dtype=np.int64)
        relabel = np.cumsum(gaps)
        src, dst, ids = relabel[src], relabel[dst], relabel
    return Graph(src=src, dst=dst, weight=w, directed=directed, vertex_ids=ids)


def erdos_renyi_graph(
    n: int, avg_degree: float = 8.0, seed: int = 0, directed: bool = True
) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree)
    src = rng.integers(0, n, size=m, dtype=np.int64)
    dst = rng.integers(0, n, size=m, dtype=np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src * n + dst
    _, idx = np.unique(key, return_index=True)
    src, dst = src[idx], dst[idx]
    w = np.ones(src.shape[0], dtype=np.float32)
    return Graph(
        src=src, dst=dst, weight=w, directed=directed,
        vertex_ids=np.arange(n, dtype=np.int64),
    )


def chain_graph(n: int, directed: bool = True) -> Graph:
    """Path graph 0→1→…→n-1: maximal diameter, the sparse-frontier worst case
    that motivates skip() (one active vertex per superstep in BFS)."""
    src = np.arange(n - 1, dtype=np.int64)
    dst = src + 1
    w = np.ones(n - 1, dtype=np.float32)
    return Graph(src=src, dst=dst, weight=w, directed=directed,
                 vertex_ids=np.arange(n, dtype=np.int64))


def star_graph(n: int, directed: bool = True) -> Graph:
    """Hub 0 → spokes 1..n-1: maximal degree skew (BTC/Twitter hub regime)."""
    src = np.zeros(n - 1, dtype=np.int64)
    dst = np.arange(1, n, dtype=np.int64)
    w = np.ones(n - 1, dtype=np.float32)
    return Graph(src=src, dst=dst, weight=w, directed=directed,
                 vertex_ids=np.arange(n, dtype=np.int64))
