"""Serving: prefill and decode steps + a batched request loop.

`prefill(params, tokens)` runs the full causal forward AND fills the caches;
`decode_step(params, caches, token, pos)` advances one token for the whole
batch against the caches. These two functions are what the dry-run lowers
for the `prefill_32k` / `decode_32k` / `long_500k` cells.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import embed, rms_norm, unembed
from repro.models.transformer import apply_stack, encoder_forward


def prefill(cfg: ModelConfig, params, tokens, caches, media=None):
    """Returns (logits for the last position, filled caches)."""
    B, S = tokens.shape
    x = embed(tokens, params["embed"]).astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    enc_states = (
        encoder_forward(cfg, params, media) if cfg.n_enc_layers else None
    )
    media_states = (
        media.astype(cfg.dtype)
        if media is not None and not cfg.n_enc_layers
        else None
    )
    x, new_caches, _ = apply_stack(
        cfg, params, x, positions,
        media_states=media_states, enc_states=enc_states, caches=caches,
    )
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed(x, table)[:, 0], new_caches


def decode_step(cfg: ModelConfig, params, caches, token, pos):
    """token: (B, 1) int32; pos: scalar int32 (uniform across the batch —
    continuous-batching slots padded to a common position).
    Returns (logits (B, V), new caches)."""
    B = token.shape[0]
    x = embed(token, params["embed"]).astype(cfg.dtype)
    positions = jnp.broadcast_to(pos.astype(jnp.int32), (B, 1))
    x, new_caches, _ = apply_stack(
        cfg, params, x, positions, caches=caches,
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed(x, table)[:, 0], new_caches


def greedy_generate(cfg: ModelConfig, params, prompt, caches, steps: int,
                    media=None):
    """Batched greedy decoding loop (the serving example driver)."""
    logits, caches = jax.jit(
        functools.partial(prefill, cfg), static_argnames=()
    )(params, prompt, caches, media=media)
    step_fn = jax.jit(functools.partial(decode_step, cfg))
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    pos = jnp.int32(prompt.shape[1])
    for _ in range(steps - 1):
        logits, caches = step_fn(params, caches, tok, pos)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
        pos = pos + 1
    return jnp.concatenate(out, axis=1)
