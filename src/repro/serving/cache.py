"""KV/state cache construction per config (GQA ring-buffer, MLA latent,
SSD state, cross-KV)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import make_gqa_cache, make_mla_cache
from repro.models.config import LayerSpec, ModelConfig


def _layer_cache(cfg: ModelConfig, spec: LayerSpec, B: int, max_len: int,
                 has_xattn: bool, n_media: int):
    c = {}
    Lc = spec.window if spec.window else max_len
    if spec.kind in ("attn",):
        c["kv"] = make_gqa_cache(B, Lc, cfg.n_kv_heads, cfg.head_dim,
                                 cfg.dtype)
    elif spec.kind == "cross":
        c["xkv"] = dict(
            k=jnp.zeros((B, n_media, cfg.n_kv_heads, cfg.head_dim),
                        cfg.dtype),
            v=jnp.zeros((B, n_media, cfg.n_kv_heads, cfg.head_dim),
                        cfg.dtype),
        )
    elif spec.kind == "mla":
        c["kv"] = make_mla_cache(B, Lc, cfg.mla_kv_lora, cfg.mla_rope_dim,
                                 cfg.dtype)
    elif spec.kind == "ssm":
        c["ssm"] = _ssm_cache(cfg, B)
    if spec.kind == "hybrid":
        c["kv"] = make_gqa_cache(B, Lc, cfg.n_kv_heads, cfg.head_dim,
                                 cfg.dtype)
        c["ssm"] = _ssm_cache(cfg, B)
    if has_xattn:  # whisper decoder cross-KV over encoder frames
        c["ekv"] = dict(
            k=jnp.zeros((B, n_media, cfg.n_kv_heads, cfg.head_dim),
                        cfg.dtype),
            v=jnp.zeros((B, n_media, cfg.n_kv_heads, cfg.head_dim),
                        cfg.dtype),
        )
    return c


def _ssm_cache(cfg: ModelConfig, B: int):
    H, hd, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    K, di = cfg.ssm_conv, cfg.d_ssm_inner
    return dict(
        state=jnp.zeros((B, H, hd, N), jnp.float32),
        conv=jnp.zeros((B, K - 1, di + 2 * N), cfg.dtype),
    )


def make_caches(cfg: ModelConfig, B: int, max_len: int,
                n_media: int | None = None):
    """Cache pytree mirroring params structure: prologue list + stacked
    groups. For whisper, decode caches cover `n_media` encoder frames but
    only `max_len` self positions (448 for whisper decode shapes)."""
    n_media = n_media if n_media is not None else cfg.n_media_tokens
    has_x = cfg.n_enc_layers > 0
    pro = [
        _layer_cache(cfg, s, B, max_len, has_x, n_media)
        for s in cfg.prologue
    ]
    G = cfg.n_pattern_groups
    groups = []
    for spec in cfg.pattern:
        one = _layer_cache(cfg, spec, B, max_len, has_x, n_media)
        groups.append(
            jax.tree.map(lambda x: jnp.broadcast_to(x, (G, *x.shape)), one)
        )
    return dict(prologue=pro, groups=groups)


def abstract_caches(cfg: ModelConfig, B: int, max_len: int,
                    n_media: int | None = None):
    """ShapeDtypeStruct caches for the dry-run (no allocation)."""
    return jax.eval_shape(
        lambda: make_caches(cfg, B, max_len, n_media)
    )


def cache_bytes(caches) -> int:
    return sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(caches)
    )
