"""Seed-driven fault schedules and the per-process injector runtime.

A :class:`FaultSchedule` is a JSON-able ``{"seed": int, "events": [...]}``
payload carried in ``launch_opts["faults"]`` and written into the worker
spec, so every process in a multi-process launch arms the same schedule.
Each :class:`FaultEvent` scopes one deterministic fault to a *site* (an
instrumented code location) with optional filters, and an ``after``
count: the event lets ``after`` matching occurrences pass, then fires on
the next one. Events are one-shot by default and the whole schedule is
disarmed on worker respawn, so a drill fires in exactly one incarnation.

Sites and the kinds they accept:

===============  =============================================  ==========================================
site             instrumented where                             kinds
===============  =============================================  ==========================================
``net.send``     ``PeerSender`` data-plane frame sends          torn_kill, kill, drop, reset, delay
``net.recv``     ``PeerServer.read_source`` frame receives      kill, drop, reset, delay
``coord.send``   ``CoordClient`` coordinator-plane sends        kill, drop, reset, delay
``io.write.spill``  ``MessageRunStore`` blob writes             enospc, eio, short, bitflip, kill
``io.write.store``  ``EdgeStreamStore.create`` channel writes   enospc, eio, short, bitflip, kill
``io.write.ckpt``   worker checkpoint shard dump                enospc, eio, kill
===============  =============================================  ==========================================

Filters: ``shard`` (only this worker), ``step`` (only this superstep),
``dest`` (only frames/blobs for this destination shard), ``where`` (only
paths containing this substring — e.g. ``"logs/"`` to target the inbox
message log rather than the outbox). Only occurrences matching *all*
present filters advance the event's counter, which keeps schedules
deterministic even when several stores write concurrently.

The bit flipped by ``bitflip`` and all other pseudo-random choices derive
from ``crc32(seed: ...)`` — replaying a schedule replays the fault.
"""

from __future__ import annotations

import errno as _errno
import os
import signal
import threading
import time
import zlib
from dataclasses import dataclass, field

_SITES = {
    "net.send": {"torn_kill", "kill", "drop", "reset", "delay"},
    "net.recv": {"kill", "drop", "reset", "delay"},
    "coord.send": {"kill", "drop", "reset", "delay"},
    "io.write.spill": {"enospc", "eio", "short", "bitflip", "kill"},
    "io.write.store": {"enospc", "eio", "short", "bitflip", "kill"},
    "io.write.ckpt": {"enospc", "eio", "kill"},
}

_EVENT_KEYS = {"site", "kind", "after", "shard", "step", "dest", "where", "seconds", "once"}

_ERRNOS = {"enospc": _errno.ENOSPC, "eio": _errno.EIO}


class InjectedFault(OSError):
    """An injected I/O or transport fault (``errno`` set for disk kinds)."""


class TierFault(RuntimeError):
    """A storage-tier write failed; names the tier for structured reporting."""

    def __init__(self, tier: str, step: int | None = None, cause: BaseException | None = None):
        self.tier = tier
        self.step = step
        at = f" at superstep {step}" if step is not None else ""
        super().__init__(f"{tier} tier write failed{at}: {cause}")

    def summary(self) -> dict:
        return {
            "kind": "disk-fault",
            "tier": self.tier,
            "step": self.step,
            "error": str(self),
        }


class BlobCorruption(RuntimeError):
    """Stored bytes no longer match the CRC recorded at write time.

    Raised by read-path verification in ``streams/msgstore.py`` (run
    blobs), ``streams/store.py`` (edge channel files), and checkpoint
    restore. Workers quarantine ``directory`` and exit for replay rather
    than consuming the corrupt bytes.
    """

    def __init__(self, path: str, detail: str, directory: str | None = None):
        self.path = path
        self.detail = detail
        self.directory = directory if directory is not None else os.path.dirname(path)
        super().__init__(f"blob corruption detected in {path}: {detail}")

    def summary(self) -> dict:
        return {
            "kind": "corruption",
            "path": self.path,
            "directory": self.directory,
            "detail": self.detail,
        }


@dataclass
class FaultEvent:
    """One site-scoped deterministic fault (see module docstring)."""

    site: str
    kind: str
    after: int = 0
    shard: int | None = None
    step: int | None = None
    dest: int | None = None
    where: str | None = None
    seconds: float = 0.05
    once: bool = True
    # runtime state (not serialized)
    count: int = field(default=0, compare=False)
    fired: bool = field(default=False, compare=False)

    def __post_init__(self) -> None:
        if self.site not in _SITES:
            raise ValueError(
                f"unknown fault site {self.site!r}; known: {sorted(_SITES)}"
            )
        if self.kind not in _SITES[self.site]:
            raise ValueError(
                f"fault kind {self.kind!r} not valid at site {self.site!r}; "
                f"valid: {sorted(_SITES[self.site])}"
            )
        if self.after < 0:
            raise ValueError("after must be >= 0")

    @classmethod
    def from_dict(cls, d: dict) -> "FaultEvent":
        unknown = set(d) - _EVENT_KEYS
        if unknown:
            raise ValueError(
                f"unknown fault event keys {sorted(unknown)}; known: {sorted(_EVENT_KEYS)}"
            )
        if "site" not in d or "kind" not in d:
            raise ValueError("fault event needs at least 'site' and 'kind'")
        return cls(**d)

    def to_dict(self) -> dict:
        out = {"site": self.site, "kind": self.kind, "after": self.after, "once": self.once}
        for k in ("shard", "step", "dest", "where"):
            v = getattr(self, k)
            if v is not None:
                out[k] = v
        if self.kind == "delay":
            out["seconds"] = self.seconds
        return out

    def matches(self, site: str, *, shard=None, step=None, dest=None, path="") -> bool:
        if self.site != site or self.fired:
            return False
        if self.shard is not None and shard != self.shard:
            return False
        if self.step is not None and step != self.step:
            return False
        if self.dest is not None and dest != self.dest:
            return False
        if self.where is not None and self.where not in (path or ""):
            return False
        return True


@dataclass
class FaultSchedule:
    """A deterministic, JSON-able set of fault events plus the chaos seed."""

    seed: int = 0
    events: list = field(default_factory=list)

    def __post_init__(self) -> None:
        self.events = [
            e if isinstance(e, FaultEvent) else FaultEvent.from_dict(dict(e))
            for e in self.events
        ]

    @classmethod
    def from_opts(cls, opts) -> "FaultSchedule":
        """Build from ``launch_opts['faults']``: a dict or a bare event list."""
        if opts is None:
            return cls()
        if isinstance(opts, list):
            return cls(events=list(opts))
        if isinstance(opts, dict):
            unknown = set(opts) - {"seed", "events"}
            if unknown:
                raise ValueError(
                    f"unknown fault schedule keys {sorted(unknown)}; known: ['events', 'seed']"
                )
            return cls(seed=int(opts.get("seed", 0)), events=list(opts.get("events", ())))
        raise ValueError("faults must be a {'seed', 'events'} dict or a list of events")

    def to_opts(self) -> dict:
        return {"seed": self.seed, "events": [e.to_dict() for e in self.events]}


def _sigkill() -> None:
    os.kill(os.getpid(), signal.SIGKILL)


class FaultInjector:
    """Per-process runtime that arms a schedule at the instrumented sites.

    Install with :func:`install`; sites consult :func:`active` and pay a
    single ``is None`` check when chaos is off. ``shard`` filters the
    schedule to this worker; :meth:`set_step` supplies step context for
    sites (file writes) that do not know the superstep themselves.
    """

    def __init__(self, schedule: FaultSchedule, shard: int | None = None):
        self.schedule = schedule
        self.shard = shard
        self._lock = threading.Lock()
        self._step = None

    def set_step(self, step: int) -> None:
        with self._lock:
            self._step = step

    def _fire(self, site: str, *, step=None, dest=None, path="") -> FaultEvent | None:
        """Advance matching events; return the first that reaches its trigger."""
        with self._lock:
            if step is None:
                step = self._step
            for ev in self.schedule.events:
                if not ev.matches(site, shard=self.shard, step=step, dest=dest, path=path):
                    continue
                ev.count += 1
                if ev.count > ev.after:
                    if ev.once:
                        ev.fired = True
                    else:
                        ev.count = 0
                    return ev
            return None

    # -- net sites ---------------------------------------------------------

    def net_send(self, conn, header: bytes, payload: bytes, *, site="net.send",
                 step=None, dest=None) -> None:
        """Consult before sending one data-plane frame; may not return."""
        ev = self._fire(site, step=step, dest=dest)
        if ev is None:
            return
        if ev.kind == "torn_kill":
            # The PR 8 drill, generalized: land the header plus half the
            # payload so the receiver holds a torn frame, then die hard.
            try:
                conn.sendall(header + payload[: max(1, len(payload) // 2)])
            except OSError:
                pass
            _sigkill()
        self._net_common(conn, ev, site)

    def net_recv(self, conn, *, site="net.recv", step=None, src=None) -> None:
        """Consult after receiving one frame; may raise or not return."""
        ev = self._fire(site, step=step, dest=src)
        if ev is None:
            return
        self._net_common(conn, ev, site)

    def _net_common(self, conn, ev: FaultEvent, site: str) -> None:
        if ev.kind == "kill":
            _sigkill()
        if ev.kind == "delay":
            time.sleep(ev.seconds)
            return
        # drop / reset: sever the socket so both ends observe the loss,
        # then surface a connection error to the calling path.
        try:
            conn.shutdown(2)  # SHUT_RDWR
        except OSError:
            pass
        if ev.kind == "reset":
            raise InjectedFault(_errno.ECONNRESET, f"injected: {site} socket reset")
        raise InjectedFault(_errno.EPIPE, f"injected: {site} socket dropped")

    # -- file sites --------------------------------------------------------

    def file_write(self, fh, data, *, site: str, path: str = "", step=None) -> None:
        """Perform (or sabotage) one blob write on behalf of the caller."""
        ev = self._fire(site, step=step, path=path)
        if ev is None:
            fh.write(data)
            return
        if ev.kind == "kill":
            _sigkill()
        if ev.kind == "bitflip":
            data = bytes(data)
            bit = zlib.crc32(f"{self.schedule.seed}:{site}:{ev.count}".encode()) % max(
                1, len(data) * 8
            )
            buf = bytearray(data)
            buf[bit // 8] ^= 1 << (bit % 8)
            fh.write(bytes(buf))
            return
        if ev.kind == "short":
            # Tear the write: land a prefix, then fail as if the disk filled.
            fh.write(bytes(data)[: max(1, len(data) // 2)])
            fh.flush()
            raise InjectedFault(
                _errno.ENOSPC, f"injected: short write ({site}, {path or '?'})"
            )
        raise InjectedFault(
            _ERRNOS[ev.kind], f"injected: {ev.kind} on write ({site}, {path or '?'})"
        )

    def check(self, site: str, *, step=None, path="") -> None:
        """Dataless site check (e.g. before a checkpoint dump); may raise."""
        ev = self._fire(site, step=step, path=path)
        if ev is None:
            return
        if ev.kind == "kill":
            _sigkill()
        raise InjectedFault(
            _ERRNOS[ev.kind], f"injected: {ev.kind} on write ({site}, {path or '?'})"
        )


_ACTIVE: FaultInjector | None = None


def install(injector: FaultInjector | None) -> None:
    """Install the process-wide injector (None clears)."""
    global _ACTIVE
    _ACTIVE = injector


def active() -> FaultInjector | None:
    """The process-wide injector, or None when chaos is off (the hot path)."""
    return _ACTIVE


def clear() -> None:
    install(None)
