"""Deterministic fault injection + unified retry discipline (chaos layer).

GraphD's premise is commodity hardware, where sockets reset, disks fill,
processes die, and bits rot. Before this package the repo had two ad-hoc
injection knobs — ``FaultPoint`` (streams/channel.py, kill the pipelined
sender thread after N packets) and ``kill_net`` (launch/procs.py, SIGKILL a
worker mid-frame) — each wired by hand into one code path. This package
subsumes both behind one deterministic, seed-driven layer that CI can soak
against:

* :class:`FaultSchedule` — a JSON-able list of site-scoped events ("on the
  3rd spill write of shard 1's superstep 2, fail with ENOSPC"; "after 1 RUN
  frame of step 2, tear the frame and SIGKILL"; "flip one seed-chosen bit
  in the 2nd inbox blob"). Schedules ride through ``launch_opts["faults"]``
  into worker processes and are disarmed on respawn, so a drill fires in
  exactly one incarnation.
* :class:`FaultInjector` — the per-process runtime. Install one with
  :func:`install`; instrumented sites (``launch/net.py`` frame sends/
  receives, ``streams/msgstore.py``/``streams/store.py`` blob writes, the
  worker checkpoint dump) consult :func:`active` and stay zero-cost when
  nothing is installed.
* :class:`RetryPolicy` — bounded reconnect discipline (max attempts,
  exponential backoff with *deterministic* jitter, overall monotonic-clock
  deadline) shared by peer reconnect, coordinator reconnect, and respawn
  paths. Exhaustion raises :class:`RetryExhausted`, which carries a
  structured summary — the clean loud abort the chaos drills assert on.
  The ``retry-discipline`` analysis pass flags bare ``while True:``
  reconnect loops that bypass it.
* :class:`BlobCorruption` — raised by read-path CRC verification
  (msgstore run blobs, edge-store channel files, checkpoint shards) when
  stored bytes no longer match the checksum recorded at write time; the
  worker quarantines the blob and recovery replays it from the sender's
  outbox log or the checkpoint lineage. An injected bit-flip is therefore
  always a detected, recoverable event — never silent corruption.

Everything here is pure stdlib: the package is importable from the
pre-heartbeat worker path, the coordinator process, and the streams layer
without dependency cycles.
"""

from repro.fault.retry import RetryExhausted, RetryPolicy
from repro.fault.schedule import (
    BlobCorruption,
    FaultEvent,
    FaultInjector,
    FaultSchedule,
    InjectedFault,
    TierFault,
    active,
    clear,
    install,
)
from repro.fault.summary import failure_record, find_in_chain, write_record

__all__ = [
    "BlobCorruption",
    "FaultEvent",
    "FaultInjector",
    "FaultSchedule",
    "InjectedFault",
    "RetryExhausted",
    "RetryPolicy",
    "TierFault",
    "active",
    "clear",
    "failure_record",
    "find_in_chain",
    "install",
    "write_record",
]
