"""Unified bounded-retry discipline for every reconnect/respawn path.

Before this module each reconnect loop in the tree hand-rolled its own
policy: ``PeerSender._ensure_conn`` polled forever, ``CoordClient`` gave
up on the first error, and neither had a deadline. A fault-tolerant
control plane needs the opposite invariant everywhere: *bounded* retries
with backoff and jitter, degrading to a clean loud abort that names the
site, the attempt count, and the elapsed budget.

Jitter is deterministic — derived from ``crc32(seed:site:attempt)``, not
``random`` — so a chaos drill replayed with the same ``FaultSchedule``
seed observes the same retry timeline. Deadlines use the monotonic clock
(the ``liveness-clock`` analysis pass forbids wall clocks here).

Canonical call shape (the ``retry-discipline`` analysis pass looks for
this instead of bare ``while True:`` reconnect loops)::

    policy = RetryPolicy(deadline=120.0)
    for attempt in policy.attempts("coord-reconnect", should_stop=...):
        try:
            sock = socket.create_connection(addr, timeout=5.0)
            break
        except OSError as e:
            last = e
    else:
        raise RetryExhausted("coord-reconnect", policy, last)

``attempts`` yields 1, 2, 3, ... sleeping the backoff *between* yields;
it stops (exhausting the ``for``) when the attempt budget or deadline
runs out, or when ``should_stop()`` turns true — callers distinguish
"stopped" from "exhausted" by checking their own flag in the ``else``.
"""

from __future__ import annotations

import time
import zlib
from dataclasses import dataclass, field


def _jitter_frac(seed: int, site: str, attempt: int) -> float:
    """Deterministic pseudo-random fraction in [0, 1) for backoff jitter."""
    h = zlib.crc32(f"{seed}:{site}:{attempt}".encode())
    return (h % 10_000) / 10_000.0


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry budget: attempts x exponential backoff x deadline.

    ``max_attempts=0`` means unbounded attempts (the deadline governs).
    ``deadline`` is the overall per-episode budget in seconds, measured
    on the monotonic clock from the first ``attempts()`` call. ``jitter``
    is the +/- fraction applied to each backoff delay, derived
    deterministically from ``seed`` and the site name.
    """

    max_attempts: int = 0
    base_delay: float = 0.1
    max_delay: float = 2.0
    deadline: float = 120.0
    jitter: float = 0.5
    seed: int = 0

    def __post_init__(self) -> None:
        if self.max_attempts < 0:
            raise ValueError("max_attempts must be >= 0 (0 = unbounded)")
        if self.base_delay <= 0 or self.max_delay < self.base_delay:
            raise ValueError("need 0 < base_delay <= max_delay")
        if self.deadline <= 0:
            raise ValueError("deadline must be > 0 seconds")
        if not (0.0 <= self.jitter <= 1.0):
            raise ValueError("jitter must be in [0, 1]")

    @classmethod
    def from_opts(cls, opts: dict | None, **overrides) -> "RetryPolicy":
        """Build from a ``launch_opts['retry']``-style dict (JSON-borne)."""
        merged = dict(opts or {})
        merged.update(overrides)
        return cls(**merged)

    def to_opts(self) -> dict:
        return {
            "max_attempts": self.max_attempts,
            "base_delay": self.base_delay,
            "max_delay": self.max_delay,
            "deadline": self.deadline,
            "jitter": self.jitter,
            "seed": self.seed,
        }

    def delay_for(self, site: str, attempt: int) -> float:
        """Backoff to sleep after failed attempt number ``attempt`` (1-based)."""
        raw = min(self.base_delay * (2.0 ** (attempt - 1)), self.max_delay)
        frac = _jitter_frac(self.seed, site, attempt)
        return raw * (1.0 + self.jitter * (2.0 * frac - 1.0))

    def attempts(self, site: str, should_stop=None):
        """Yield attempt numbers 1..N, sleeping backoff between yields.

        The generator ends (so a ``for/else`` falls through) when the
        attempt or deadline budget is exhausted, or when ``should_stop()``
        returns true during a backoff sleep. Sleeps are sliced to at most
        0.25 s so a closing owner is never blocked behind a long backoff.
        """
        start = time.monotonic()
        attempt = 0
        while True:
            attempt += 1
            if self.max_attempts and attempt > self.max_attempts:
                return
            if time.monotonic() - start > self.deadline:
                return
            yield attempt
            # Attempt failed (a success breaks out of the caller's loop):
            # back off before the next one, watching for stop requests.
            remaining = self.delay_for(site, attempt)
            while remaining > 0:
                if should_stop is not None and should_stop():
                    return
                step = min(remaining, 0.25)
                time.sleep(step)
                remaining -= step
            if should_stop is not None and should_stop():
                return

    def elapsed_since(self, start_monotonic: float) -> float:
        return time.monotonic() - start_monotonic


class RetryExhausted(ConnectionError):
    """A retry episode ran out of budget: the clean, loud, structured abort.

    Subclasses ``ConnectionError`` so transport-level handlers that
    already treat connection loss as fatal propagate it unchanged.
    """

    def __init__(self, site: str, policy: RetryPolicy, last: BaseException | None = None,
                 attempts: int = 0, elapsed: float = 0.0):
        self.site = site
        self.policy = policy
        self.last = last
        self.attempts = attempts
        self.elapsed = elapsed
        detail = f": last error: {last}" if last is not None else ""
        super().__init__(
            f"retry budget exhausted at {site} "
            f"({attempts} attempts over {elapsed:.1f}s, "
            f"deadline {policy.deadline:.1f}s){detail}"
        )

    def summary(self) -> dict:
        """Structured failure summary (JSON-able) for failure records."""
        return {
            "kind": "retry-exhausted",
            "site": self.site,
            "attempts": self.attempts,
            "elapsed_seconds": round(self.elapsed, 3),
            "deadline_seconds": self.policy.deadline,
            "max_attempts": self.policy.max_attempts,
            "last_error": repr(self.last) if self.last is not None else None,
        }
