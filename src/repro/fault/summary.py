"""Structured failure summaries: how a fault becomes a diagnosable record.

When the retry budget is exhausted, a storage tier faults, or read-path
CRC verification catches corruption, the failing process writes a small
JSON record (atomically: tmp -> fsync -> replace) before exiting. The
launcher folds these into the ``WorkerFailed`` it raises and into the
run-level ``failure-summary.json`` that the CI chaos-soak job uploads as
an artifact — so a chaos failure is a named, machine-readable event, not
a stack trace to spelunk.

Stdlib-only on purpose: both the pre-heartbeat worker path and the
coordinator process import this before any heavy dependency loads.
"""

from __future__ import annotations

import json
import os


def failure_record(kind: str, *, shard=None, step=None, message="", **extra) -> dict:
    """A normalized failure record; ``extra`` keys ride along verbatim."""
    rec = {"kind": kind, "shard": shard, "step": step, "message": message}
    rec.update(extra)
    return rec


def write_record(path: str, record: dict) -> None:
    """Atomically publish one failure record (tmp -> fsync -> replace)."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def find_in_chain(exc: BaseException, *types) -> BaseException | None:
    """Walk ``__cause__``/``__context__`` for the first exception of ``types``.

    Fault classification has to see through wrapping: a ``BlobCorruption``
    may surface as ``ChannelError(cause=...)``, an injected ``ENOSPC`` as a
    ``TierFault``. Bounded walk; cycles cannot occur in practice but the
    depth cap keeps this total.
    """
    seen = 0
    node: BaseException | None = exc
    while node is not None and seen < 50:
        if isinstance(node, types):
            return node
        node = node.__cause__ if node.__cause__ is not None else node.__context__
        seen += 1
    return None
