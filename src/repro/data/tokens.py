"""Deterministic synthetic token pipeline.

Sharded host feed: each data-parallel host slice draws its deterministic
slice of the global batch from a counter-based generator (no state to
checkpoint beyond the step counter — restart-safe by construction, which is
the data-pipeline side of the paper's restartability story)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_batch(cfg, step: int, seq_len: int, global_batch: int,
                    with_media: bool = False, n_media: int | None = None):
    """Counter-based batch: tokens[i, t] = f(step, i, t) — reproducible at
    any restart point without replaying the stream."""
    rng = np.random.default_rng(np.uint64(0xC0FFEE) + np.uint64(step))
    tokens = rng.integers(
        0, cfg.vocab, size=(global_batch, seq_len), dtype=np.int32
    )
    batch = dict(
        tokens=jnp.asarray(tokens),
        labels=jnp.asarray(np.roll(tokens, -1, axis=1)),
    )
    if with_media or cfg.n_media_tokens:
        nm = n_media or cfg.n_media_tokens
        media = rng.standard_normal(
            (global_batch, nm, cfg.d_model), dtype=np.float32
        )
        batch["media"] = jnp.asarray(media, dtype=cfg.dtype)
    return batch


def batch_specs(cfg, seq_len: int, global_batch: int,
                with_media: bool | None = None):
    """ShapeDtypeStruct twin of synthetic_batch (dry-run input_specs)."""
    s = jax.ShapeDtypeStruct
    out = dict(
        tokens=s((global_batch, seq_len), jnp.int32),
        labels=s((global_batch, seq_len), jnp.int32),
    )
    use_media = cfg.n_media_tokens if with_media is None else with_media
    if use_media:
        out["media"] = s(
            (global_batch, cfg.n_media_tokens, cfg.d_model), cfg.dtype
        )
    return out
