"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel body runs in Python/XLA exactly as written, which is how they are
validated against ``ref.py``. On a TPU backend the same calls compile through
Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import digest as _digest
from repro.kernels import edge_combine as _ec


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def window_first_mask(blk_dwin: jax.Array) -> jax.Array:
    """True for each window's first block — those must survive skip
    compaction so every output window gets initialized."""
    NB = blk_dwin.shape[0]
    prev = jnp.concatenate([blk_dwin[:1] - 1, blk_dwin[:-1]])
    return blk_dwin != prev


def compact_blocks(keep: jax.Array):
    """Compacted ascending block-id list from a keep mask (skip(), §3.2).

    Tail entries repeat the last kept block so tail grid steps revisit it
    (no HBM refetch) and contribute the combiner identity."""
    NB = keep.shape[0]
    n_keep = jnp.sum(keep.astype(jnp.int32))
    (ids,) = jnp.nonzero(keep, size=NB, fill_value=0)
    last = ids[jnp.maximum(n_keep - 1, 0)]
    ids = jnp.where(jnp.arange(NB) < n_keep, ids, last)
    return ids.astype(jnp.int32), n_keep


def skip_keep_mask(blk_lo, blk_hi, blk_dwin, active_prefix):
    """keep = window-initializer OR has-an-active-source (the skip() test:
    prefix[hi+1] - prefix[lo] > 0 over the active bitmap)."""
    P = active_prefix.shape[0] - 1
    nonempty = blk_hi >= 0
    cnt = active_prefix[jnp.clip(blk_hi + 1, 0, P)] - active_prefix[
        jnp.clip(blk_lo, 0, P)
    ]
    return window_first_mask(blk_dwin) | (nonempty & (cnt > 0))


def edge_combine(
    state3, sp, dp, w, blk_ids, n_keep, blk_swin, blk_dwin,
    *, SRC_WIN, DST_WIN, msg_kind, combiner,
):
    return _ec.edge_combine_group(
        state3, sp, dp, w, blk_ids, n_keep, blk_swin, blk_dwin,
        SRC_WIN=SRC_WIN, DST_WIN=DST_WIN, msg_kind=msg_kind,
        combiner=combiner, interpret=_interpret(),
    )


def digest(A_r, cnt, recv, rcnt, *, combiner, WIN: int = 512):
    return _digest.digest(
        A_r, cnt, recv, rcnt, combiner=combiner, WIN=WIN,
        interpret=_interpret(),
    )
