"""Streaming A_r digest kernel (paper §5, "In-Memory Message Digesting").

Combines a received message buffer into the resident A_r accumulator in one
pass, fused with the has-message count update — the receiver-side dual of
edge_combine. Trivial compute, but it IS the U_r inner loop; as a Pallas
kernel it streams both buffers HBM->VMEM in (1, WIN) tiles with the pipeline
double-buffering the next tile during the combine (C3 overlap on the
receive path)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ar_ref, cnt_ref, recv_ref, rcnt_ref, out_ref, ocnt_ref, *, combiner):
    a = ar_ref[...]
    r = recv_ref[...]
    if combiner == "sum":
        out_ref[...] = a + r
    elif combiner == "min":
        out_ref[...] = jnp.minimum(a, r)
    else:
        out_ref[...] = jnp.maximum(a, r)
    ocnt_ref[...] = cnt_ref[...] + rcnt_ref[...]


def digest(A_r, cnt, recv, rcnt, *, combiner: str, WIN: int = 512,
           interpret: bool = False):
    """(A_r', cnt') = (combine(A_r, recv), cnt + rcnt); all shapes (P,)."""
    P = A_r.shape[0]
    WIN = min(WIN, P)
    assert P % WIN == 0
    n = P // WIN
    spec = pl.BlockSpec((1, WIN), lambda j: (j, 0))
    kern = functools.partial(_kernel, combiner=combiner)
    r2 = lambda x: x.reshape(n, WIN)
    out, ocnt = pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n, WIN), A_r.dtype),
            jax.ShapeDtypeStruct((n, WIN), cnt.dtype),
        ],
        interpret=interpret,
    )(r2(A_r), r2(cnt), r2(recv), r2(rcnt))
    return out.reshape(P), ocnt.reshape(P)
