"""Fused edge-stream combine kernel (the paper's U_c hot loop, §3.2 + §5).

One grid step processes one edge block of a (shard, dest) group laid out by
``graph.kblocks``:

  HBM -> VMEM   sp/dp/w edge block           (the streaming buffer B, §3.2;
                                              double-buffered by the Pallas
                                              pipeline = overlap C3)
  HBM -> VMEM   values/degree/active window  (the in-memory state array A —
                                              only an aligned SRC_WIN slice,
                                              selected by scalar-prefetched
                                              block metadata)
  MXU           one-hot gather of source state      (Mosaic has no vector
  MXU/VPU       one-hot combine into the A_s window  gather/scatter; one-hot
                                                      matmul is the TPU idiom)
  VMEM          window accumulator persists across the window's block run
                (output revisiting); first block of a window initializes it.

skip() (§3.2): the grid walks a scalar-prefetched *compacted* block list
(active blocks + each window's initializer block). Tail grid steps repeat the
last kept block with contributions masked to the combiner identity — they cost
no extra HBM traffic because Pallas skips the copy when the block index does
not change. Worst case = the dense scan, the paper's guarantee (3).

Supported message kinds (trace-time specialization of compute(.)'s send):
  div_deg: value / max(degree, 1)      (PageRank)
  add_w:   value + weight              (SSSP)
  add_1:   value + 1                   (BFS)
  copy:    value                       (Hash-Min / label propagation)
  deg:     degree                      (neighbourhood degree sums)
Combiners: sum (MXU matmul), min / max (VPU masked reduce).

Layout notes (TPU tiling): the state table is (3, P) so the P axis rides the
lanes; outputs are (n_dst_windows, DST_WIN) with (1, DST_WIN) blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MSG_KINDS = ("div_deg", "add_w", "add_1", "copy", "deg")
COMBINERS = ("sum", "min", "max")

_E0 = {"sum": 0.0, "min": jnp.inf, "max": -jnp.inf}


def _msg(kind: str, vals, degs, w):
    if kind == "div_deg":
        return vals / jnp.maximum(degs, 1.0)
    if kind == "add_w":
        return vals + w
    if kind == "add_1":
        return vals + 1.0
    if kind == "copy":
        return vals
    if kind == "deg":
        return degs
    raise ValueError(kind)


def _combine2(comb: str, a, b):
    if comb == "sum":
        return a + b
    if comb == "min":
        return jnp.minimum(a, b)
    return jnp.maximum(a, b)


def _kernel(
    # scalar prefetch (SMEM)
    ids_ref,    # (NB,) i32 compacted block ids (ascending; tail repeats last)
    nkeep_ref,  # (1,) i32 number of kept blocks
    swin_ref,   # (NB,) i32 source-window index per block
    dwin_ref,   # (NB,) i32 dest-window index per block
    # blocked inputs (VMEM)
    state_ref,  # (3, SRC_WIN) f32 [values ; degree ; active] window
    sp_ref,     # (1, BLK) i32
    dp_ref,     # (1, BLK) i32
    w_ref,      # (1, BLK) f32
    # outputs (VMEM)
    out_ref,    # (1, DST_WIN) f32 A_s window accumulator
    cnt_ref,    # (1, DST_WIN) f32 message counts
    *,
    BLK: int,
    SRC_WIN: int,
    DST_WIN: int,
    msg_kind: str,
    combiner: str,
):
    j = pl.program_id(0)
    blk = ids_ref[j]
    prev = ids_ref[jnp.maximum(j - 1, 0)]
    is_first = (j == 0) | (dwin_ref[blk] != dwin_ref[prev])
    live = j < nkeep_ref[0]

    sp = sp_ref[0, :]
    dp = dp_ref[0, :]
    w = w_ref[0, :]
    src_base = swin_ref[blk] * SRC_WIN
    dst_base = dwin_ref[blk] * DST_WIN

    # --- one-hot gather of source state (MXU; Mosaic has no vector gather) ---
    sl = jnp.clip(sp - src_base, 0, SRC_WIN - 1)
    valid = (sp >= 0) & live
    oh_s = jnp.where(
        valid[:, None],
        sl[:, None] == lax.broadcasted_iota(jnp.int32, (BLK, SRC_WIN), 1),
        False,
    )
    # (BLK, SRC_WIN) x (3, SRC_WIN) -> (BLK, 3), contracting the window axis
    g = lax.dot_general(
        oh_s.astype(jnp.float32), state_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    vals, degs, acts = g[:, 0], g[:, 1], g[:, 2]
    aact = valid & (acts > 0.0)

    # --- compute(.)'s send, masked to the combiner identity ------------------
    e0 = jnp.float32(_E0[combiner])
    msg = jnp.where(aact, _msg(msg_kind, vals, degs, w), e0)

    # --- one-hot combine into the A_s window (§5 in-memory combining) --------
    dl = jnp.clip(dp - dst_base, 0, DST_WIN - 1)
    oh_d = jnp.where(
        aact[:, None],
        dl[:, None] == lax.broadcasted_iota(jnp.int32, (BLK, DST_WIN), 1),
        False,
    )
    if combiner == "sum":
        part = jnp.dot(msg, oh_d.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    elif combiner == "min":
        part = jnp.min(jnp.where(oh_d, msg[:, None], e0), axis=0)
    else:
        part = jnp.max(jnp.where(oh_d, msg[:, None], e0), axis=0)
    cpart = jnp.dot(aact.astype(jnp.float32), oh_d.astype(jnp.float32),
                    preferred_element_type=jnp.float32)

    # --- window-run accumulation (first block initializes) -------------------
    @pl.when(is_first)
    def _init():
        out_ref[0, :] = part
        cnt_ref[0, :] = cpart

    @pl.when(jnp.logical_not(is_first))
    def _acc():
        out_ref[0, :] = _combine2(combiner, out_ref[0, :], part)
        cnt_ref[0, :] = cnt_ref[0, :] + cpart


def edge_combine_group(
    state3: jax.Array,  # (3, P) f32 [values ; degree ; active]
    sp: jax.Array,  # (NB, BLK) i32
    dp: jax.Array,  # (NB, BLK) i32
    w: jax.Array,  # (NB, BLK) f32
    blk_ids: jax.Array,  # (NB,) i32 compacted (dense: iota)
    n_keep: jax.Array,  # () or (1,) i32
    blk_swin: jax.Array,  # (NB,) i32
    blk_dwin: jax.Array,  # (NB,) i32
    *,
    SRC_WIN: int,
    DST_WIN: int,
    msg_kind: str,
    combiner: str,
    interpret: bool = False,
):
    """A_s, cnt for one (shard, dest) group. Returns ((P,) f32, (P,) f32)."""
    P = state3.shape[1]
    NB, BLK = sp.shape
    assert msg_kind in MSG_KINDS and combiner in COMBINERS
    n_dwin = P // DST_WIN

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(NB,),
        in_specs=[
            pl.BlockSpec(
                (3, SRC_WIN), lambda j, ids, nk, sw, dw: (0, sw[ids[j]])
            ),
            pl.BlockSpec((1, BLK), lambda j, ids, nk, sw, dw: (ids[j], 0)),
            pl.BlockSpec((1, BLK), lambda j, ids, nk, sw, dw: (ids[j], 0)),
            pl.BlockSpec((1, BLK), lambda j, ids, nk, sw, dw: (ids[j], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, DST_WIN), lambda j, ids, nk, sw, dw: (dw[ids[j]], 0)),
            pl.BlockSpec((1, DST_WIN), lambda j, ids, nk, sw, dw: (dw[ids[j]], 0)),
        ],
    )
    kernel = functools.partial(
        _kernel, BLK=BLK, SRC_WIN=SRC_WIN, DST_WIN=DST_WIN,
        msg_kind=msg_kind, combiner=combiner,
    )
    out_shape = [
        jax.ShapeDtypeStruct((n_dwin, DST_WIN), jnp.float32),
        jax.ShapeDtypeStruct((n_dwin, DST_WIN), jnp.float32),
    ]
    A_s, cnt = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(
        blk_ids.astype(jnp.int32),
        jnp.atleast_1d(n_keep).astype(jnp.int32),
        blk_swin.astype(jnp.int32),
        blk_dwin.astype(jnp.int32),
        state3,
        sp,
        dp,
        w,
    )
    return A_s.reshape(P), cnt.reshape(P)
