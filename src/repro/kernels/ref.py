"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.edge_combine import _E0, _msg


def edge_combine_ref(
    state3: jax.Array,  # (3, P)
    sp: jax.Array,  # (NB, BLK)
    dp: jax.Array,
    w: jax.Array,
    blk_ids: jax.Array,  # (NB,)
    n_keep: jax.Array,
    blk_swin,  # unused (absolute positions suffice in jnp)
    blk_dwin,  # unused
    *,
    SRC_WIN: int,
    DST_WIN: int,
    msg_kind: str,
    combiner: str,
):
    """Oracle for kernels.edge_combine.edge_combine_group.

    Processes exactly the blocks listed in blk_ids[:n_keep] (set difference
    is what skip() saves), using plain gathers and scatter-combines.
    """
    P = state3.shape[1]
    NB, BLK = sp.shape
    values, degree, active = state3[0], state3[1], state3[2]

    keep = jnp.arange(NB) < jnp.atleast_1d(n_keep)[0]
    spk = jnp.where(keep[:, None], jnp.take(sp, jnp.clip(blk_ids, 0), axis=0), -1)
    dpk = jnp.where(keep[:, None], jnp.take(dp, jnp.clip(blk_ids, 0), axis=0), 0)
    wk = jnp.where(keep[:, None], jnp.take(w, jnp.clip(blk_ids, 0), axis=0), 0.0)

    spf, dpf, wf = spk.reshape(-1), dpk.reshape(-1), wk.reshape(-1)
    spc = jnp.clip(spf, 0)
    vals = values[spc]
    degs = degree[spc]
    aact = (spf >= 0) & (active[spc] > 0)
    e0 = jnp.float32(_E0[combiner])
    msg = jnp.where(aact, _msg(msg_kind, vals, degs, wf), e0)

    A = jnp.full((P,), e0, jnp.float32)
    if combiner == "sum":
        A = A.at[dpf].add(msg)
    elif combiner == "min":
        A = A.at[dpf].min(msg)
    else:
        A = A.at[dpf].max(msg)
    cnt = jnp.zeros((P,), jnp.float32).at[dpf].add(aact.astype(jnp.float32))
    return A, cnt


def digest_ref(A_r, cnt, recv, rcnt, *, combiner: str):
    """Oracle for kernels.digest: A_r' = combine(A_r, recv); cnt' = cnt+rcnt."""
    if combiner == "sum":
        A = A_r + recv
    elif combiner == "min":
        A = jnp.minimum(A_r, recv)
    else:
        A = jnp.maximum(A_r, recv)
    return A, cnt + rcnt


def moe_combine_ref(expert_out, topk_idx, topk_w):
    """Oracle for kernels.moe_dispatch combine: y[t] = sum_k w[t,k]*out[t,k]."""
    return jnp.einsum("tkd,tk->td", expert_out, topk_w)
