"""Attention variants: GQA, sliding-window local, cross-attention, and MLA
(DeepSeek multi-head latent attention with the compressed KV cache).

All functions take/return (B, S, d) activations. Caches are explicit dicts:

  GQA:  {k: (B, Lc, Hkv, hd), v: ..., pos: (Lc,) int32 absolute, -1 empty}
  MLA:  {c_kv: (B, Lc, r), k_rope: (B, Lc, rd), pos: (Lc,)}

``Lc = window`` for sliding-window layers (ring buffer — this is what makes
gemma3/hymba ``long_500k`` decode cheap) and ``Lc = max_len`` for global
layers. Three static modes per call:

  cache=None              train forward (causal or bidirectional)
  cache given, S > 1      prefill: attend causally AND fill the cache
  cache given, S == 1     decode: ring-write one entry, attend over cache
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope
from repro.models.sharding import act_bshd, act_btd


_FLAT_HEADS = False  # §Perf: repeat KV to flat heads so TP shards H cleanly


def set_flat_heads(on: bool):
    """Hillclimb knob (§Perf iteration 1): grouped-KV attention keeps the
    tiny Hkv axis, which the 16-way 'model' axis cannot shard — XLA then
    replicates the O(S^2) logits/probs. Flat mode repeats K/V to H heads
    (bytes negligible next to the S^2 tensors) so logits shard 16-ways."""
    global _FLAT_HEADS
    _FLAT_HEADS = on


def _attend(q, k, v, mask):
    """q: (B,S,H,hd), k/v: (B,T,Hkv,hd) with GQA head grouping."""
    from repro.models.sharding import constrain

    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    if _FLAT_HEADS:
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)  # (B,T,H,hd)
            v = jnp.repeat(v, rep, axis=2)
            Hkv, rep = H, 1
        logits = jnp.einsum(
            "bshd,bthd->bhst", q.astype(jnp.float32), k.astype(jnp.float32)
        ) / jnp.sqrt(jnp.float32(hd))
        logits = constrain(logits, "batch", "model", None, None)
        logits = jnp.where(mask[:, None, :, :], logits, -1e30)
        p = constrain(jax.nn.softmax(logits, axis=-1).astype(v.dtype),
                      "batch", "model", None, None)
        return jnp.einsum("bhst,bthd->bshd", p, v)
    qg = q.reshape(B, S, Hkv, rep, hd)
    logits = jnp.einsum(
        "bsgrd,btgd->bgrst", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) / jnp.sqrt(jnp.float32(hd))
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrst,btgd->bsgrd", p.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def _train_mask(positions, window, causal):
    q_pos = positions
    k_pos = positions
    if not causal:
        B, S = positions.shape
        return jnp.ones((B, S, S), bool)
    m = k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        m &= k_pos[:, None, :] > q_pos[:, :, None] - window
    return m


def _fill_cache(cache, entries, positions):
    """Prefill: write the last Lc entries (ring order is trivially aligned
    because prefill starts at position 0)."""
    Lc = cache["pos"].shape[0]
    S = positions.shape[1]
    new = dict()
    take = min(S, Lc)
    for name, e in entries.items():
        new[name] = jax.lax.dynamic_update_slice_in_dim(
            cache[name], e[:, S - take:], 0, 1
        )
    pos_tail = positions[0, S - take:]
    new["pos"] = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], pos_tail.astype(jnp.int32), 0, 0
    )
    return new


def _ring_write(cache, entries, positions):
    """Decode: write one entry at slot pos % Lc."""
    Lc = cache["pos"].shape[0]
    p = positions[0, 0]
    slot = jnp.mod(p, Lc)
    new = dict()
    for name, e in entries.items():
        new[name] = jax.lax.dynamic_update_slice_in_dim(cache[name], e, slot, 1)
    new["pos"] = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], p[None].astype(jnp.int32), slot, 0
    )
    return new


def _cache_mask(positions, cache_pos, window):
    """(B, S, Lc) mask from absolute cached positions (-1 = empty)."""
    k_pos = cache_pos[None, None, :]
    q_pos = positions[:, :, None]
    m = (k_pos >= 0) & (k_pos <= q_pos)
    if window is not None:
        m &= k_pos > q_pos - window
    return m


def gqa_attention(
    p: dict,
    x,
    positions,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    window: int | None = None,
    causal: bool = True,
    cache: dict | None = None,
    cross_kv=None,  # (k, v) precomputed for cross-attention
):
    """Returns (out (B,S,d), new_cache_or_None)."""
    B, S, d = x.shape
    q = act_bshd(jnp.einsum("bsd,dhk->bshk", x,
                            p["wq"].reshape(d, n_heads, head_dim)))
    q = apply_rope(q, positions, rope_theta)

    if cross_kv is not None:
        k, v = cross_kv  # (B, T, Hkv, hd) media/encoder keys, full attention
        mask = jnp.ones((B, S, k.shape[1]), bool)
        out = _attend(q, k, v, mask)
        new_cache = None
    else:
        k = act_bshd(jnp.einsum("bsd,dhk->bshk", x,
                                p["wk"].reshape(d, n_kv_heads, head_dim)))
        v = act_bshd(jnp.einsum("bsd,dhk->bshk", x,
                                p["wv"].reshape(d, n_kv_heads, head_dim)))
        k = apply_rope(k, positions, rope_theta)
        if cache is None:
            out = _attend(q, k, v, _train_mask(positions, window, causal))
            new_cache = None
        elif S > 1:  # prefill
            out = _attend(q, k, v, _train_mask(positions, window, causal))
            new_cache = _fill_cache(cache, dict(k=k, v=v), positions)
        else:  # decode
            new_cache = _ring_write(cache, dict(k=k, v=v), positions)
            mask = _cache_mask(positions, new_cache["pos"], window)
            out = _attend(q, new_cache["k"], new_cache["v"], mask)
    y = act_btd(jnp.einsum("bshk,hkd->bsd", out,
                           p["wo"].reshape(n_heads, head_dim, d)))
    return y.astype(x.dtype), new_cache


def cross_kv_project(p: dict, media, *, n_kv_heads: int, head_dim: int,
                     keys=("wk", "wv")):
    """Project media/encoder embeddings to cross K/V once (cacheable)."""
    B, T, d = media.shape
    k = jnp.einsum("btd,dhk->bthk", media,
                   p[keys[0]].reshape(d, n_kv_heads, head_dim))
    v = jnp.einsum("btd,dhk->bthk", media,
                   p[keys[1]].reshape(d, n_kv_heads, head_dim))
    return k, v


def make_gqa_cache(B, Lc, n_kv_heads, head_dim, dtype):
    return dict(
        k=jnp.zeros((B, Lc, n_kv_heads, head_dim), dtype),
        v=jnp.zeros((B, Lc, n_kv_heads, head_dim), dtype),
        pos=jnp.full((Lc,), -1, jnp.int32),
    )


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2). The KV cache stores only
# the rank-`kv_lora` latent c_kv plus the shared rope key: the serving-memory
# win that shows up in the decode roofline.
# ---------------------------------------------------------------------------

def _mla_attend(q_nope, q_rope, c_kv, k_rope, mask, p, H, hd, kv_lora, dtype):
    kv = jnp.einsum("btr,rhk->bthk", c_kv,
                    p["w_ukv"].reshape(kv_lora, H, 2 * hd))
    k_nope, v = kv[..., :hd], kv[..., hd:]
    l_nope = jnp.einsum("bshk,bthk->bhst", q_nope.astype(jnp.float32),
                        k_nope.astype(jnp.float32))
    l_rope = jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                        k_rope.astype(jnp.float32))
    rd = q_rope.shape[-1]
    logits = (l_nope + l_rope) / jnp.sqrt(jnp.float32(hd + rd))
    logits = jnp.where(mask[:, None, :, :], logits, -1e30)
    pattn = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bthk->bshk", pattn.astype(dtype), v)


def mla_attention(
    p: dict,
    x,
    positions,
    *,
    n_heads: int,
    head_dim: int,
    kv_lora: int,
    rope_dim: int,
    rope_theta: float,
    cache: dict | None = None,
):
    B, S, d = x.shape
    H, hd, rd = n_heads, head_dim, rope_dim

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].reshape(d, H, hd + rd))
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, rope_theta)

    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])  # (B,S,r) latent
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_krope"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, rope_theta)[:, :, 0]

    if cache is None:
        mask = _train_mask(positions, None, True)
        out = _mla_attend(q_nope, q_rope, c_kv, k_rope, mask, p, H, hd,
                          kv_lora, x.dtype)
        new_cache = None
    elif S > 1:  # prefill
        mask = _train_mask(positions, None, True)
        out = _mla_attend(q_nope, q_rope, c_kv, k_rope, mask, p, H, hd,
                          kv_lora, x.dtype)
        new_cache = _fill_cache(cache, dict(c_kv=c_kv, k_rope=k_rope),
                                positions)
    else:  # decode against the latent cache
        new_cache = _ring_write(cache, dict(c_kv=c_kv, k_rope=k_rope),
                                positions)
        mask = _cache_mask(positions, new_cache["pos"], None)
        out = _mla_attend(q_nope, q_rope, new_cache["c_kv"],
                          new_cache["k_rope"], mask, p, H, hd, kv_lora,
                          x.dtype)
    y = act_btd(jnp.einsum("bshk,hkd->bsd", out, p["wo"].reshape(H, hd, d)))
    return y.astype(x.dtype), new_cache


def make_mla_cache(B, Lc, kv_lora, rope_dim, dtype):
    return dict(
        c_kv=jnp.zeros((B, Lc, kv_lora), dtype),
        k_rope=jnp.zeros((B, Lc, rope_dim), dtype),
        pos=jnp.full((Lc,), -1, jnp.int32),
    )
