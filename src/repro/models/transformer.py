"""Model assembly: pattern-grouped scan transformer for all 10 families.

Params are nested dicts; repeated layers are stacked along a leading group
axis and executed with ``lax.scan`` (HLO size O(pattern), compile-time safe
for 95-layer configs × 40 dry-run cells). ``jax.checkpoint`` (remat) wraps
the scan body when cfg.remat.

Layer kinds: attn (GQA, optional sliding window), mla, ssm (Mamba2 SSD),
hybrid (parallel attn+SSM heads, Hymba-style), cross (VLM cross-attention).
Enc-dec (Whisper): a bidirectional encoder stack + a decoder whose every
layer self-attends causally then cross-attends to encoder states.

Caches: dict trees mirroring the layer structure; sliding-window layers use
ring-buffer caches of length `window` (this is what makes gemma3/hymba
long_500k decode feasible), SSM layers carry O(1) state.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.attention import (
    cross_kv_project, gqa_attention, mla_attention,
)
from repro.models.config import LayerSpec, ModelConfig
from repro.models.layers import (
    embed, gelu, init_rms, rms_norm, silu, swiglu_ffn, truncated_normal,
    unembed,
)
from repro.models.moe import moe_ffn
from repro.models.sharding import act_btd
from repro.models.ssm import mamba_block


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig, cross=False):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 0.02
    return dict(
        wq=truncated_normal(ks[0], (d, H * hd), s, cfg.dtype),
        wk=truncated_normal(ks[1], (d, Hkv * hd), s, cfg.dtype),
        wv=truncated_normal(ks[2], (d, Hkv * hd), s, cfg.dtype),
        wo=truncated_normal(ks[3], (H * hd, d), s / (2 * cfg.n_layers) ** 0.5,
                            cfg.dtype),
    )


def _init_mla(key, cfg: ModelConfig):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    r, rd = cfg.mla_kv_lora, cfg.mla_rope_dim
    ks = jax.random.split(key, 5)
    s = 0.02
    return dict(
        wq=truncated_normal(ks[0], (d, H * (hd + rd)), s, cfg.dtype),
        w_dkv=truncated_normal(ks[1], (d, r), s, cfg.dtype),
        w_krope=truncated_normal(ks[2], (d, rd), s, cfg.dtype),
        w_ukv=truncated_normal(ks[3], (r, H * 2 * hd), s, cfg.dtype),
        wo=truncated_normal(ks[4], (H * hd, d), s / (2 * cfg.n_layers) ** 0.5,
                            cfg.dtype),
    )


def _init_ssm(key, cfg: ModelConfig):
    d, di, N = cfg.d_model, cfg.d_ssm_inner, cfg.ssm_state
    H, K = cfg.n_ssm_heads, cfg.ssm_conv
    ks = jax.random.split(key, 4)
    s = 0.02
    return dict(
        in_proj=truncated_normal(ks[0], (d, 2 * di + 2 * N + H), s, cfg.dtype),
        conv_w=truncated_normal(ks[1], (K, di + 2 * N), s, cfg.dtype),
        conv_b=jnp.zeros((di + 2 * N,), cfg.dtype),
        A_log=jnp.zeros((H,), jnp.float32),
        dt_bias=jnp.zeros((H,), jnp.float32),
        D=jnp.ones((H,), jnp.float32),
        out_proj=truncated_normal(ks[2], (di, d),
                                  s / (2 * cfg.n_layers) ** 0.5, cfg.dtype),
    )


def _init_ffn(key, cfg: ModelConfig, spec: LayerSpec):
    d = cfg.d_model
    s = 0.02
    if spec.ffn == "none":
        return {}
    if spec.ffn == "moe":
        E, f = cfg.n_experts, cfg.moe_dff
        ks = jax.random.split(key, 7)
        p = dict(
            router=truncated_normal(ks[0], (d, E), s, jnp.float32),
            w_gate=truncated_normal(ks[1], (E, d, f), s, cfg.dtype),
            w_up=truncated_normal(ks[2], (E, d, f), s, cfg.dtype),
            w_down=truncated_normal(ks[3], (E, f, d),
                                    s / (2 * cfg.n_layers) ** 0.5, cfg.dtype),
        )
        if cfg.n_shared_experts:
            fs = f * cfg.n_shared_experts
            p.update(
                ws_gate=truncated_normal(ks[4], (d, fs), s, cfg.dtype),
                ws_up=truncated_normal(ks[5], (d, fs), s, cfg.dtype),
                ws_down=truncated_normal(ks[6], (fs, d),
                                         s / (2 * cfg.n_layers) ** 0.5,
                                         cfg.dtype),
            )
        return p
    f = cfg.d_ff
    ks = jax.random.split(key, 3)
    return dict(
        w_gate=truncated_normal(ks[0], (d, f), s, cfg.dtype),
        w_up=truncated_normal(ks[1], (d, f), s, cfg.dtype),
        w_down=truncated_normal(ks[2], (f, d),
                                s / (2 * cfg.n_layers) ** 0.5, cfg.dtype),
    )


def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, decoder_cross=False):
    ks = jax.random.split(key, 5)
    p: dict[str, Any] = dict(ln1=init_rms(cfg.d_model, cfg.dtype),
                             ln2=init_rms(cfg.d_model, cfg.dtype))
    if spec.kind in ("attn", "cross"):
        p["attn"] = _init_attn(ks[0], cfg)
    elif spec.kind == "mla":
        p["attn"] = _init_mla(ks[0], cfg)
    elif spec.kind == "ssm":
        p["ssm"] = _init_ssm(ks[1], cfg)
    elif spec.kind == "hybrid":
        p["attn"] = _init_attn(ks[0], cfg)
        p["ssm"] = _init_ssm(ks[1], cfg)
        p["mix_a"] = jnp.full((cfg.d_model,), 0.5, cfg.dtype)
        p["mix_s"] = jnp.full((cfg.d_model,), 0.5, cfg.dtype)
    if decoder_cross:  # whisper decoder: extra cross-attn sublayer
        p["xattn"] = _init_attn(ks[2], cfg)
        p["ln_x"] = init_rms(cfg.d_model, cfg.dtype)
    p["ffn"] = _init_ffn(ks[3], cfg, spec)
    return p


def _stack(layers: list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def init_params(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = dict(
        embed=truncated_normal(ks[0], (cfg.vocab, cfg.d_model), 0.02,
                               cfg.dtype),
        final_norm=init_rms(cfg.d_model, cfg.dtype),
    )
    if not cfg.tie_embeddings:
        params["unembed"] = truncated_normal(
            ks[1], (cfg.vocab, cfg.d_model), 0.02, cfg.dtype
        )
    dec_cross = cfg.n_enc_layers > 0
    params["prologue"] = [
        _init_layer(k, cfg, s, dec_cross)
        for k, s in zip(jax.random.split(ks[2], max(len(cfg.prologue), 1)),
                        cfg.prologue)
    ]
    G = cfg.n_pattern_groups
    gkeys = jax.random.split(ks[3], G)
    params["groups"] = [
        _stack([
            _init_layer(jax.random.fold_in(gk, pi), cfg, spec, dec_cross)
            for gk in gkeys
        ])
        for pi, spec in enumerate(cfg.pattern)
    ]
    if cfg.n_enc_layers:
        ekeys = jax.random.split(ks[4], cfg.n_enc_layers)
        espec = LayerSpec(kind="attn", window=None, ffn="dense")
        params["encoder"] = _stack(
            [_init_layer(k, cfg, espec) for k in ekeys]
        )
        params["enc_final_norm"] = init_rms(cfg.d_model, cfg.dtype)
    return params


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct params via eval_shape — zero allocation (dry-run)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.key(0)
    )


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _apply_layer(cfg: ModelConfig, spec: LayerSpec, p, x, positions, *,
                 media_states=None, enc_states=None, cache=None):
    """One layer. Returns (x', new_cache_dict, aux_scalar)."""
    act = silu if cfg.act == "silu" else gelu
    new_cache = {}
    aux = jnp.float32(0)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    kw = dict(
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
    )
    get = lambda k: None if cache is None else cache.get(k)

    if spec.kind == "attn":
        a, kc = gqa_attention(p["attn"], h, positions, window=spec.window,
                              cache=get("kv"), **kw)
        if kc is not None:
            new_cache["kv"] = kc
        x = x + a
    elif spec.kind == "cross":
        # VLM cross layer: K/V from image patch embeddings (cached at prefill)
        if get("xkv") is not None:
            mkv = (cache["xkv"]["k"], cache["xkv"]["v"])
        else:
            mkv = cross_kv_project(p["attn"], media_states,
                                   n_kv_heads=cfg.n_kv_heads,
                                   head_dim=cfg.head_dim)
        if cache is not None:
            new_cache["xkv"] = dict(k=mkv[0], v=mkv[1])
        a, _ = gqa_attention(p["attn"], h, positions, cross_kv=mkv, **kw)
        x = x + a
    elif spec.kind == "mla":
        a, kc = mla_attention(
            p["attn"], h, positions, n_heads=cfg.n_heads,
            head_dim=cfg.head_dim, kv_lora=cfg.mla_kv_lora,
            rope_dim=cfg.mla_rope_dim, rope_theta=cfg.rope_theta,
            cache=get("kv"),
        )
        if kc is not None:
            new_cache["kv"] = kc
        x = x + a
    elif spec.kind == "ssm":
        a, sc = mamba_block(p["ssm"], h, cfg=cfg, cache=get("ssm"))
        if sc is not None:
            new_cache["ssm"] = sc
        x = x + a
    elif spec.kind == "hybrid":
        a, kc = gqa_attention(p["attn"], h, positions, window=spec.window,
                              cache=get("kv"), **kw)
        m, sc = mamba_block(p["ssm"], h, cfg=cfg, cache=get("ssm"))
        if kc is not None:
            new_cache["kv"] = kc
        if sc is not None:
            new_cache["ssm"] = sc
        x = x + a * p["mix_a"] + m * p["mix_s"]

    if "xattn" in p:  # whisper decoder: cross-attend to encoder states
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps)
        if get("ekv") is not None:
            ekv = (cache["ekv"]["k"], cache["ekv"]["v"])
        else:
            ekv = cross_kv_project(p["xattn"], enc_states,
                                   n_kv_heads=cfg.n_kv_heads,
                                   head_dim=cfg.head_dim)
        if cache is not None:
            new_cache["ekv"] = dict(k=ekv[0], v=ekv[1])
        a, _ = gqa_attention(p["xattn"], hx, positions, cross_kv=ekv, **kw)
        x = x + a

    if spec.ffn != "none":
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if spec.ffn == "moe":
            f, (aux_lb, _drop) = moe_ffn(
                p["ffn"], h2, n_experts=cfg.n_experts, topk=cfg.topk,
                capacity_factor=cfg.capacity_factor,
                n_shared=cfg.n_shared_experts,
            )
            aux = aux + aux_lb
        else:
            f = swiglu_ffn(h2, p["ffn"]["w_gate"], p["ffn"]["w_up"],
                           p["ffn"]["w_down"], act)
        x = x + f
    return x, new_cache, aux


def encoder_forward(cfg: ModelConfig, params, media):
    """Bidirectional encoder over precomputed frame embeddings (whisper).
    The conv frontend is a stub: `media` IS the post-conv embedding."""
    x = media.astype(cfg.dtype)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), x.shape[:2])

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, _ = gqa_attention(
            lp["attn"], h, positions, causal=False,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
        )
        x = x + a
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + swiglu_ffn(h2, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                           lp["ffn"]["w_down"], gelu)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = lax.scan(body, x, params["encoder"])
    else:
        for li in range(cfg.n_enc_layers):
            x, _ = body(x, jax.tree.map(lambda p: p[li],
                                        params["encoder"]))
    return rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def apply_stack(cfg: ModelConfig, params, x, positions, *,
                media_states=None, enc_states=None, caches=None):
    """Run prologue + scanned pattern groups. Returns (x, new_caches, aux).

    caches: dict(prologue=[...], groups=[stacked per pattern elem]) or None.
    """
    aux = jnp.float32(0)
    new_pro = []
    for li, (spec, lp) in enumerate(zip(cfg.prologue, params["prologue"])):
        c = None if caches is None else caches["prologue"][li]
        x, nc, a = _apply_layer(cfg, spec, lp, x, positions,
                                media_states=media_states,
                                enc_states=enc_states, cache=c)
        x = act_btd(x)
        new_pro.append(nc)
        aux = aux + a

    if caches is None:
        def body(carry, stacked_p):
            x, aux = carry
            for pi, spec in enumerate(cfg.pattern):
                x, _, a = _apply_layer(cfg, spec, stacked_p[pi], x, positions,
                                       media_states=media_states,
                                       enc_states=enc_states)
                x = act_btd(x)
                aux = aux + a
            return (x, aux), None

        body = jax.checkpoint(body) if cfg.remat else body
        if cfg.scan_layers:
            (x, aux), _ = lax.scan(body, (x, aux), tuple(params["groups"]))
        else:
            # unrolled (exact cost_analysis: XLA counts while bodies ONCE,
            # so the dry-run compiles small unrolled variants to extrapolate)
            G = cfg.n_pattern_groups
            for g in range(G):
                sl = jax.tree.map(lambda p: p[g], tuple(params["groups"]))
                (x, aux), _ = body((x, aux), sl)
        return x, None, aux

    def body_c(carry, xs):
        x, aux = carry
        stacked_p, stacked_c = xs
        new_cs = []
        for pi, spec in enumerate(cfg.pattern):
            x, nc, a = _apply_layer(cfg, spec, stacked_p[pi], x, positions,
                                    media_states=media_states,
                                    enc_states=enc_states,
                                    cache=stacked_c[pi])
            x = act_btd(x)
            new_cs.append(nc)
            aux = aux + a
        return (x, aux), tuple(new_cs)

    if cfg.scan_layers:
        (x, aux), new_groups = lax.scan(
            body_c, (x, aux),
            (tuple(params["groups"]), tuple(caches["groups"])),
        )
    else:
        G = cfg.n_pattern_groups
        outs = []
        for g in range(G):
            sl = jax.tree.map(
                lambda p: p[g], (tuple(params["groups"]),
                                 tuple(caches["groups"]))
            )
            (x, aux), nc = body_c((x, aux), sl)
            outs.append(nc)
        new_groups = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return x, dict(prologue=new_pro, groups=list(new_groups)), aux


def forward(cfg: ModelConfig, params, tokens, media=None, positions=None):
    """Training forward. Returns (logits_f32, aux_loss)."""
    B, S = tokens.shape
    x = act_btd(embed(tokens, params["embed"]).astype(cfg.dtype))
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    enc_states = None
    if cfg.n_enc_layers:
        enc_states = encoder_forward(cfg, params, media)
    media_states = (
        media.astype(cfg.dtype)
        if media is not None and not cfg.n_enc_layers
        else None
    )
    x, _, aux = apply_stack(cfg, params, x, positions,
                            media_states=media_states, enc_states=enc_states)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return unembed(x, table), aux
