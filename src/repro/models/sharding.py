"""Activation sharding constraints (logical-axis rules).

XLA SPMD propagation from argument shardings alone can pick pathological
layouts deep in the graph (observed: it replicated the global batch inside
attention, inflating collective bytes ~60x). Frameworks pin activations at
block boundaries; we do the same via a small context the launcher sets:

    set_rules(batch=('pod','data'), model='model', seq=None)

``constrain(x, kind)`` is a no-op when no rules are active (unit tests,
single-device runs) and skips any axis that does not divide the dimension.
"""

from __future__ import annotations

from contextlib import contextmanager

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

_RULES: dict | None = None


def set_rules(batch, model, seq=None, mesh=None):
    global _RULES
    _RULES = dict(batch=batch, model=model, seq=seq, mesh=mesh)


def clear_rules():
    global _RULES
    _RULES = None


@contextmanager
def rules(batch, model, seq=None, mesh=None):
    global _RULES
    old = _RULES
    set_rules(batch, model, seq, mesh)
    try:
        yield
    finally:
        _RULES = old


def _axis_size(ax) -> int:
    mesh = _RULES.get("mesh")
    if mesh is None or ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return int(mesh.shape[ax])


def _fit(spec, shape):
    out = []
    for dim, ax in zip(shape, spec):
        size = _axis_size(ax)
        out.append(ax if ax is not None and size > 1 and dim % size == 0
                   else None)
    return P(*out)


def constrain(x, *axes):
    """axes: logical names per dim from {'batch','model','seq',None}."""
    if _RULES is None:
        return x
    spec = tuple(_RULES.get(a) if a else None for a in axes)
    return jax.lax.with_sharding_constraint(x, _fit(spec, x.shape))


def act_btd(x):  # (B, S, d) residual-stream activations
    return constrain(x, "batch", "seq", None)


def act_bshd(x):  # (B, S, H, hd) per-head activations
    return constrain(x, "batch", None, "model", None)


def act_bsf(x):  # (B, S, ff) FFN hidden
    return constrain(x, "batch", None, "model")


def act_logits(x):  # (B, S, V) or (B, V)
    if x.ndim == 3:
        return constrain(x, "batch", None, "model")
    return constrain(x, "batch", "model")


def act_ecd(x):  # (E, C, d) MoE expert buffers
    return constrain(x, "model", None, None)
