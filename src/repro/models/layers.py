"""Shared neural layers: norms, RoPE, embeddings, initializers.

Pure-JAX (no flax): params are nested dicts of jnp arrays; every ``init_*``
has an abstract twin usable under ``jax.eval_shape`` so the dry-run allocates
nothing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, std, dtype=jnp.bfloat16):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def rms_norm(x, scale, eps=1e-6):
    """RMSNorm computed in f32 (bf16 params/activations elsewhere)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def init_rms(d, dtype=jnp.bfloat16):
    return jnp.zeros((d,), dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed(tokens, table):
    return jnp.take(table, tokens, axis=0)


def unembed(x, table):
    """Logits in f32 (loss stability)."""
    from repro.models.sharding import act_logits

    return act_logits(jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                                 table.astype(jnp.float32)))


def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def swiglu_ffn(x, wg, wu, wd, act=silu):
    from repro.models.sharding import act_bsf, act_btd

    h = act(jnp.einsum("...d,df->...f", x, wg)) * jnp.einsum(
        "...d,df->...f", x, wu
    )
    if h.ndim == 3:
        h = act_bsf(h)
    out = jnp.einsum("...f,fd->...d", h, wd)
    return act_btd(out) if out.ndim == 3 else out
