"""Model configuration: one dataclass covering all 10 assigned families.

Layers are described by a repeating *pattern* of layer specs; the stack is
``prologue + pattern * (n_layers // len(pattern))``. Scan-over-layers groups
by pattern period, so HLO size is O(pattern), not O(n_layers) — essential for
the 40-cell dry-run compile times.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax.numpy as jnp


@dataclass(frozen=True)
class LayerSpec:
    """One layer's shape within the repeating pattern."""

    kind: str = "attn"  # attn | mla | ssm | hybrid | cross
    window: int | None = None  # sliding-window size (None = global)
    ffn: str = "dense"  # dense | moe | none


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | moe | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 => d_model // n_heads

    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    prologue: tuple[LayerSpec, ...] = ()  # non-repeated leading layers

    # --- MLA (DeepSeek) ---
    mla_kv_lora: int = 0
    mla_rope_dim: int = 64

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    topk: int = 0
    moe_dff: int = 0  # per-expert FFN width (d_ff of the dense path if 0)
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4

    # --- encoder-decoder (Whisper) ---
    n_enc_layers: int = 0
    enc_is_causal: bool = False

    # --- VLM / audio frontends are stubs: inputs are precomputed embeddings
    n_media_tokens: int = 0  # image patches / audio frames per sample

    # --- misc ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    act: str = "silu"
    tie_embeddings: bool = False
    dtype: object = jnp.bfloat16

    # --- execution knobs (hillclimbed in §Perf) ---
    remat: bool = True
    scan_layers: bool = True
    seq_shard: bool = True  # SP: shard activations' seq dim over 'model'
    grad_compress: bool = False  # int8 error-feedback gradient all-reduce

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def n_pattern_groups(self) -> int:
        body = self.n_layers - len(self.prologue)
        assert body % len(self.pattern) == 0, (
            f"{self.name}: {body} layers not divisible by pattern "
            f"{len(self.pattern)}"
        )
        return body // len(self.pattern)

    @property
    def d_ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_ssm_inner // self.ssm_head_dim

    def n_params(self) -> int:
        """Analytic parameter count (for 6·N·D roofline bookkeeping)."""
        d, hd = self.d_model, self.head_dim
        n_q, n_kv = self.n_heads, self.n_kv_heads
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = emb

        def attn_p():
            return d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d

        def mla_p():
            r, rd = self.mla_kv_lora, self.mla_rope_dim
            return (
                d * n_q * (hd + rd)  # q (nope+rope)
                + d * (r + rd)  # kv down + shared k_rope
                + r * n_kv * (hd + hd)  # kv up (k_nope, v)
                + n_q * hd * d  # o
            )

        def ssm_p():
            di, ns, nh = self.d_ssm_inner, self.ssm_state, self.n_ssm_heads
            return (
                d * (2 * di + 2 * ns + nh)  # in_proj (x, z, B, C, dt)
                + self.ssm_conv * (di + 2 * ns)  # conv
                + 2 * nh  # A_log, D
                + di * d  # out_proj
            )

        def ffn_p(spec: LayerSpec):
            if spec.ffn == "none":
                return 0
            if spec.ffn == "moe":
                per = 3 * d * self.moe_dff
                return (
                    self.n_experts * per
                    + self.n_shared_experts * per
                    + d * self.n_experts  # router
                )
            return 3 * d * self.d_ff

        layers = list(self.prologue) + list(self.pattern) * self.n_pattern_groups
        for spec in layers:
            if spec.kind in ("attn", "cross"):
                total += attn_p()
            elif spec.kind == "mla":
                total += mla_p()
            elif spec.kind == "ssm":
                total += ssm_p()
            elif spec.kind == "hybrid":
                total += attn_p() + ssm_p()
            total += ffn_p(spec) + 2 * d  # two norms
            if self.n_enc_layers:  # enc-dec: every decoder layer cross-attends
                total += attn_p() + d
        if self.n_enc_layers:
            total += self.n_enc_layers * (attn_p() + 3 * d * self.d_ff + 2 * d)
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: topk + shared experts only)."""
        if not self.n_experts:
            return self.n_params()
        d = self.d_model
        per = 3 * d * self.moe_dff
        n_moe_layers = sum(
            1
            for s in list(self.prologue)
            + list(self.pattern) * self.n_pattern_groups
            if s.ffn == "moe"
        )
        inactive = n_moe_layers * (self.n_experts - self.topk) * per
        return self.n_params() - inactive

    def with_groups(self, k: int) -> "ModelConfig":
        """Same config with k pattern groups (and proportionally scaled
        encoder), unrolled — used by the dry-run to recover exact depth-linear
        cost terms (XLA's cost_analysis counts scan bodies once)."""
        enc = 0
        if self.n_enc_layers:
            enc = max(1, round(self.n_enc_layers * k / self.n_pattern_groups))
        return replace(
            self,
            name=f"{self.name}@g{k}",
            n_layers=len(self.prologue) + len(self.pattern) * k,
            n_enc_layers=enc,
            scan_layers=False,
        )

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        pat = len(self.pattern)
        pro = len(self.prologue)
        layers = pro + pat * min(2, self.n_pattern_groups)
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128,
            vocab=256,
            mla_kv_lora=32 if self.mla_kv_lora else 0,
            mla_rope_dim=8 if self.mla_kv_lora else 64,
            n_experts=min(self.n_experts, 8),
            topk=min(self.topk, 2),
            moe_dff=32 if self.n_experts else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16,
            ssm_chunk=16,
            n_enc_layers=2 if self.n_enc_layers else 0,
            n_media_tokens=16 if self.n_media_tokens else 0,
            remat=False,
            seq_shard=False,
        )
