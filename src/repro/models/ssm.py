"""Mamba2 — state-space duality (SSD) chunked scan (arXiv:2405.21060).

The SSD computation is itself a streaming recurrence with the paper's DSS
shape (DESIGN.md §Arch-applicability): the sequence is cut into chunks
(streamed blocks), intra-chunk work is dense (quadratic within the chunk,
MXU-friendly), and a tiny carried state (the in-memory ``A`` analogue) is
passed between chunks by an associative scan. Decode keeps O(1) state per
token — this is why mamba2/hymba run the ``long_500k`` cell.

Shapes: x (B, S, d_inner) split into H heads of hd; B/C (B, S, G=1, N).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import silu


def _segsum(log_a):
    """segsum(x)[..., i, j] = sum_{j<k<=i} x[..., k] (lower-triangular)."""
    L = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def pick_chunk(S: int, chunk: int) -> int:
    """Largest divisor of S that is <= chunk (trace-time static)."""
    c = min(chunk, S)
    while S % c:
        c -= 1
    return c


def ssd_scan(x, dt, A, Bm, Cm, chunk: int):
    """SSD forward.

    x:  (B, S, H, hd)   values
    dt: (B, S, H)       softplus'd step sizes
    A:  (H,)            negative decay rates
    Bm: (B, S, N)       input gates  (single group)
    Cm: (B, S, N)       output gates
    Returns y (B, S, H, hd), final_state (B, H, hd, N).
    """
    Bsz, S, H, hd = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    r = lambda t: t.reshape(Bsz, nc, chunk, *t.shape[2:])
    xc, dtc, Bc, Cc = r(x), r(dt), r(Bm), r(Cm)

    dA = dtc * A[None, None, None, :]  # (B, nc, L, H) log-decay per step
    dA_cs = jnp.cumsum(dA, axis=2)

    # --- intra-chunk (dense, MXU): Y_diag = (C B^T ∘ L) (dt x) --------------
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (B, nc, H, L, L)
    CB = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)  # (B, nc, L, L)
    M = CB[:, :, None] * L  # (B, nc, H, L, L)
    xdt = xc * dtc[..., None]  # (B, nc, L, H, hd)
    y_diag = jnp.einsum("bchlm,bcmhp->bclhp", M, xdt)

    # --- chunk states: decay-to-end weighted outer products ------------------
    decay_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (B, nc, L, H)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, dtc * decay_end, xc)

    # --- inter-chunk recurrence (the streamed carried state) ----------------
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # (B, nc, H)

    def step(carry, inp):
        s_prev = carry  # (B, H, hd, N)
        s_c, dec = inp  # (B, H, hd, N), (B, H)
        s_new = s_prev * dec[:, :, None, None] + s_c
        return s_new, s_prev

    init = jnp.zeros((Bsz, H, hd, N), x.dtype)
    final, prev_states = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B, nc, H, hd, N)

    # --- inter-chunk output: y_off = C · decayed prev state ------------------
    decay_in = jnp.exp(dA_cs)  # (B, nc, L, H)
    y_off = jnp.einsum(
        "bcln,bchpn,bclh->bclhp", Cc, prev_states, decay_in
    )
    y = (y_diag + y_off).reshape(Bsz, S, H, hd)
    return y, final


def ssd_decode_step(state, x, dt, A, Bm, Cm):
    """One-token SSD update: state' = e^{dt A} state + dt B x^T; y = C state'.

    state: (B, H, hd, N); x: (B, 1, H, hd); dt: (B, 1, H); Bm/Cm: (B, 1, N).
    """
    dec = jnp.exp(dt[:, 0, :, None, None] * A[None, :, None, None])
    upd = jnp.einsum(
        "bn,bh,bhp->bhpn", Bm[:, 0], dt[:, 0], x[:, 0]
    )
    new_state = state * dec + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], new_state)
    return y[:, None], new_state  # (B, 1, H, hd)


def mamba_block(p: dict, x, *, cfg, cache=None, positions=None):
    """Full Mamba2 block: in_proj -> conv -> SSD -> gated out_proj.

    cache (decode): dict(state=(B,H,hd,N), conv=(B, K-1, conv_dim)).
    """
    Bsz, S, d = x.shape
    di, N, H = cfg.d_ssm_inner, cfg.ssm_state, cfg.n_ssm_heads
    hd, K = cfg.ssm_head_dim, cfg.ssm_conv

    # projection layout: z (di) | xBC (di + 2N) | dt (H)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N :]

    # depthwise causal conv over xBC (explicit window sum; K small)
    conv_w = p["conv_w"]  # (K, di + 2N)
    decoding = cache is not None and S == 1
    if decoding:
        pads = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B, K-1+1, ·)
    else:
        pads = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    new_conv = pads[:, pads.shape[1] - (K - 1):, :]
    conv = sum(
        pads[:, i : i + S, :] * conv_w[i][None, None, :] for i in range(K)
    )
    conv = silu(conv + p["conv_b"][None, None, :])

    xs = conv[..., :di].reshape(Bsz, S, H, hd)
    Bm = conv[..., di : di + N]
    Cm = conv[..., di + N :]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)

    if decoding:
        y, new_state = ssd_decode_step(
            cache["state"], xs.astype(jnp.float32), dt, A,
            Bm.astype(jnp.float32), Cm.astype(jnp.float32),
        )
        new_cache = dict(state=new_state, conv=new_conv)
    else:
        y, final = ssd_scan(
            xs.astype(jnp.float32), dt, A,
            Bm.astype(jnp.float32), Cm.astype(jnp.float32),
            chunk=pick_chunk(S, cfg.ssm_chunk),
        )
        # prefill: carry the final state + conv tail into the decode cache
        new_cache = dict(state=final, conv=new_conv) if cache is not None else None
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, S, di).astype(x.dtype) * silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, new_cache
