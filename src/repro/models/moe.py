"""Mixture-of-Experts FFN with expert parallelism.

The dispatch/combine is the GraphD message pattern applied to tokens
(DESIGN.md §Arch-applicability): tokens are messages, experts are vertices,
top-k routing is message sending, and the return path is a weighted-SUM
combine. Like the paper's OMSs, tokens are grouped *by destination expert*
into capacity-bounded buffers (the OMS capacity ℬ analogue); overflow is
dropped-and-counted exactly like a bounded splittable stream would surface
back-pressure, and the aux loss keeps the router balanced (Lemma-1 style
balance, but learned instead of hashed).

Sharding: the expert axis of all expert weights carries the 'model' mesh
axis (EP). The scatter into the (E, C, d) buffer and the gather back are
resharding points where XLA inserts the token all-to-all — visible in the
dry-run collective bytes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import silu
from repro.models.sharding import act_ecd


def moe_ffn(p: dict, x, *, n_experts: int, topk: int,
            capacity_factor: float = 1.25, n_shared: int = 0):
    """x: (B, S, d) -> (y, aux) where aux = (load-balance loss, drop frac)."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, topk)  # (T, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # --- dispatch: group token copies by destination expert (OMS layout) ----
    C = int(capacity_factor * topk * T / n_experts) + 1
    flat_e = eidx.reshape(-1)  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), topk)
    flat_g = gate.reshape(-1)
    # position of each copy within its expert's buffer (rank among same-e)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot  # 1-based rank
    pos = jnp.sum(pos_in_e, axis=-1) - 1  # (T*k,)
    keep = pos < C
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    slot = jnp.where(keep, flat_e * C + pos, n_experts * C)  # OOB -> dropped

    buf = jnp.zeros((n_experts * C + 1, d), xt.dtype).at[slot].set(
        xt[flat_t], mode="drop"
    )
    xe = act_ecd(buf[: n_experts * C].reshape(n_experts, C, d))

    # --- expert FFN (E sharded over 'model': this einsum IS the EP math) ----
    h = silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w_up"]
    )
    ye = act_ecd(jnp.einsum("ecf,efd->ecd", h, p["w_down"]))  # (E, C, d)

    # --- combine: weighted-sum scatter back to tokens (the SUM combiner) ----
    yflat = ye.reshape(n_experts * C, d)
    contrib = jnp.where(
        keep[:, None], yflat[jnp.clip(slot, 0, n_experts * C - 1)], 0.0
    ) * flat_g[:, None].astype(yflat.dtype)
    y = jnp.zeros((T, d), x.dtype).at[flat_t].add(contrib)

    if n_shared:
        hs = silu(jnp.einsum("td,df->tf", xt, p["ws_gate"])) * jnp.einsum(
            "td,df->tf", xt, p["ws_up"]
        )
        y = y + jnp.einsum("tf,fd->td", hs, p["ws_down"])

    # load-balance aux (Switch): E * sum_e f_e * p_e
    me = jnp.mean(jax.nn.one_hot(eidx, n_experts, dtype=jnp.float32),
                  axis=(0, 1))
    pe = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(me * pe)
    return y.reshape(B, S, d), (aux, dropped)
