"""int8 error-feedback gradient compression (distributed-optimization trick).

Before the data-parallel gradient reduction, each leaf is quantized to int8
with a per-leaf f32 scale; the quantization error is carried in an error
buffer and added back next step (error feedback keeps SGD/Adam convergence).
Halves-to-quarters the cross-pod reduce bytes — the collective-bytes delta is
visible in the dry-run roofline when `grad_compress=True`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_buffer(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize(g, err):
    """Returns (int8 codes, f32 scale, new error)."""
    g32 = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g32 - deq


def dequantize(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads, errs):
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errs)
    qs, scales, nes = [], [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = quantize(g, e)
        qs.append(q)
        scales.append(s)
        nes.append(ne)
    return (
        jax.tree.unflatten(tdef, qs),
        jax.tree.unflatten(tdef, scales),
        jax.tree.unflatten(tdef, nes),
    )


def decompress_tree(qs, scales, like):
    return jax.tree.map(
        lambda q, s, p: dequantize(q, s, jnp.float32), qs, scales, like
    )
