"""Training step: CE loss + AdamW, microbatch accumulation, optional int8
error-feedback gradient compression. Pure function of (params, opt, batch) —
this is what the dry-run lowers for every `train_4k` cell."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import forward
from repro.training.compress import compress_tree, decompress_tree, init_error_buffer
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


def ce_loss(cfg: ModelConfig, params, batch):
    """Next-token cross entropy (+ MoE load-balance aux)."""
    logits, aux = forward(
        cfg, params, batch["tokens"], media=batch.get("media")
    )
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + 0.01 * aux, dict(loss=loss, aux=aux)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics).

    With microbatches > 1, gradients accumulate over a lax.scan of micro
    slices (activation memory / global batch trade — a §Perf knob).
    """

    grad_fn = jax.value_and_grad(
        lambda p, b: ce_loss(cfg, p, b), has_aux=True
    )

    def compute_grads(params, batch):
        if microbatches == 1:
            (_, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        B = batch["tokens"].shape[0]
        assert B % microbatches == 0
        mb = B // microbatches
        resh = lambda x: x.reshape(microbatches, mb, *x.shape[1:])
        stacked = jax.tree.map(resh, batch)

        def body(carry, micro):
            acc, _ = carry
            (_, metrics), grads = grad_fn(params, micro)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                               acc, grads)
            return (acc, metrics), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (acc, metrics), _ = jax.lax.scan(
            body, (zeros, dict(loss=jnp.float32(0), aux=jnp.float32(0))),
            stacked,
        )
        grads = jax.tree.map(lambda a: a / microbatches, acc)
        return grads, metrics

    def train_step(params, opt_state, batch):
        grads, metrics = compute_grads(params, batch)
        if cfg.grad_compress:
            # int8 error-feedback quantization around the DP reduction.
            # (XLA's psum of the int8 codes is the compressed all-reduce.)
            errs = opt_state.get("err")
            qs, scales, new_err = compress_tree(grads, errs)
            grads = decompress_tree(qs, scales, grads)
            opt_state = dict(opt_state, err=new_err)
        err = opt_state.pop("err") if "err" in opt_state else None
        new_params, new_opt, stats = adamw_update(
            opt_cfg, params, grads, opt_state
        )
        if err is not None:
            new_opt["err"] = err
        metrics.update(stats)
        return new_params, new_opt, metrics

    return train_step


def init_train_state(cfg: ModelConfig, params):
    opt = init_opt_state(params)
    if cfg.grad_compress:
        opt["err"] = init_error_buffer(params)
    return opt
