"""AdamW, in-repo (no optax): f32 moments over bf16 params, global-norm
clip, linear-warmup cosine schedule. State is a pytree matching params, so
FSDP shards optimizer state exactly like weights (the ZeRO layout)."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def init_opt_state(params):
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return dict(
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
        step=jnp.zeros((), jnp.int32),
    )


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))
    lr = schedule(cfg, step)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu2 / (1 - cfg.b1 ** step)
        nhat = nu2 / (1 - cfg.b2 ** step)
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu2, nu2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, dict(mu=new_mu, nu=new_nu, step=step), dict(
        grad_norm=gn, lr=lr
    )
