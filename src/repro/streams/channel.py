"""Per-destination outbox→inbox message channels — the paper's §4 parallel
pipeline (U_s ∥ U_c ∥ U_r), reproduced at the host-thread boundary, in BOTH
directions: a background sender transmits finished groups while the fold
still computes, and a background receiver (:class:`ChannelReceiver` /
:func:`receive_iter`) densifies and digests the runs that have already
landed — full duplex, the "fully overlaps computation with communication"
of the paper's headline claim.

GraphD's headline design is that every worker "fully overlaps computation
with communication": while the compute thread is still folding edge blocks
for one destination group, the message groups that are already combined are
being serialized, optionally varint-delta compressed, and *transmitted* in
parallel by a dedicated sender. In this reproduction "transmission" is an
append to the destination shard's **inbox run files** (a
``streams.msgstore.MessageRunStore`` — one sorted run per transmitted group,
tagged with the producing source shard), which is exactly what a remote
GraphD machine would do with the bytes on arrival, and doubles as the
persisted-OMS message log of §3.4 when a ``RunFileMessageLog`` backs it.

:class:`ShardChannels` is that pipeline:

* ``send`` / ``send_raw`` enqueue one outgoing packet (a combined ``A_s``
  group, or one edge chunk's raw messages) onto a **bounded** in-flight
  queue — the producer blocks once ``inflight`` packets are queued, so the
  channel adds only a compiled-in constant to the engine's O(|V|/n) resident
  budget (each packet is at most one sparse group / one staged chunk);
* one background sender thread drains the queue in FIFO order: serializes,
  sorts raw packets by destination, appends to the inbox store, and runs the
  enqueued §3.3.1 compaction ops — all strictly in send order, so the inbox
  run table evolves exactly as the unpipelined engine's did (results can
  never depend on thread timing);
* ``flush`` is the per-destination barrier (all packets sent before the
  receiver digests an inbox), ``close`` the end-of-superstep join;
* :class:`ChannelStats` measures the overlap: ``send_seconds`` the sender
  spent transmitting vs ``stall_seconds`` the compute thread spent blocked
  on the channel — ``overlap_seconds`` (their difference) is transmit time
  hidden under compute, the quantity the paper's full-overlap claim is
  about (surfaced by ``benchmarks/bench_memory.py``);
* :class:`FaultPoint` is deterministic crash injection for fault drills: the
  sender thread dies after exactly N packets, mid-superstep, and the error
  surfaces on the next channel call — ``tests/test_fault.py`` drives
  recovery through it.

A sender crash can never publish a torn run: packets are appended atomically
at the Python level and the inbox index is only written at ``close_step``,
so recovery (``MessageRunStore`` re-``create`` on rerun, or
``recover_shard_streamed`` replay) starts from a consistent store.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.streams.msgstore import MessageRunStore


class ChannelError(RuntimeError):
    """The sender thread died; the original error is the ``__cause__``."""


@dataclass
class FaultPoint:
    """Deterministic fault injection: kill the sender thread once it has
    fully transmitted ``after_packets`` packets (barriers and compaction ops
    do not count). The count is cumulative across channels — i.e. across
    supersteps of one engine run, since packets flow in FIFO program order —
    so a single integer pins the crash to an exact packet of an exact
    superstep. Used by the crash drills in tests/test_fault.py.

    .. deprecated:: Kept only for the in-process (threads) sender drills.
       Everything process-level — socket sends/recvs, spill/store/checkpoint
       writes, coordinator kills — is driven by ``repro.fault``'s
       site-scoped :class:`~repro.fault.FaultSchedule` (the
       ``launch_opts["faults"]`` knob), which subsumes this single-counter
       hook; new drills should use that layer."""

    after_packets: int
    message: str = "injected sender fault"
    fired: bool = field(default=False)
    _count: int = field(default=0, repr=False)

    def record(self) -> None:
        self._count += 1
        if self._count >= self.after_packets:
            self.fired = True
            raise RuntimeError(self.message)


@dataclass
class ChannelStats:
    """Per-superstep channel accounting, both directions (surfaced by
    bench_memory's ``pipeline_overlap`` section)."""

    packets: int = 0
    messages: int = 0
    payload_bytes: int = 0  # pre-serialization bytes handed to the sender
    wire_bytes: int = 0  # bytes actually appended to the inbox files
    send_seconds: float = 0.0  # sender busy (serialize/compress/append)
    stall_seconds: float = 0.0  # compute thread blocked on the channel
    recv_runs: int = 0  # inbox runs digested by the background receiver
    recv_seconds: float = 0.0  # receiver busy (densify + digest / merge)
    recv_stall_seconds: float = 0.0  # compute thread blocked on the receiver
    # compress_payload="auto" verdict, e.g. "cnt=lossless(0.31) msg=raw(0.97)"
    # — per-channel scheme picked from the first-superstep sample's measured
    # codec ratios ("" until decided / when the knob is not "auto")
    payload_choice: str = ""

    def sender_overlap_seconds(self) -> float:
        """Transmit time hidden under compute: the sender was busy for
        ``send_seconds`` but only ``stall_seconds`` of it ever held the
        compute thread up — the rest ran under the fold (U_c ∥ U_s)."""
        return max(self.send_seconds - self.stall_seconds, 0.0)

    # pre-full-duplex name; ChannelStats used to account the sender only
    overlap_seconds = sender_overlap_seconds

    def receiver_overlap_seconds(self) -> float:
        """Digest time hidden under compute — the receiver-side dual
        (U_r ∥ U_c): the receiver was busy ``recv_seconds`` but only
        ``recv_stall_seconds`` of it held the compute thread at a collect
        barrier."""
        return max(self.recv_seconds - self.recv_stall_seconds, 0.0)

    def wire_ratio(self) -> float:
        """Pre-serialization payload bytes per byte actually put on the
        wire — the payload-codec shrink factor (1.0 when uncompressed)."""
        return self.payload_bytes / self.wire_bytes if self.wire_bytes else 1.0


_CLOSE = object()


class ShardChannels:
    """Outbox→inbox channels over one inbox store, one sender thread, and a
    bounded in-flight budget."""

    # cross-thread fields relying on GIL-atomic access instead of a lock:
    # _exc is write-once (sender thread) then read-only after _dead is set;
    # stats scalars are monotonic counters where a torn read is at worst a
    # stale-by-one report, never a control-flow input
    _LOCKED_FIELDS = frozenset({"_exc", "stats"})

    @staticmethod
    def packet_bytes(*, P: int, msg_itemsize: int, combined: bool,
                     chunk_slots: int = 0, compress: bool = False,
                     compress_payload=False) -> int:
        """Worst-case bytes of ONE in-flight packet — the unit of the §4
        channel RAM budget (``inflight * packet_bytes``), shared with the
        engine's memory_model and the resource planner. Combiner packets are
        one sparse combined group (<= P slots of dp+msg+cnt); raw packets one
        staged edge chunk (dp+msg+valid per slot). In-flight packets hold
        DECODED arrays (the sender encodes as it appends), so the RAM unit
        ignores the codecs; ``compress``/``compress_payload`` scale the
        *wire* estimate instead (see :func:`wire_bytes_per_message`)."""
        if combined:
            return P * (4 + msg_itemsize + 4)
        return chunk_slots * (4 + msg_itemsize + 1)

    @staticmethod
    def wire_bytes_per_message(*, msg_itemsize: int, combined: bool,
                               compress: bool = False,
                               compress_payload=False) -> float:
        """Estimated bytes ONE message costs on the wire — the unit of the
        planner's per-superstep network model. dp shrinks by the varint
        estimate under ``compress``; the msg (+ cnt) payload channels shrink
        by the payload-codec estimate under ``compress_payload`` (bf16
        additionally halves the msg channel before the codec)."""
        from repro.streams.codec import PAYLOAD_RATIO_ESTIMATE
        from repro.streams.store import COMPRESS_RATIO_ESTIMATE

        dp = 4 * COMPRESS_RATIO_ESTIMATE if compress else 4.0
        payload = msg_itemsize + (4 if combined else 0)  # msg (+ cnt)
        if compress_payload:
            if compress_payload == "bf16":
                payload = msg_itemsize / 2 + (4 if combined else 0)
            payload *= PAYLOAD_RATIO_ESTIMATE
        return dp + payload

    def __init__(self, inbox: MessageRunStore, inflight: int = 4,
                 fault: FaultPoint | None = None,
                 receiver: "ChannelReceiver | None" = None):
        if inflight < 1:
            raise ValueError("inflight budget must be >= 1")
        self.inbox = inbox
        self.inflight = inflight
        self.stats = ChannelStats()
        self._fault = fault
        # full-duplex mode: the sender notifies the receiver of every run it
        # lands, in append order, so digest order == transmit order
        self._receiver = receiver
        if receiver is not None and receiver.stats is None:
            receiver.stats = self.stats
        self._q: queue.Queue = queue.Queue(maxsize=inflight)
        self._exc: BaseException | None = None
        self._dead = threading.Event()
        self._aborting = threading.Event()
        self._worker = threading.Thread(
            target=self._run, name="channel-sender", daemon=True
        )
        self._worker.start()

    # -- producer side (the compute thread) ----------------------------------
    def send(self, dest: int, dp: np.ndarray, msg: np.ndarray,
             cnt: np.ndarray | None = None, tag: int = -1) -> None:
        """Transmit one already-combined, destination-sorted group (the
        sparse A_s(tag→dest) of §5): appended to ``dest``'s inbox as one
        tagged run. The arrays must be owned by the caller (they cross a
        thread boundary)."""
        self._put(("run", dest, dp, msg, cnt, tag))

    def send_combined(self, dest: int, A: np.ndarray, cnt: np.ndarray,
                      tag: int = -1) -> None:
        """Transmit one dense combined group A_s(tag→dest) (§5): the sender
        sparsifies (positions with cnt == 0 hold the combiner identity and
        are dropped on the wire) and appends one tagged run — serialization
        moves off the compute thread."""
        self._put(("combined", dest, A, cnt, tag))

    def send_raw(self, dest: int, dp: np.ndarray, msg: np.ndarray,
                 valid: np.ndarray, tag: int = -1) -> None:
        """Transmit one edge chunk's raw messages (combiner-less path): the
        sender filters invalid lanes, destination-sorts, and appends — the
        spill sort itself moves off the compute thread."""
        self._put(("raw", dest, dp, msg, valid, tag))

    def compact(self, dest: int, tag: int, fanin: int,
                read_chunk: int) -> None:
        """Enqueue a §3.3.1 bounded-fan-in compaction of ``tag``'s inbox
        runs; runs in send order like every other op."""
        self._put(("compact", dest, tag, fanin, read_chunk))

    def flush(self) -> None:
        """Barrier: returns once every previously enqueued op has been
        applied to the inbox (the receiver may digest after this). Raises
        if the sender died first — a barrier released by the death-path
        drain does NOT mean the ops before it landed."""
        done = threading.Event()
        self._put(("barrier", done))
        t0 = time.perf_counter()
        while not done.wait(timeout=0.05):
            if self._dead.is_set():
                break
        self.stats.stall_seconds += time.perf_counter() - t0
        if self._dead.is_set():
            # the sender processes ops FIFO and this thread is the only
            # producer, so a dead sender at this point means the barrier was
            # drained, not executed — ops before it may be missing
            self._raise()
            raise ChannelError("channel sender died before the barrier")

    def close(self) -> None:
        """Flush, stop the sender, and surface any sender error."""
        if self._worker.is_alive():
            self._offer_close()
            self._worker.join(timeout=10.0)
            self._check_stopped()
        self._raise()

    def abort(self) -> None:
        """Stop the sender WITHOUT surfacing its error — the crash-path
        cleanup (the superstep already failed; a second raise would mask
        the original). The sender discards any queued backlog (it is all
        destined for a store the caller is about to drop) instead of
        transmitting it, so abort returns promptly. A sender that still
        will not stop — hung mid-op — is the one exception that stays
        loud, or a rerun would truncate files a zombie thread keeps
        appending to."""
        self._aborting.set()
        self._offer_close()
        self._worker.join(timeout=10.0)
        self._check_stopped()

    def _offer_close(self) -> None:
        """Try to hand the sender a _CLOSE, giving up after 10s: a sender
        hung mid-op behind a full queue must fall through to join +
        _check_stopped (the loud hang report), not spin here forever."""
        deadline = time.monotonic() + 10.0
        while (self._worker.is_alive() and not self._dead.is_set()
               and time.monotonic() < deadline):
            try:
                self._q.put((_CLOSE,), timeout=0.05)
                return
            except queue.Full:
                pass

    def _check_stopped(self) -> None:
        if self._worker.is_alive():
            # python cannot kill a thread: surface the hang rather than let
            # the caller truncate/republish files the sender still writes
            raise ChannelError(
                "channel sender did not stop within 10s; its open file "
                "handles make the inbox store unsafe to reuse"
            )

    # -- internals ------------------------------------------------------------
    def _raise(self) -> None:
        if self._exc is not None:
            raise ChannelError("channel sender thread died") from self._exc

    def _put(self, item) -> None:
        t0 = time.perf_counter()
        while True:
            if self._dead.is_set():
                self.stats.stall_seconds += time.perf_counter() - t0
                self._raise()
                raise ChannelError("channel is closed")
            try:
                self._q.put(item, timeout=0.05)
                break
            except queue.Full:
                pass
        self.stats.stall_seconds += time.perf_counter() - t0

    def _run(self) -> None:
        try:
            while True:
                item = self._q.get()
                op = item[0]
                if op is _CLOSE or self._aborting.is_set():
                    return
                if op == "barrier":
                    item[1].set()
                    continue
                t0 = time.perf_counter()
                if op == "run":
                    _, dest, dp, msg, cnt, tag = item
                    seg = self.inbox.append_run(dest, dp, msg, cnt=cnt,
                                                tag=tag)
                    self._account(dp, msg, cnt, seg)
                    self._notify_receiver(dest, seg)
                elif op == "combined":
                    _, dest, A, cnt, tag = item
                    seg = self.inbox.append_combined(dest, A, cnt, tag=tag)
                    self._account_n(seg.length,
                                    seg.length * (4 + A.itemsize + 4), seg)
                    self._notify_receiver(dest, seg)
                elif op == "raw":
                    _, dest, dp, msg, valid, tag = item
                    seg = self.inbox.append_raw(dest, dp, msg, valid, tag=tag)
                    n = seg.length if seg is not None else 0
                    per = dp.itemsize + msg.itemsize
                    self._account_n(n, n * per, seg)
                elif op == "compact":
                    _, dest, tag, fanin, read_chunk = item
                    self.inbox.compact_tag(dest, tag, fanin, read_chunk)
                    self.stats.send_seconds += time.perf_counter() - t0
                    continue
                self.stats.send_seconds += time.perf_counter() - t0
                if self._fault is not None:
                    self._fault.record()
        except BaseException as e:
            self._exc = e
        finally:
            self._dead.set()
            # unblock producers waiting on a full queue; drained barriers
            # are set only to wake their waiters fast — flush() re-checks
            # _dead and refuses to treat a drained barrier as success
            while True:
                try:
                    leftover = self._q.get_nowait()
                    if leftover[0] == "barrier":
                        leftover[1].set()
                except queue.Empty:
                    break

    def _notify_receiver(self, dest: int, seg) -> None:
        if self._receiver is not None and seg is not None and seg.length:
            self._receiver.enqueue_digest(dest, seg)

    def _seg_wire_bytes(self, seg) -> int:
        """Bytes this run actually occupies in the inbox files (codec
        output for blob channels, fixed width otherwise)."""
        if seg is None or not seg.length:
            return 0
        inbox = self.inbox
        b = seg.dp_nbytes if seg.dp_nbytes >= 0 else seg.length * 4
        b += (seg.msg_nbytes if seg.msg_nbytes >= 0
              else seg.length * inbox.msg_dtype.itemsize)
        if inbox.with_counts:
            b += seg.cnt_nbytes if seg.cnt_nbytes >= 0 else seg.length * 4
        return b

    def _account(self, dp, msg, cnt, seg=None) -> None:
        self._account_n(int(dp.size), int(
            dp.nbytes + msg.nbytes + (cnt.nbytes if cnt is not None else 0)
        ), seg)

    def _account_n(self, messages: int, payload_bytes: int,
                   seg=None) -> None:
        self.stats.packets += 1
        self.stats.messages += messages
        self.stats.payload_bytes += payload_bytes
        self.stats.wire_bytes += self._seg_wire_bytes(seg)


class ChannelReceiver:
    """Background receiver — the U_r half of the §4 full overlap.

    The sender notifies it of every inbox run it lands (in append order);
    the receiver densifies the run back to a dense ``(A, cnt)`` pair
    (:meth:`MessageRunStore.read_combined`) and folds it into that
    destination's accumulator with the engine's jitted digest — all while
    the compute thread is still folding the NEXT group's edge chunks.
    Because digest order equals append order equals transmit order, the
    accumulated result is the exact per-position sequence of the
    half-duplex (digest-after-flush) path: full duplex is purely a
    scheduling change and results stay bit-identical.

    ``collect(dest)`` is the receiver-side barrier: it returns ``dest``'s
    finished accumulator once every digest enqueued before it has run
    (call it after the sender's ``flush()`` so all of ``dest``'s runs have
    been both appended and announced). The compute thread's wait there is
    ``recv_stall_seconds``; the receiver's total busy time minus it is the
    receiver overlap — digest time hidden under compute.

    ``fault`` is the receiver-side :class:`FaultPoint`: the thread dies
    after exactly N digested runs, mid-superstep; the error surfaces at the
    next ``collect``/``close`` and a torn inbox is never published
    (tests/test_fault.py drives recovery through it).
    """

    # same contract as ShardChannels: _exc write-once before _dead, stats
    # monotonic report-only counters — GIL-atomic by review
    _LOCKED_FIELDS = frozenset({"_exc", "stats"})

    def __init__(self, inbox: MessageRunStore, digest, identity, e0,
                 stats: ChannelStats | None = None,
                 fault: FaultPoint | None = None):
        self.inbox = inbox
        self._digest = digest  # (A, cnt, A_d, c_d) -> (A, cnt), blocking
        self._identity = identity  # () -> fresh (A, cnt)
        self._e0 = e0
        self.stats = stats
        self._fault = fault
        self._acc: dict[int, tuple] = {}
        self._q: queue.Queue = queue.Queue()
        self._exc: BaseException | None = None
        self._dead = threading.Event()
        self._worker = threading.Thread(
            target=self._run, name="channel-receiver", daemon=True
        )
        self._worker.start()

    # -- sender-thread side ---------------------------------------------------
    def enqueue_digest(self, dest: int, seg) -> None:
        """Announce one appended run (called by the channel sender; ops are
        descriptors only — the run data itself stays in the inbox files, so
        the queue never holds message payloads)."""
        self._q.put(("digest", dest, seg))

    # -- compute-thread side --------------------------------------------------
    def collect(self, dest: int):
        """Barrier + result: (A, cnt) for ``dest`` after every digest
        announced before this call has been folded in; the identity pair
        when no runs arrived (an all-skipped destination)."""
        box: list = [None]
        done = threading.Event()
        self._q.put(("collect", dest, box, done))
        t0 = time.perf_counter()
        while not done.wait(timeout=0.05):
            if self._dead.is_set():
                break
        if self.stats is not None:
            self.stats.recv_stall_seconds += time.perf_counter() - t0
        if self._dead.is_set() and not done.is_set():
            self._raise()
            raise ChannelError("channel receiver died before the collect")
        if self._exc is not None:
            self._raise()
        return box[0] if box[0] is not None else self._identity()

    def close(self) -> None:
        if self._worker.is_alive():
            self._q.put((_CLOSE,))
            self._worker.join(timeout=10.0)
            if self._worker.is_alive():
                raise ChannelError(
                    "channel receiver did not stop within 10s"
                )
        self._raise()

    def abort(self) -> None:
        """Crash-path stop WITHOUT surfacing the receiver's error (the
        superstep already failed; a second raise would mask the original).
        A receiver that will not stop — hung mid-digest — stays loud like
        the sender's: a zombie thread keeps the inbox run files open and
        would race any rerun that truncates them."""
        if self._worker.is_alive():
            self._q.put((_CLOSE,))
            self._worker.join(timeout=10.0)
            if self._worker.is_alive():
                raise ChannelError(
                    "channel receiver did not stop within 10s (aborting)"
                )

    # -- internals ------------------------------------------------------------
    def _raise(self) -> None:
        if self._exc is not None:
            raise ChannelError("channel receiver thread died") from self._exc

    def _run(self) -> None:
        try:
            while True:
                item = self._q.get()
                op = item[0]
                if op is _CLOSE:
                    return
                if op == "collect":
                    _, dest, box, done = item
                    box[0] = self._acc.pop(dest, None)
                    done.set()
                    continue
                _, dest, seg = item
                t0 = time.perf_counter()
                A_d, c_d = self.inbox.read_combined(dest, seg, self._e0)
                acc = self._acc.get(dest)
                if acc is None:
                    acc = self._identity()
                self._acc[dest] = self._digest(acc[0], acc[1], A_d, c_d)
                if self.stats is not None:
                    self.stats.recv_seconds += time.perf_counter() - t0
                    self.stats.recv_runs += 1
                if self._fault is not None:
                    self._fault.record()
        except BaseException as e:
            self._exc = e
        finally:
            self._dead.set()
            # wake collect() waiters fast; they re-check _dead and refuse
            # to treat a drained collect as success
            while True:
                try:
                    leftover = self._q.get_nowait()
                    if leftover[0] == "collect":
                        leftover[3].set()
                except queue.Empty:
                    break


def receive_iter(iterable, *, stats: ChannelStats | None = None,
                 fault: FaultPoint | None = None, depth: int = 2):
    """Receiver-thread prefetch over any staged stream — the combiner-less
    dual of :class:`ChannelReceiver`.

    ``streams.reader.prefetch_iter`` (the producer runs ``depth`` items
    ahead on a background thread) with the producer made an *accounted
    receiver*: its busy time lands in ``ChannelStats.recv_seconds``, the
    consumer's waits in ``recv_stall_seconds`` — so the OMS path's
    merge-read I/O hidden under apply compute shows up as receiver overlap —
    and a :class:`FaultPoint` kills the thread deterministically after N
    produced items (mid-merge crash drills). Producer errors surface on the
    consumer as :class:`ChannelError`.
    """
    from repro.streams.reader import prefetch_iter

    def on_item(seconds: float) -> None:
        if stats is not None:
            stats.recv_seconds += seconds
            stats.recv_runs += 1
        if fault is not None:
            fault.record()

    def on_wait(seconds: float) -> None:
        if stats is not None:
            stats.recv_stall_seconds += seconds

    return prefetch_iter(
        iterable, depth=depth, on_item=on_item, on_wait=on_wait,
        wrap_exc=lambda e: ChannelError("channel receiver thread died"),
        thread_name="channel-receiver",
    )
