"""Per-destination outbox→inbox message channels — the paper's §4 parallel
sender pipeline (U_s ∥ U_c), reproduced at the host-thread boundary.

GraphD's headline design is that every worker "fully overlaps computation
with communication": while the compute thread is still folding edge blocks
for one destination group, the message groups that are already combined are
being serialized, optionally varint-delta compressed, and *transmitted* in
parallel by a dedicated sender. In this reproduction "transmission" is an
append to the destination shard's **inbox run files** (a
``streams.msgstore.MessageRunStore`` — one sorted run per transmitted group,
tagged with the producing source shard), which is exactly what a remote
GraphD machine would do with the bytes on arrival, and doubles as the
persisted-OMS message log of §3.4 when a ``RunFileMessageLog`` backs it.

:class:`ShardChannels` is that pipeline:

* ``send`` / ``send_raw`` enqueue one outgoing packet (a combined ``A_s``
  group, or one edge chunk's raw messages) onto a **bounded** in-flight
  queue — the producer blocks once ``inflight`` packets are queued, so the
  channel adds only a compiled-in constant to the engine's O(|V|/n) resident
  budget (each packet is at most one sparse group / one staged chunk);
* one background sender thread drains the queue in FIFO order: serializes,
  sorts raw packets by destination, appends to the inbox store, and runs the
  enqueued §3.3.1 compaction ops — all strictly in send order, so the inbox
  run table evolves exactly as the unpipelined engine's did (results can
  never depend on thread timing);
* ``flush`` is the per-destination barrier (all packets sent before the
  receiver digests an inbox), ``close`` the end-of-superstep join;
* :class:`ChannelStats` measures the overlap: ``send_seconds`` the sender
  spent transmitting vs ``stall_seconds`` the compute thread spent blocked
  on the channel — ``overlap_seconds`` (their difference) is transmit time
  hidden under compute, the quantity the paper's full-overlap claim is
  about (surfaced by ``benchmarks/bench_memory.py``);
* :class:`FaultPoint` is deterministic crash injection for fault drills: the
  sender thread dies after exactly N packets, mid-superstep, and the error
  surfaces on the next channel call — ``tests/test_fault.py`` drives
  recovery through it.

A sender crash can never publish a torn run: packets are appended atomically
at the Python level and the inbox index is only written at ``close_step``,
so recovery (``MessageRunStore`` re-``create`` on rerun, or
``recover_shard_streamed`` replay) starts from a consistent store.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.streams.msgstore import MessageRunStore


class ChannelError(RuntimeError):
    """The sender thread died; the original error is the ``__cause__``."""


@dataclass
class FaultPoint:
    """Deterministic fault injection: kill the sender thread once it has
    fully transmitted ``after_packets`` packets (barriers and compaction ops
    do not count). The count is cumulative across channels — i.e. across
    supersteps of one engine run, since packets flow in FIFO program order —
    so a single integer pins the crash to an exact packet of an exact
    superstep. Used by the crash drills in tests/test_fault.py."""

    after_packets: int
    message: str = "injected sender fault"
    fired: bool = field(default=False)
    _count: int = field(default=0, repr=False)

    def record(self) -> None:
        self._count += 1
        if self._count >= self.after_packets:
            self.fired = True
            raise RuntimeError(self.message)


@dataclass
class ChannelStats:
    """Per-superstep channel accounting (surfaced by bench_memory)."""

    packets: int = 0
    messages: int = 0
    payload_bytes: int = 0  # pre-serialization bytes handed to the sender
    send_seconds: float = 0.0  # sender busy (serialize/compress/append)
    stall_seconds: float = 0.0  # compute thread blocked on the channel

    def overlap_seconds(self) -> float:
        """Transmit time hidden under compute: the sender was busy for
        ``send_seconds`` but only ``stall_seconds`` of it ever held the
        compute thread up — the rest ran under the fold (U_c ∥ U_s)."""
        return max(self.send_seconds - self.stall_seconds, 0.0)


_CLOSE = object()


class ShardChannels:
    """Outbox→inbox channels over one inbox store, one sender thread, and a
    bounded in-flight budget."""

    @staticmethod
    def packet_bytes(*, P: int, msg_itemsize: int, combined: bool,
                     chunk_slots: int = 0) -> int:
        """Worst-case bytes of ONE in-flight packet — the unit of the §4
        channel RAM budget (``inflight * packet_bytes``), shared with the
        engine's memory_model and the resource planner. Combiner packets are
        one sparse combined group (<= P slots of dp+msg+cnt); raw packets one
        staged edge chunk (dp+msg+valid per slot)."""
        if combined:
            return P * (4 + msg_itemsize + 4)
        return chunk_slots * (4 + msg_itemsize + 1)

    def __init__(self, inbox: MessageRunStore, inflight: int = 4,
                 fault: FaultPoint | None = None):
        if inflight < 1:
            raise ValueError("inflight budget must be >= 1")
        self.inbox = inbox
        self.inflight = inflight
        self.stats = ChannelStats()
        self._fault = fault
        self._q: queue.Queue = queue.Queue(maxsize=inflight)
        self._exc: BaseException | None = None
        self._dead = threading.Event()
        self._aborting = threading.Event()
        self._worker = threading.Thread(
            target=self._run, name="channel-sender", daemon=True
        )
        self._worker.start()

    # -- producer side (the compute thread) ----------------------------------
    def send(self, dest: int, dp: np.ndarray, msg: np.ndarray,
             cnt: np.ndarray | None = None, tag: int = -1) -> None:
        """Transmit one already-combined, destination-sorted group (the
        sparse A_s(tag→dest) of §5): appended to ``dest``'s inbox as one
        tagged run. The arrays must be owned by the caller (they cross a
        thread boundary)."""
        self._put(("run", dest, dp, msg, cnt, tag))

    def send_combined(self, dest: int, A: np.ndarray, cnt: np.ndarray,
                      tag: int = -1) -> None:
        """Transmit one dense combined group A_s(tag→dest) (§5): the sender
        sparsifies (positions with cnt == 0 hold the combiner identity and
        are dropped on the wire) and appends one tagged run — serialization
        moves off the compute thread."""
        self._put(("combined", dest, A, cnt, tag))

    def send_raw(self, dest: int, dp: np.ndarray, msg: np.ndarray,
                 valid: np.ndarray, tag: int = -1) -> None:
        """Transmit one edge chunk's raw messages (combiner-less path): the
        sender filters invalid lanes, destination-sorts, and appends — the
        spill sort itself moves off the compute thread."""
        self._put(("raw", dest, dp, msg, valid, tag))

    def compact(self, dest: int, tag: int, fanin: int,
                read_chunk: int) -> None:
        """Enqueue a §3.3.1 bounded-fan-in compaction of ``tag``'s inbox
        runs; runs in send order like every other op."""
        self._put(("compact", dest, tag, fanin, read_chunk))

    def flush(self) -> None:
        """Barrier: returns once every previously enqueued op has been
        applied to the inbox (the receiver may digest after this). Raises
        if the sender died first — a barrier released by the death-path
        drain does NOT mean the ops before it landed."""
        done = threading.Event()
        self._put(("barrier", done))
        t0 = time.perf_counter()
        while not done.wait(timeout=0.05):
            if self._dead.is_set():
                break
        self.stats.stall_seconds += time.perf_counter() - t0
        if self._dead.is_set():
            # the sender processes ops FIFO and this thread is the only
            # producer, so a dead sender at this point means the barrier was
            # drained, not executed — ops before it may be missing
            self._raise()
            raise ChannelError("channel sender died before the barrier")

    def close(self) -> None:
        """Flush, stop the sender, and surface any sender error."""
        if self._worker.is_alive():
            self._offer_close()
            self._worker.join(timeout=10.0)
            self._check_stopped()
        self._raise()

    def abort(self) -> None:
        """Stop the sender WITHOUT surfacing its error — the crash-path
        cleanup (the superstep already failed; a second raise would mask
        the original). The sender discards any queued backlog (it is all
        destined for a store the caller is about to drop) instead of
        transmitting it, so abort returns promptly. A sender that still
        will not stop — hung mid-op — is the one exception that stays
        loud, or a rerun would truncate files a zombie thread keeps
        appending to."""
        self._aborting.set()
        self._offer_close()
        self._worker.join(timeout=10.0)
        self._check_stopped()

    def _offer_close(self) -> None:
        """Try to hand the sender a _CLOSE, giving up after 10s: a sender
        hung mid-op behind a full queue must fall through to join +
        _check_stopped (the loud hang report), not spin here forever."""
        deadline = time.monotonic() + 10.0
        while (self._worker.is_alive() and not self._dead.is_set()
               and time.monotonic() < deadline):
            try:
                self._q.put((_CLOSE,), timeout=0.05)
                return
            except queue.Full:
                pass

    def _check_stopped(self) -> None:
        if self._worker.is_alive():
            # python cannot kill a thread: surface the hang rather than let
            # the caller truncate/republish files the sender still writes
            raise ChannelError(
                "channel sender did not stop within 10s; its open file "
                "handles make the inbox store unsafe to reuse"
            )

    # -- internals ------------------------------------------------------------
    def _raise(self) -> None:
        if self._exc is not None:
            raise ChannelError("channel sender thread died") from self._exc

    def _put(self, item) -> None:
        t0 = time.perf_counter()
        while True:
            if self._dead.is_set():
                self.stats.stall_seconds += time.perf_counter() - t0
                self._raise()
                raise ChannelError("channel is closed")
            try:
                self._q.put(item, timeout=0.05)
                break
            except queue.Full:
                pass
        self.stats.stall_seconds += time.perf_counter() - t0

    def _run(self) -> None:
        try:
            while True:
                item = self._q.get()
                op = item[0]
                if op is _CLOSE or self._aborting.is_set():
                    return
                if op == "barrier":
                    item[1].set()
                    continue
                t0 = time.perf_counter()
                if op == "run":
                    _, dest, dp, msg, cnt, tag = item
                    self.inbox.append_run(dest, dp, msg, cnt=cnt, tag=tag)
                    self._account(dp, msg, cnt)
                elif op == "combined":
                    _, dest, A, cnt, tag = item
                    seg = self.inbox.append_combined(dest, A, cnt, tag=tag)
                    self._account_n(seg.length,
                                    seg.length * (4 + A.itemsize + 4))
                elif op == "raw":
                    _, dest, dp, msg, valid, tag = item
                    seg = self.inbox.append_raw(dest, dp, msg, valid, tag=tag)
                    n = seg.length if seg is not None else 0
                    per = dp.itemsize + msg.itemsize
                    self._account_n(n, n * per)
                elif op == "compact":
                    _, dest, tag, fanin, read_chunk = item
                    self.inbox.compact_tag(dest, tag, fanin, read_chunk)
                    self.stats.send_seconds += time.perf_counter() - t0
                    continue
                self.stats.send_seconds += time.perf_counter() - t0
                if self._fault is not None:
                    self._fault.record()
        except BaseException as e:
            self._exc = e
        finally:
            self._dead.set()
            # unblock producers waiting on a full queue; drained barriers
            # are set only to wake their waiters fast — flush() re-checks
            # _dead and refuses to treat a drained barrier as success
            while True:
                try:
                    leftover = self._q.get_nowait()
                    if leftover[0] == "barrier":
                        leftover[1].set()
                except queue.Empty:
                    break

    def _account(self, dp, msg, cnt) -> None:
        self._account_n(int(dp.size), int(
            dp.nbytes + msg.nbytes + (cnt.nbytes if cnt is not None else 0)
        ))

    def _account_n(self, messages: int, payload_bytes: int) -> None:
        self.stats.packets += 1
        self.stats.messages += messages
        self.stats.payload_bytes += payload_bytes
