"""Varint-delta codec for the sorted position columns of on-disk streams.

The paper's streaming analysis (§3) argues cost in terms of *sequential disk
bandwidth*, so shrinking the byte stream is a direct superstep speedup: the
sorted ``dst_pos`` column of a message run (and the source-sorted ``src_pos``
column of an edge block) is monotone, so consecutive deltas are tiny and a
varint encoding stores most of them in one byte instead of four.

Encoding: first value absolute, the rest first-order deltas; every delta is
zigzag-mapped (so out-of-order inputs — e.g. the unsorted ``dst_pos`` column
of a source-sorted edge block, or the ``-1`` padding tail — still round-trip,
they just compress less) and LEB128 varint-packed, 7 bits per byte with a
continuation MSB.

Both directions are numpy-vectorized (no per-value Python loop):

* :func:`encode_varint_delta` builds the byte-length table for all values at
  once and scatters the 7-bit groups by position;
* :func:`decode_varint_delta` recovers value boundaries from the
  continuation bits with one cumulative sum and reassembles every value with
  a single ``np.add.at``.

:class:`VarintDeltaDecoder` is the streaming form: it decodes a blob in
bounded chunks while carrying the delta predecessor across calls, so the
external-merge cursors of ``streams/msgstore.py`` keep their O(read_chunk)
residency over compressed runs. Chained encoding (``prev=``) is the mirror
image, used by run compaction to emit one logical stream chunk-by-chunk.
"""

from __future__ import annotations

import numpy as np

_U64 = np.uint64
_MAX_VARINT_BYTES = 10  # ceil(64 / 7)


def encode_varint_delta(values: np.ndarray, prev: int | None = None) -> bytes:
    """Delta + zigzag + LEB128 encode ``values`` (any integer dtype).

    ``prev`` chains encoding across chunks of one logical stream: when given,
    the first delta is ``values[0] - prev`` instead of an absolute value, so
    ``encode(a) + encode(b, prev=a[-1])`` decodes identically to
    ``encode(concat(a, b))``.
    """
    v = np.asarray(values, dtype=np.int64)
    if v.ndim != 1:
        raise ValueError("encode_varint_delta takes a 1-D integer array")
    if v.size == 0:
        return b""
    d = np.empty_like(v)
    d[0] = v[0] if prev is None else v[0] - int(prev)
    np.subtract(v[1:], v[:-1], out=d[1:])
    # zigzag: sign bit to bit 0, magnitude doubled -> small |delta| stays small
    z = ((d << 1) ^ (d >> 63)).astype(_U64)

    nbytes = np.ones(z.shape, np.int64)
    rest = z >> _U64(7)
    while rest.any():
        nbytes += (rest > 0)
        rest >>= _U64(7)
    ends = np.cumsum(nbytes)
    starts = ends - nbytes
    out = np.zeros(int(ends[-1]), np.uint8)
    for j in range(int(nbytes.max())):
        m = nbytes > j
        group = ((z[m] >> _U64(7 * j)) & _U64(0x7F)).astype(np.uint8)
        cont = (nbytes[m] - 1 > j).astype(np.uint8) << 7
        out[starts[m] + j] = group | cont
    return out.tobytes()


def decode_varint_delta(data: bytes | np.ndarray,
                        prev: int | None = None) -> np.ndarray:
    """Inverse of :func:`encode_varint_delta`; returns int64 values.

    ``prev`` must match the value passed at encode time (None for a
    self-contained blob, the predecessor value for a chained chunk).
    """
    b = np.frombuffer(data, np.uint8) if not isinstance(data, np.ndarray) \
        else data.astype(np.uint8, copy=False)
    if b.size == 0:
        return np.empty((0,), np.int64)
    is_end = (b & 0x80) == 0
    if not is_end[-1]:
        raise ValueError("truncated varint stream (dangling continuation)")
    vid = np.zeros(b.size, np.int64)
    np.cumsum(is_end[:-1], out=vid[1:])
    val_starts = np.concatenate([[0], np.nonzero(is_end)[0][:-1] + 1])
    pos = np.arange(b.size, dtype=np.int64) - val_starts[vid]
    if int(pos.max()) >= _MAX_VARINT_BYTES:
        raise ValueError("varint longer than 10 bytes (corrupt stream)")
    z = np.zeros(int(vid[-1]) + 1, _U64)
    contrib = (b & 0x7F).astype(_U64) << (_U64(7) * pos.astype(_U64))
    np.add.at(z, vid, contrib)  # 7-bit groups never overlap -> add == or
    # un-zigzag in uint64 (a signed shift would sign-extend bit 63 and
    # corrupt |values| >= 2^62), then reinterpret the bits as int64
    d = ((z >> _U64(1)) ^ (_U64(0) - (z & _U64(1)))).view(np.int64)
    if prev is not None:
        d = d.copy()
        d[0] += int(prev)
    return np.cumsum(d)


class VarintDeltaDecoder:
    """Streaming decoder over one encoded blob: yields bounded chunks of
    values in order, holding only a cursor (byte position + predecessor) —
    the compressed-run counterpart of a fixed-size read window."""

    def __init__(self, blob: np.ndarray | bytes, n_values: int):
        self._blob = (np.frombuffer(blob, np.uint8)
                      if not isinstance(blob, np.ndarray) else blob)
        self._n = int(n_values)
        self._done = 0
        self._byte = 0
        self._prev: int | None = None

    @property
    def remaining(self) -> int:
        return self._n - self._done

    def take(self, count: int) -> np.ndarray:
        """Decode the next ``min(count, remaining)`` values."""
        count = min(int(count), self.remaining)
        if count <= 0:
            return np.empty((0,), np.int64)
        # a value is <= 10 bytes: a bounded byte window always covers `count`
        window = self._blob[self._byte:
                            self._byte + count * _MAX_VARINT_BYTES]
        is_end = (window & 0x80) == 0
        ends = np.nonzero(is_end)[0]
        if ends.size < count:
            raise ValueError("truncated varint stream (short blob)")
        used = int(ends[count - 1]) + 1
        vals = decode_varint_delta(window[:used], prev=self._prev)
        self._byte += used
        self._done += count
        self._prev = int(vals[-1])
        return vals
