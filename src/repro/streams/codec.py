"""Codecs for the byte streams the paper pays sequential bandwidth for.

The paper's streaming analysis (§3) argues cost in terms of *sequential disk
bandwidth*, so shrinking the byte stream is a direct superstep speedup. Two
codec families live here:

* **varint-delta** for sorted position columns: the sorted ``dst_pos`` of a
  message run (and the source-sorted ``src_pos`` of an edge block) is
  monotone, so consecutive deltas are tiny and a varint encoding stores most
  of them in one byte instead of four;
* **payload codec** for the value columns (message payloads, edge weights,
  combine counts): block-wise byte-plane shuffle + DEFLATE — similar floats
  share exponent/high-mantissa bytes, so transposing the byte planes turns
  them into long runs the stdlib ``zlib`` folds away, LOSSLESSLY (the
  equivalence matrix stays bit-identical). The optional ``"bf16"`` scheme
  additionally rounds float32 payloads to bfloat16 on the wire — the same
  trick ``mode="recoded_compact"`` plays in memory — halving the stream
  before the shuffle at the cost of bf16 rounding (float-message programs
  only; the engine enforces the same guard as recoded_compact).

Encoding: first value absolute, the rest first-order deltas; every delta is
zigzag-mapped (so out-of-order inputs — e.g. the unsorted ``dst_pos`` column
of a source-sorted edge block, or the ``-1`` padding tail — still round-trip,
they just compress less) and LEB128 varint-packed, 7 bits per byte with a
continuation MSB.

Both directions are numpy-vectorized (no per-value Python loop):

* :func:`encode_varint_delta` builds the byte-length table for all values at
  once and scatters the 7-bit groups by position;
* :func:`decode_varint_delta` recovers value boundaries from the
  continuation bits with one cumulative sum and reassembles every value with
  a single ``np.add.at``.

:class:`VarintDeltaDecoder` is the streaming form: it decodes a blob in
bounded chunks while carrying the delta predecessor across calls, so the
external-merge cursors of ``streams/msgstore.py`` keep their O(read_chunk)
residency over compressed runs. Chained encoding (``prev=``) is the mirror
image, used by run compaction to emit one logical stream chunk-by-chunk.
"""

from __future__ import annotations

import struct
import zlib

import numpy as np

_U64 = np.uint64
_MAX_VARINT_BYTES = 10  # ceil(64 / 7)

#: values per self-contained payload block: the unit of streaming decode —
#: a reader never holds more than one decoded block per cursor, so
#: compressed payload runs keep the same O(read_chunk)-class residency as
#: the fixed-width channels they replace
PAYLOAD_BLOCK = 4096

#: conservative planning estimate of the payload codec's shrink on message
#: payload channels (measured ~0.4x on combined (msg, cnt) PageRank wire
#: traffic; planners that promise less than the codec delivers stay
#: feasible). Shared with core/plan.py's net-budget ladder.
PAYLOAD_RATIO_ESTIMATE = 0.7

#: payload codec schemes: "lossless" = byte-plane shuffle + DEFLATE
#: (bit-exact round-trip for ANY dtype); "bf16" = float32 -> bfloat16
#: rounding first (recoded_compact's wire trick), then shuffle + DEFLATE
PAYLOAD_SCHEMES = ("lossless", "bf16")


def normalize_payload_scheme(compress_payload, allow_auto: bool = False
                             ) -> str | None:
    """THE ``compress_payload`` knob normalization — ``False`` -> None,
    ``True`` -> "lossless", a scheme name passes through. Every consumer
    (``ChannelConfig``, ``MessageRunStore``) delegates here so the accepted
    value set cannot drift from the codec's scheme table.

    ``"auto"`` (config surface only, hence opt-in via ``allow_auto``) defers
    the choice to a first-superstep sample: the engine spills the first
    superstep raw, measures the lossless codec on those runs via
    :class:`PayloadAutoPicker`, and picks lossless vs raw per value channel.
    Stores never see "auto" — they get the resolved scheme."""
    if not compress_payload:
        return None
    if compress_payload is True:
        return "lossless"
    if compress_payload == "auto" and allow_auto:
        return "auto"
    if compress_payload not in PAYLOAD_SCHEMES:
        raise ValueError(
            f"unknown compress_payload={compress_payload!r}; use a bool"
            f"{', auto' if allow_auto else ''} or one of {PAYLOAD_SCHEMES}"
        )
    return compress_payload


class PayloadAutoPicker:
    """First-superstep payload-codec sampling (``compress_payload="auto"``).

    The engine attaches one of these to the first superstep's message store
    (``MessageRunStore.payload_sampler``); ``offer`` sees every value column
    the store spills — possibly from the channel sender thread; the counter
    updates are GIL-atomic and there is a single writer — and trial-encodes
    the first ``max_samples`` runs per channel with the LOSSLESS codec. At
    superstep end the engine asks :meth:`choose` which channels measured a
    ratio better than ``threshold`` and fixes the wire format for every
    later superstep; raw spilling meanwhile means the sample costs no codec
    work on the critical path beyond the trial encodes themselves.
    """

    def __init__(self, max_samples: int = 8, threshold: float = 0.9):
        self.max_samples = int(max_samples)
        self.threshold = float(threshold)
        self._raw: dict[str, int] = {}  # channel -> sampled decoded bytes
        self._enc: dict[str, int] = {}  # channel -> lossless-encoded bytes
        self._n: dict[str, int] = {}  # channel -> runs sampled

    def offer(self, channel: str, values: np.ndarray) -> None:
        n = self._n.get(channel, 0)
        if n >= self.max_samples or values.size == 0:
            return
        arr = np.ascontiguousarray(values)
        self._n[channel] = n + 1
        self._raw[channel] = self._raw.get(channel, 0) + arr.nbytes
        self._enc[channel] = (self._enc.get(channel, 0)
                              + len(encode_payload(arr, "lossless")))

    @property
    def sampled(self) -> bool:
        return bool(self._n)

    def ratios(self) -> dict[str, float]:
        """Measured encoded/raw byte ratio per sampled channel (< 1 means
        the codec shrinks that channel's wire bytes)."""
        return {ch: self._enc[ch] / self._raw[ch]
                for ch in self._n if self._raw.get(ch)}

    def choose(self) -> tuple[str, ...]:
        """Channels whose measured ratio beats the threshold — the store's
        ``payload_channels`` for every subsequent superstep."""
        return tuple(sorted(ch for ch, r in self.ratios().items()
                            if r < self.threshold))

    def summary(self) -> str:
        """Human-readable record of the decision, e.g.
        ``"cnt=lossless(0.31) msg=raw(0.97)"`` — stored in
        ``ChannelStats.payload_choice``."""
        picked = set(self.choose())
        return " ".join(
            f"{ch}={'lossless' if ch in picked else 'raw'}({r:.2f})"
            for ch, r in sorted(self.ratios().items())
        )

_BLOCK_HEADER = struct.Struct("<II")  # (compressed nbytes, n values)


def encode_varint_delta(values: np.ndarray, prev: int | None = None) -> bytes:
    """Delta + zigzag + LEB128 encode ``values`` (any integer dtype).

    ``prev`` chains encoding across chunks of one logical stream: when given,
    the first delta is ``values[0] - prev`` instead of an absolute value, so
    ``encode(a) + encode(b, prev=a[-1])`` decodes identically to
    ``encode(concat(a, b))``.
    """
    v = np.asarray(values, dtype=np.int64)
    if v.ndim != 1:
        raise ValueError("encode_varint_delta takes a 1-D integer array")
    if v.size == 0:
        return b""
    d = np.empty_like(v)
    d[0] = v[0] if prev is None else v[0] - int(prev)
    np.subtract(v[1:], v[:-1], out=d[1:])
    # zigzag: sign bit to bit 0, magnitude doubled -> small |delta| stays small
    z = ((d << 1) ^ (d >> 63)).astype(_U64)

    nbytes = np.ones(z.shape, np.int64)
    rest = z >> _U64(7)
    while rest.any():
        nbytes += (rest > 0)
        rest >>= _U64(7)
    ends = np.cumsum(nbytes)
    starts = ends - nbytes
    out = np.zeros(int(ends[-1]), np.uint8)
    for j in range(int(nbytes.max())):
        m = nbytes > j
        group = ((z[m] >> _U64(7 * j)) & _U64(0x7F)).astype(np.uint8)
        cont = (nbytes[m] - 1 > j).astype(np.uint8) << 7
        out[starts[m] + j] = group | cont
    return out.tobytes()


def decode_varint_delta(data: bytes | np.ndarray,
                        prev: int | None = None) -> np.ndarray:
    """Inverse of :func:`encode_varint_delta`; returns int64 values.

    ``prev`` must match the value passed at encode time (None for a
    self-contained blob, the predecessor value for a chained chunk).
    """
    b = np.frombuffer(data, np.uint8) if not isinstance(data, np.ndarray) \
        else data.astype(np.uint8, copy=False)
    if b.size == 0:
        return np.empty((0,), np.int64)
    is_end = (b & 0x80) == 0
    if not is_end[-1]:
        raise ValueError("truncated varint stream (dangling continuation)")
    vid = np.zeros(b.size, np.int64)
    np.cumsum(is_end[:-1], out=vid[1:])
    val_starts = np.concatenate([[0], np.nonzero(is_end)[0][:-1] + 1])
    pos = np.arange(b.size, dtype=np.int64) - val_starts[vid]
    if int(pos.max()) >= _MAX_VARINT_BYTES:
        raise ValueError("varint longer than 10 bytes (corrupt stream)")
    z = np.zeros(int(vid[-1]) + 1, _U64)
    contrib = (b & 0x7F).astype(_U64) << (_U64(7) * pos.astype(_U64))
    np.add.at(z, vid, contrib)  # 7-bit groups never overlap -> add == or
    # un-zigzag in uint64 (a signed shift would sign-extend bit 63 and
    # corrupt |values| >= 2^62), then reinterpret the bits as int64
    d = ((z >> _U64(1)) ^ (_U64(0) - (z & _U64(1)))).view(np.int64)
    if prev is not None:
        d = d.copy()
        d[0] += int(prev)
    return np.cumsum(d)


class VarintDeltaDecoder:
    """Streaming decoder over one encoded blob: yields bounded chunks of
    values in order, holding only a cursor (byte position + predecessor) —
    the compressed-run counterpart of a fixed-size read window."""

    def __init__(self, blob: np.ndarray | bytes, n_values: int):
        self._blob = (np.frombuffer(blob, np.uint8)
                      if not isinstance(blob, np.ndarray) else blob)
        self._n = int(n_values)
        self._done = 0
        self._byte = 0
        self._prev: int | None = None

    @property
    def remaining(self) -> int:
        return self._n - self._done

    def take(self, count: int) -> np.ndarray:
        """Decode the next ``min(count, remaining)`` values."""
        count = min(int(count), self.remaining)
        if count <= 0:
            return np.empty((0,), np.int64)
        # a value is <= 10 bytes: a bounded byte window always covers `count`
        window = self._blob[self._byte:
                            self._byte + count * _MAX_VARINT_BYTES]
        is_end = (window & 0x80) == 0
        ends = np.nonzero(is_end)[0]
        if ends.size < count:
            raise ValueError("truncated varint stream (short blob)")
        used = int(ends[count - 1]) + 1
        vals = decode_varint_delta(window[:used], prev=self._prev)
        self._byte += used
        self._done += count
        self._prev = int(vals[-1])
        return vals


# --------------------------------------------------------------------------
# payload codec (value columns: message payloads, edge weights, counts)
# --------------------------------------------------------------------------

def _f32_to_bf16_bits(x: np.ndarray) -> np.ndarray:
    """float32 -> bfloat16 bit pattern (uint16), round-to-nearest-even —
    identical rounding to ``astype(jnp.bfloat16)`` so the wire matches what
    recoded_compact would have put in memory. NaN must bypass the rounding
    bias (it would carry into the exponent and turn NaN into ±0) and stays
    NaN with the quiet bit forced, matching the XLA convert."""
    b = np.ascontiguousarray(x, np.float32).view(np.uint32)
    rounding = np.uint32(0x7FFF) + ((b >> np.uint32(16)) & np.uint32(1))
    rounded = ((b + rounding) >> np.uint32(16)).astype(np.uint16)
    is_nan = (b & np.uint32(0x7FFFFFFF)) > np.uint32(0x7F800000)
    quiet_nan = ((b >> np.uint32(16)).astype(np.uint16)
                 | np.uint16(0x0040))
    return np.where(is_nan, quiet_nan, rounded)


def _bf16_bits_to_f32(b: np.ndarray) -> np.ndarray:
    return (b.astype(np.uint32) << np.uint32(16)).view(np.float32)


def _shuffle_bytes(arr: np.ndarray) -> bytes:
    """Byte-plane transposition: plane j holds byte j of every value, so the
    near-constant sign/exponent planes of similar floats (and the zero high
    bytes of small ints) become long runs DEFLATE collapses."""
    raw = np.ascontiguousarray(arr).view(np.uint8)
    return raw.reshape(arr.size, arr.itemsize).T.tobytes()


def _unshuffle_bytes(data: bytes, dtype: np.dtype, n: int) -> np.ndarray:
    planes = np.frombuffer(data, np.uint8).reshape(dtype.itemsize, n)
    return np.ascontiguousarray(planes.T).reshape(-1).view(dtype)[:n]


def encode_payload(values: np.ndarray, scheme: str = "lossless") -> bytes:
    """Encode a value column as self-contained compressed blocks.

    Block format: ``<u32 compressed nbytes><u32 n values><DEFLATE data>``,
    each covering up to :data:`PAYLOAD_BLOCK` values — so concatenating two
    encoded streams yields a valid encoded stream (run compaction emits
    merged runs chunk-by-chunk through :class:`PayloadEncoder`).
    """
    if scheme not in PAYLOAD_SCHEMES:
        raise ValueError(f"unknown payload scheme {scheme!r}")
    arr = np.ascontiguousarray(values)
    if arr.ndim != 1:
        raise ValueError("encode_payload takes a 1-D array")
    if scheme == "bf16":
        if arr.dtype != np.float32:
            raise ValueError("payload scheme 'bf16' needs float32 values")
        arr = _f32_to_bf16_bits(arr)
    out = []
    for off in range(0, arr.size, PAYLOAD_BLOCK):
        block = arr[off:off + PAYLOAD_BLOCK]
        comp = zlib.compress(_shuffle_bytes(block), 6)
        out.append(_BLOCK_HEADER.pack(len(comp), block.size))
        out.append(comp)
    return b"".join(out)


class PayloadEncoder:
    """Chunk-wise payload encoding for one logical stream: buffers values to
    full :data:`PAYLOAD_BLOCK` blocks so that feeding a stream in arbitrary
    small chunks (the external merge yields per-cursor fragments) produces
    the same dense block layout — and ratio — as one-shot encoding."""

    def __init__(self, dtype, scheme: str = "lossless"):
        self.dtype = np.dtype(dtype)
        self.scheme = scheme
        self._pending = np.empty((0,), self.dtype)

    def add(self, values: np.ndarray) -> bytes:
        """Absorb ``values``; returns the bytes of any blocks completed."""
        buf = np.concatenate(
            [self._pending, np.ascontiguousarray(values, self.dtype)]
        )
        full = (buf.size // PAYLOAD_BLOCK) * PAYLOAD_BLOCK
        self._pending = buf[full:]
        return encode_payload(buf[:full], self.scheme) if full else b""

    def flush(self) -> bytes:
        out = encode_payload(self._pending, self.scheme)
        self._pending = np.empty((0,), self.dtype)
        return out


class PayloadDecoder:
    """Streaming decoder over one encoded payload blob: yields bounded
    chunks of values in order, holding at most one decoded block — the
    compressed-payload counterpart of a fixed-size read window."""

    def __init__(self, blob: np.ndarray | bytes, dtype,
                 n_values: int, scheme: str = "lossless"):
        if scheme not in PAYLOAD_SCHEMES:
            raise ValueError(f"unknown payload scheme {scheme!r}")
        self._blob = (np.frombuffer(blob, np.uint8)
                      if not isinstance(blob, np.ndarray) else blob)
        self.dtype = np.dtype(dtype)
        self.scheme = scheme
        self._n = int(n_values)
        self._done = 0
        self._byte = 0
        self._buf = np.empty((0,), self.dtype)

    @property
    def remaining(self) -> int:
        return self._n - self._done

    def _next_block(self) -> np.ndarray:
        hdr = bytes(self._blob[self._byte:self._byte + _BLOCK_HEADER.size])
        if len(hdr) < _BLOCK_HEADER.size:
            raise ValueError("truncated payload stream (short header)")
        nbytes, nvals = _BLOCK_HEADER.unpack(hdr)
        start = self._byte + _BLOCK_HEADER.size
        comp = bytes(self._blob[start:start + nbytes])
        if len(comp) < nbytes:
            raise ValueError("truncated payload stream (short block)")
        self._byte = start + nbytes
        raw = zlib.decompress(comp)
        store_dt = np.dtype(np.uint16) if self.scheme == "bf16" else self.dtype
        vals = _unshuffle_bytes(raw, store_dt, nvals)
        if self.scheme == "bf16":
            vals = _bf16_bits_to_f32(vals)
        return vals

    def take(self, count: int) -> np.ndarray:
        """Decode the next ``min(count, remaining)`` values."""
        count = min(int(count), self.remaining)
        if count <= 0:
            return np.empty((0,), self.dtype)
        parts = []
        got = 0
        while got < count:
            if self._buf.size == 0:
                self._buf = self._next_block()
            take = min(count - got, self._buf.size)
            parts.append(self._buf[:take])
            self._buf = self._buf[take:]
            got += take
        self._done += count
        out = np.concatenate(parts) if len(parts) > 1 else parts[0]
        return np.ascontiguousarray(out)


def decode_payload(blob: np.ndarray | bytes, dtype, n_values: int,
                   scheme: str = "lossless") -> np.ndarray:
    """One-shot inverse of :func:`encode_payload`."""
    return PayloadDecoder(blob, dtype, n_values, scheme).take(n_values)
