"""Disk-spilled outgoing-message streams — the OMS tier of the paper (§3.3).

Combiner-less Pregel programs consume destination-sorted message *lists*
(``VertexProgram.apply_list``), so the streamed engine cannot scatter-combine
messages into an O(|V|/n) accumulator as it digests edge chunks. GraphD's
answer (§3.3.1) is the external merge-sort: every chunk of raw messages is
sorted by destination and appended to a local-disk run, and the runs are
k-way merged back into one destination-sorted stream at apply time. Pregelix
pays an external join/group-by for the same class of programs; here the
merge is a sequential scan of sorted runs — the access pattern the paper's
streaming analysis assumes.

``MessageRunStore`` is that tier:

* per destination shard ``k``, two flat binary append-only files
  (``oms-k.dp.bin`` destination positions, ``oms-k.msg.bin`` payloads; an
  optional ``oms-k.cnt.bin`` int32 channel carries combined-message counts
  when the store backs a message log) plus an in-memory run table — each run
  is a contiguous, destination-sorted segment of those files;
* with ``compress=True`` the sorted ``dp`` channel is varint-delta encoded
  (``streams/codec.py``): each run's positions become one self-contained
  blob, read back through a bounded streaming decoder, so the paper's
  sequential-bandwidth argument gets a smaller stream at the same
  O(read_chunk) residency;
* with ``compress_payload=`` the VALUE channels shrink too: the ``msg``
  payload (and, on combined stores, the ``cnt`` channel) are stored as
  payload-codec blobs — losslessly by default (byte-plane shuffle +
  DEFLATE; results stay bit-identical), or with bfloat16 wire rounding
  under the ``"bf16"`` scheme (float32 messages only, the
  ``recoded_compact`` guard) — again streamed back through bounded
  decoders;
* ``iter_merged`` — a k-way heap merge over the sorted runs that reads each
  run through a small fixed-size cursor buffer, so merge-time resident
  memory is O(fan-in · read_chunk), never O(messages);
* ``compact_tag`` — the multi-pass bounded-fan-in merge of §3.3.1: when a
  destination accumulates more runs than the merge may hold open, same-tag
  runs are merged into longer runs on disk until the fan-in bound holds
  (tags record the producing source shard, so log-backed stores never lose
  message attribution — single-shard recovery excludes the failed shard's
  own runs and regenerates them instead). Superseded segments become dead
  file regions; once a destination's dead bytes reach its live bytes,
  :meth:`vacuum` rewrites the files compactly, so compaction can no longer
  leak disk until the per-step store is deleted;
* ``merged_slices`` — fixed-capacity, *destination-aligned* slices of the
  merged stream, padded with the ``dst = P`` sentinel, ready for
  ``program.apply_list``. A vertex's whole message run always lands in one
  slice (the Pregel contract: ``compute()`` sees the full message list of a
  vertex), so slicing is invisible to any vertex-local program.

A JSON index (run table + geometry) makes a store re-openable after a crash,
which is what lets ``RunFileMessageLog`` (core/checkpoint.py) use these same
run files as the persisted OMSs of the paper's fast-recovery protocol — and
the pipelined engine's *inbox* files (streams/channel.py) are exactly these
stores, so transmitted-but-unapplied messages survive a crash the same way.

Read-path integrity: every appended run records a CRC32 per channel blob
(computed over the pristine bytes before they hit the page cache) in its
:class:`RunSegment`, persisted through the index. Readers verify a run's
checksums once before first use and raise
:class:`repro.fault.BlobCorruption` on mismatch — so a flipped bit on disk
(or injected by the chaos layer between write and read) is a detected,
named event the worker can quarantine and replay, never silently wrong
math. All blob writes route through the installed
:class:`repro.fault.FaultInjector` (if any), which is how the chaos
drills land ENOSPC/EIO/short-write/bit-flip faults at this tier.
"""

from __future__ import annotations

import dataclasses
import heapq
import json
import os
import shutil
import zlib
from dataclasses import dataclass

import numpy as np

import repro.fault as _fault
from repro.fault import BlobCorruption
from repro.streams.codec import (
    PayloadDecoder, PayloadEncoder, VarintDeltaDecoder, decode_varint_delta,
    encode_payload, encode_varint_delta, normalize_payload_scheme,
)

INDEX = "index.json"


@dataclass(frozen=True)
class RunSegment:
    """One sorted run: a contiguous slice of a destination's OMS files.

    ``offset``/``length`` are in messages (fixed-width channels are indexed
    by them directly); ``*_off``/``*_nbytes`` are the *byte* extent of a
    channel's compressed blob when that channel is encoded (-1 on
    fixed-width channels, where the extent is implied by offset/length):
    ``dp_*`` for the varint-delta position blob (``compress=True``),
    ``msg_*``/``cnt_*`` for the payload-codec blobs
    (``compress_payload=...``).
    """

    tag: int  # producing source shard (-1 = untagged)
    offset: int  # messages before this run in the files
    length: int  # messages in this run
    dp_off: int = -1  # byte offset of the compressed dp blob
    dp_nbytes: int = -1  # byte length of the compressed dp blob
    msg_off: int = -1  # byte offset of the payload-codec msg blob
    msg_nbytes: int = -1  # byte length of the payload-codec msg blob
    cnt_off: int = -1  # byte offset of the payload-codec cnt blob
    cnt_nbytes: int = -1  # byte length of the payload-codec cnt blob
    crc: str = ""  # comma-joined per-channel CRC32 hex ("" = legacy, unchecked)


#: RunSegment byte-extent fields per blob-encoded channel
_EXTENTS = {"dp": ("dp_off", "dp_nbytes"), "msg": ("msg_off", "msg_nbytes"),
            "cnt": ("cnt_off", "cnt_nbytes")}


class MessageRunStore:
    """Append-only per-destination sorted message runs + bounded k-way merge."""

    def __init__(self, directory: str, n_shards: int, P: int, msg_dtype,
                 with_counts: bool = False, create: bool = True,
                 compress: bool = False, compress_payload=False,
                 payload_channels=None):
        self.dir = directory
        self.n_shards = n_shards
        self.P = P
        self.msg_dtype = np.dtype(msg_dtype)
        self.with_counts = with_counts
        self.compress = bool(compress)
        # payload codec: msg channel in the requested scheme; the cnt
        # channel (combine counts must stay exact) always lossless
        self.payload_scheme = normalize_payload_scheme(compress_payload)
        # which value channels the codec covers: None = all of them; the
        # payload auto-pick narrows this to the channels whose measured
        # ratio paid off (a channel outside the set stays fixed-width)
        if payload_channels is not None:
            bad = set(payload_channels) - {"msg", "cnt"}
            if bad:
                raise ValueError(
                    f"payload_channels must be among ('msg', 'cnt'): {bad}")
            payload_channels = tuple(sorted(payload_channels))
        self.payload_channels = payload_channels
        # optional codec.PayloadAutoPicker: sees every value column this
        # store appends (set by the engine on the sampling superstep only)
        self.payload_sampler = None
        if self.payload_scheme == "bf16" and self.msg_dtype != np.float32:
            raise ValueError(
                "compress_payload='bf16' rounds float32 payloads on the "
                f"wire; this store carries {self.msg_dtype} messages"
            )
        self._runs: list[list[RunSegment]] = [[] for _ in range(n_shards)]
        self._sizes = [0] * n_shards  # messages written per destination
        # per-channel blob file bytes (encoded channels only)
        self._blob_bytes: dict[str, list[int]] = {
            ch: [0] * n_shards for ch in self._blob_channels()
        }
        # per-(dest, position) message counts: O(|V|) host ints, the slice
        # planner's only state (NOT O(messages))
        self._counts = np.zeros((n_shards, P), np.int64)
        self._wfh: dict[tuple[int, str], object] = {}
        # run identities whose CRCs verified clean (reads re-check only
        # segments not yet seen; set.add is GIL-atomic for the cross-thread
        # append/digest pattern)
        self._crc_ok: set[tuple] = set()
        # destinations whose _counts row must be rebuilt from the live runs
        # before use (set by open(); rebuilding eagerly would scan every
        # destination when a reader typically wants just one)
        self._stale_counts: set[int] = set()
        if create:
            os.makedirs(directory, exist_ok=True)
            # a re-created store restarts its step from scratch: truncate the
            # data files AND drop any index a crashed earlier attempt
            # published, or a later open() would map past the truncated files
            try:
                os.remove(os.path.join(directory, INDEX))
            except OSError:
                pass
            for k in range(n_shards):
                for ch in self._channels():
                    open(self._path(k, ch), "wb").close()

    def _channels(self) -> tuple[str, ...]:
        return ("dp", "msg", "cnt") if self.with_counts else ("dp", "msg")

    def _blob_channels(self) -> tuple[str, ...]:
        """Channels stored as per-run compressed blobs (byte-indexed)."""
        out = []
        if self.compress:
            out.append("dp")
        if self.payload_scheme is not None:
            covered = (self.payload_channels
                       if self.payload_channels is not None
                       else ("msg", "cnt"))
            if "msg" in covered:
                out.append("msg")
            if self.with_counts and "cnt" in covered:
                out.append("cnt")
        return tuple(out)

    def _is_blob(self, ch: str) -> bool:
        return ch in self._blob_bytes

    def _scheme(self, ch: str) -> str:
        return self.payload_scheme if ch == "msg" else "lossless"

    def _decoded_dtype(self, ch: str):
        return self.msg_dtype if ch == "msg" else np.dtype(np.int32)

    def _dtype(self, ch: str):
        if self._is_blob(ch):
            return np.dtype(np.uint8)
        return self._decoded_dtype(ch)

    def _encode(self, ch: str, values: np.ndarray) -> bytes:
        if ch == "dp":
            return encode_varint_delta(np.asarray(values, np.int64))
        return encode_payload(
            np.ascontiguousarray(values, self._decoded_dtype(ch)),
            self._scheme(ch),
        )

    def _blob_slice(self, mm: dict, seg: RunSegment, ch: str) -> np.ndarray:
        off_f, nb_f = _EXTENTS[ch]
        off, nb = getattr(seg, off_f), getattr(seg, nb_f)
        return mm[ch][off:off + nb]

    def _decoder(self, mm: dict, seg: RunSegment, ch: str):
        """Streaming decoder over one run's blob for ``ch`` (None when the
        channel is fixed-width and the memmap slice is the stream)."""
        if not self._is_blob(ch):
            return None
        blob = self._blob_slice(mm, seg, ch)
        if ch == "dp":
            return VarintDeltaDecoder(blob, seg.length)
        return PayloadDecoder(blob, self._decoded_dtype(ch), seg.length,
                              self._scheme(ch))

    def _path(self, dest: int, ch: str) -> str:
        return os.path.join(self.dir, f"oms-{dest:03d}.{ch}.bin")

    # -- writes ---------------------------------------------------------------
    def _handle(self, dest: int, ch: str):
        fh = self._wfh.get((dest, ch))
        if fh is None:
            fh = open(self._path(dest, ch), "ab")
            self._wfh[(dest, ch)] = fh
        return fh

    def _write(self, dest: int, ch: str, data: bytes, crc: int = 0) -> int:
        """Append one channel blob; returns the CRC32 of the pristine bytes.

        The checksum is computed BEFORE the bytes reach the injector/OS, so
        anything that mutates them on the way to (or at rest on) disk is
        caught by read-path verification. A failed write poisons the store:
        extents for the torn bytes are never published and the worker
        aborts the step (quarantine-and-replay regenerates the data).
        """
        crc = zlib.crc32(data, crc)
        fh = self._handle(dest, ch)
        inj = _fault.active()
        if inj is not None:
            inj.file_write(fh, data, site="io.write.spill",
                           path=self._path(dest, ch))
        else:
            fh.write(data)
        return crc

    @staticmethod
    def _crc_field(crcs: list[int]) -> str:
        return ",".join(f"{c & 0xFFFFFFFF:08x}" for c in crcs)

    def _verify(self, dest: int, seg: RunSegment, mm: dict) -> None:
        """Check one run's stored CRCs against the bytes on disk (memoized
        per segment identity; vacuum re-bases offsets, which re-keys)."""
        if not seg.crc:
            return  # legacy segment from a pre-CRC index: unverifiable
        key = (dest, seg.tag, seg.offset, seg.length, seg.crc)
        if key in self._crc_ok:
            return
        want = seg.crc.split(",")
        for ch, w in zip(self._channels(), want):
            if self._is_blob(ch):
                data = np.ascontiguousarray(
                    self._blob_slice(mm, seg, ch)).tobytes()
            else:
                data = np.ascontiguousarray(
                    mm[ch][seg.offset:seg.offset + seg.length]).tobytes()
            got = f"{zlib.crc32(data):08x}"
            if got != w:
                raise BlobCorruption(
                    self._path(dest, ch),
                    f"run tag={seg.tag} offset={seg.offset} "
                    f"length={seg.length} channel={ch}: "
                    f"stored crc32 {w} != read crc32 {got}",
                    directory=self.dir,
                )
        self._crc_ok.add(key)

    def append_run(self, dest: int, dp: np.ndarray, msg: np.ndarray,
                   cnt: np.ndarray | None = None, tag: int = -1) -> RunSegment:
        """Append one destination-sorted run for shard ``dest``.

        ``dp`` must be ascending (the chunk was sorted by destination before
        spilling); ``cnt`` is required iff the store carries a count channel.
        """
        if dp.size and np.any(np.diff(dp) < 0):
            raise ValueError("append_run requires destination-sorted input")
        if self.with_counts and cnt is None:
            raise ValueError("this store carries a count channel; pass cnt=")
        data = {"dp": dp, "msg": msg}
        if self.with_counts:
            data["cnt"] = cnt
        if self.payload_sampler is not None:
            for ch in self._channels():
                if ch != "dp":
                    self.payload_sampler.offer(ch, data[ch])
        extents: dict[str, int] = {}
        blob_len: dict[str, int] = {}
        crcs: list[int] = []
        for ch in self._channels():
            if self._is_blob(ch):
                blob = self._encode(ch, data[ch])
                off_f, nb_f = _EXTENTS[ch]
                extents[off_f] = self._blob_bytes[ch][dest]
                extents[nb_f] = len(blob)
                blob_len[ch] = len(blob)
                crcs.append(self._write(dest, ch, blob))
            else:
                crcs.append(self._write(
                    dest, ch,
                    np.ascontiguousarray(data[ch],
                                         self._decoded_dtype(ch)).tobytes()))
        seg = RunSegment(tag=tag, offset=self._sizes[dest],
                         length=int(dp.size), crc=self._crc_field(crcs),
                         **extents)
        for ch in self._channels():
            self._wfh[(dest, ch)].flush()
        # size counters move only AFTER the flush: the full-duplex receiver
        # maps read extents from these counters on another thread, and a
        # counter that ran ahead of the bytes on disk would make it mmap
        # past EOF (the sender's single-thread append order makes
        # post-flush publication sufficient)
        for ch, nb in blob_len.items():
            self._blob_bytes[ch][dest] += nb
        self._sizes[dest] += seg.length
        if dp.size:
            self._ensure_counts(dest)
            np.add.at(
                self._counts[dest], dp,
                cnt.astype(np.int64) if cnt is not None else 1,
            )
        self._runs[dest].append(seg)
        return seg

    def append_combined(self, dest: int, A: np.ndarray, cnt: np.ndarray,
                        tag: int = -1) -> RunSegment:
        """One dense combined buffer A_s(tag→dest) -> one sparse sorted run:
        positions with no messages hold the combiner identity by
        construction and are dropped on the wire. THE combined-group format
        — shared by the channel sender, the message log and recovery, so
        the three can never drift."""
        dp = np.nonzero(np.asarray(cnt) > 0)[0].astype(np.int32)
        return self.append_run(dest, dp, np.asarray(A)[dp],
                               cnt=np.asarray(cnt)[dp].astype(np.int32),
                               tag=tag)

    def read_combined(self, dest: int, seg: RunSegment, e0):
        """Inverse of :meth:`append_combined`: densify one sparse run back
        to full (P,) ``(A, cnt)`` buffers, identity at absent positions."""
        dp, msg, cnt = self.read_run(dest, seg)
        A = np.full((self.P,), e0, dtype=self.msg_dtype)
        A[dp] = msg
        c = np.zeros((self.P,), np.int32)
        c[dp] = cnt
        return A, c

    def append_raw(self, dest: int, dp: np.ndarray, msg: np.ndarray,
                   valid: np.ndarray, tag: int = -1) -> RunSegment | None:
        """One edge chunk's raw messages -> one sorted run: drop invalid
        lanes, stable-sort by destination, append. THE spill transform —
        shared by the inline engine path and the channel sender, so the
        pipelined run's byte-identical-results guarantee can never drift.
        Returns None when the chunk had no valid messages."""
        dpv = dp[valid]
        if not dpv.size:
            return None
        order = np.argsort(dpv, kind="stable")
        return self.append_run(dest, dpv[order], msg[valid][order], tag=tag)

    # -- run access -----------------------------------------------------------
    def runs(self, dest: int) -> list[RunSegment]:
        return list(self._runs[dest])

    def n_messages(self, dest: int) -> int:
        return int(self.dest_counts(dest).sum())

    def _ensure_counts(self, dest: int) -> None:
        if dest in self._stale_counts:
            self._stale_counts.discard(dest)
            for seg in self._runs[dest]:
                for part in self.iter_run(dest, seg, read_chunk=1 << 20):
                    weights = part[2] if self.with_counts else None
                    self._counts[dest] += np.bincount(
                        part[0], weights=weights, minlength=self.P
                    ).astype(np.int64)

    def dest_counts(self, dest: int) -> np.ndarray:
        """(P,) messages per destination position (max = the in-degree bound
        a single apply_list slice must hold — Pregel's per-vertex list)."""
        self._ensure_counts(dest)
        return self._counts[dest]

    def _read_mm(self, dest: int):
        """Fresh read memmaps over the currently-written extent (writers only
        ever append, so an open memmap never sees moving data). Snapshot the
        handle table: a channel sender may be opening handles for OTHER
        destinations while this destination is being merged."""
        for (d, ch), fh in list(self._wfh.items()):
            if d == dest:
                fh.flush()
        sizes = {
            ch: (self._blob_bytes[ch][dest] if self._is_blob(ch)
                 else self._sizes[dest])
            for ch in self._channels()
        }
        return {
            ch: (np.empty((0,), self._dtype(ch)) if sizes[ch] == 0 else
                 np.memmap(self._path(dest, ch), dtype=self._dtype(ch),
                           mode="r", shape=(sizes[ch],)))
            for ch in self._channels()
        }

    def read_run(self, dest: int, seg: RunSegment):
        """Materialize one run (tests / log densification — small runs)."""
        mm = self._read_mm(dest)
        self._verify(dest, seg, mm)
        sl = slice(seg.offset, seg.offset + seg.length)
        out = []
        for ch in self._channels():
            dec = self._decoder(mm, seg, ch)
            if dec is None:
                out.append(np.array(mm[ch][sl]))
            else:
                vals = dec.take(seg.length)
                out.append(np.asarray(vals, self._decoded_dtype(ch)))
        return tuple(out)

    def iter_run(self, dest: int, seg: RunSegment, read_chunk: int = 4096):
        """Stream one run in bounded chunks (per-channel tuples) — for
        copying arbitrarily long runs without materializing them."""
        mm = self._read_mm(dest)
        self._verify(dest, seg, mm)
        # blobs stay memmap views: the decoders read them in bounded
        # windows, so even a compaction-length run costs O(read_chunk) heap
        decs = {ch: self._decoder(mm, seg, ch) for ch in self._channels()}
        end = seg.offset + seg.length
        for off in range(seg.offset, end, max(1, read_chunk)):
            hi = min(off + max(1, read_chunk), end)
            yield tuple(
                (np.asarray(decs[ch].take(hi - off), self._decoded_dtype(ch))
                 if decs[ch] is not None else np.array(mm[ch][off:hi]))
                for ch in self._channels()
            )

    # -- the external merge (§3.3.1) -----------------------------------------
    def iter_merged(self, dest: int, read_chunk: int = 4096,
                    segments: list[RunSegment] | None = None):
        """K-way heap merge of the sorted runs of ``dest``; yields ascending
        per-channel numpy chunk tuples (``(dp, msg)``, plus ``cnt`` when the
        store carries it). Resident memory is O(runs · read_chunk): each run
        is read through a fixed-size cursor buffer, never whole."""
        segs = self._runs[dest] if segments is None else segments
        segs = [s for s in segs if s.length]
        if not segs:
            return
        mm = self._read_mm(dest)
        for s in segs:
            self._verify(dest, s, mm)
        channels = self._channels()
        cursors = [
            _Cursor(mm, s, read_chunk, channels,
                    decoders={ch: self._decoder(mm, s, ch)
                              for ch in channels})
            for s in segs
        ]
        heap = [(c.head, j) for j, c in enumerate(cursors)]
        heapq.heapify(heap)
        while heap:
            _, j = heapq.heappop(heap)
            cur = cursors[j]
            bound = heap[0][0] if heap else None
            yield cur.take_until(bound)
            if not cur.exhausted:
                heapq.heappush(heap, (cur.head, j))

    def compact_tag(self, dest: int, tag: int, fanin: int = 16,
                    read_chunk: int = 4096) -> None:
        """Multi-pass merge of all runs with this ``tag`` down to ONE run,
        never holding more than ``fanin`` cursors open (§3.3.1's bounded
        external merge-sort). All channels are rewritten together. Merged
        output is appended to the same files and the superseded segments
        become dead regions; :meth:`vacuum` reclaims them as soon as they
        outweigh the live data, so repeated compaction holds disk usage at
        <= 2x the live bytes instead of leaking until store deletion."""
        channels = self._channels()
        while True:
            mine = [s for s in self._runs[dest] if s.tag == tag]
            if len(mine) <= 1:
                self.vacuum_if_worthwhile(dest)
                return
            batch = mine[:max(2, fanin)]
            offset = self._sizes[dest]
            blob_start = {ch: self._blob_bytes[ch][dest]
                          for ch in self._blob_channels()}
            length = 0
            prev = None  # chains the varint deltas across merge chunks
            # payload blocks are self-contained, but the merge yields small
            # fragments — buffer them to full blocks so compaction keeps
            # the dense block layout (and ratio) of a one-shot encode
            encoders = {
                ch: PayloadEncoder(self._decoded_dtype(ch), self._scheme(ch))
                for ch in self._blob_channels() if ch != "dp"
            }
            # byte counts of the merged run accumulate locally; the
            # published counters (_blob_bytes/_sizes) move only after the
            # flush below, so a reader that maps mid-merge sees at most
            # the pre-merge extent (which the old segments fully cover)
            written = {ch: 0 for ch in self._blob_channels()}
            # per-channel CRC of the merged run accumulates across the
            # fragment writes (crc32 chains over concatenation)
            crcs = {ch: 0 for ch in channels}
            for part in self.iter_merged(dest, read_chunk, segments=batch):
                for ch, arr in zip(channels, part):
                    if ch == "dp" and self.compress:
                        blob = encode_varint_delta(
                            np.asarray(arr, np.int64), prev=prev)
                        prev = int(arr[-1])
                        crcs[ch] = self._write(dest, ch, blob, crcs[ch])
                        written[ch] += len(blob)
                    elif ch in encoders:
                        blob = encoders[ch].add(arr)
                        crcs[ch] = self._write(dest, ch, blob, crcs[ch])
                        written[ch] += len(blob)
                    else:
                        crcs[ch] = self._write(
                            dest, ch,
                            np.ascontiguousarray(
                                arr, self._dtype(ch)).tobytes(), crcs[ch])
                length += int(part[0].size)
            extents: dict[str, int] = {}
            for ch, enc in encoders.items():
                blob = enc.flush()
                crcs[ch] = self._write(dest, ch, blob, crcs[ch])
                written[ch] += len(blob)
            for ch in self._blob_channels():
                off_f, nb_f = _EXTENTS[ch]
                extents[off_f] = blob_start[ch]
                extents[nb_f] = written[ch]
            for ch in channels:
                if (dest, ch) in self._wfh:
                    self._wfh[(dest, ch)].flush()
            for ch in self._blob_channels():
                self._blob_bytes[ch][dest] += written[ch]
            self._sizes[dest] += length
            merged = RunSegment(tag=tag, offset=offset, length=length,
                                crc=self._crc_field(
                                    [crcs[ch] for ch in channels]),
                                **extents)
            keep = [s for s in self._runs[dest] if s not in batch]
            self._runs[dest] = keep + [merged]

    # -- dead-region reclamation ---------------------------------------------
    @staticmethod
    def fixed_bytes_per_message(msg_itemsize: int, with_counts: bool = False,
                                compress: bool = False) -> int:
        """Bytes per message in the fixed-width channels (msg [+ cnt], and dp
        when uncompressed) — the unit of the OMS-tier byte model, shared with
        the resource planner (core/plan.py) so predicted and realized window
        sizes use the same algebra."""
        b = int(msg_itemsize)
        if with_counts:
            b += 4
        if not compress:
            b += 4
        return b

    def _per_msg_fixed_bytes(self) -> int:
        """On-disk bytes per message in the FIXED-WIDTH channels of this
        store (blob-encoded channels are byte-accounted per run instead)."""
        b = 0
        for ch in self._channels():
            if not self._is_blob(ch):
                b += self._decoded_dtype(ch).itemsize
        return b

    def live_bytes(self, dest: int) -> int:
        live = sum(s.length for s in self._runs[dest])
        b = live * self._per_msg_fixed_bytes()
        for ch in self._blob_channels():
            nb_f = _EXTENTS[ch][1]
            b += sum(max(getattr(s, nb_f), 0) for s in self._runs[dest])
        return b

    def dead_bytes(self, dest: int) -> int:
        """Bytes of superseded (compacted-away) run data still on disk."""
        live = sum(s.length for s in self._runs[dest])
        b = (self._sizes[dest] - live) * self._per_msg_fixed_bytes()
        for ch in self._blob_channels():
            nb_f = _EXTENTS[ch][1]
            live_blob = sum(max(getattr(s, nb_f), 0)
                            for s in self._runs[dest])
            b += self._blob_bytes[ch][dest] - live_blob
        return b

    def vacuum_if_worthwhile(self, dest: int) -> bool:
        """Vacuum when the dead regions outweigh the live data — amortized
        O(1) rewrites per byte of compacted traffic."""
        dead = self.dead_bytes(dest)
        if dead and dead >= self.live_bytes(dest):
            self.vacuum(dest)
            return True
        return False

    def vacuum(self, dest: int) -> None:
        """Rewrite ``dest``'s files with only the live segments (chunked
        sequential copy — never materializes a run), atomically replacing
        the originals and re-basing every run's offsets. Reclaims the dead
        regions compaction leaves behind."""
        if not self.dead_bytes(dest):
            return
        channels = self._channels()
        for ch in channels:
            fh = self._wfh.pop((dest, ch), None)
            if fh is not None:
                fh.close()
        mm = self._read_mm(dest)
        tmp = {ch: open(self._path(dest, ch) + ".vacuum", "wb")
               for ch in channels}
        inj = _fault.active()

        def _copy(ch: str, data: bytes) -> None:
            # byte-identical copy, so each segment's recorded CRC survives
            # the rewrite; still injectable (ENOSPC mid-vacuum leaves the
            # originals untouched behind the atomic replace below)
            if inj is not None:
                inj.file_write(tmp[ch], data, site="io.write.spill",
                               path=self._path(dest, ch) + ".vacuum")
            else:
                tmp[ch].write(data)

        new_runs = []
        off = 0
        blob_off = {ch: 0 for ch in self._blob_channels()}
        for seg in self._runs[dest]:
            extents: dict[str, int] = {}
            for ch in channels:
                if self._is_blob(ch):
                    blob = np.ascontiguousarray(self._blob_slice(mm, seg, ch))
                    _copy(ch, blob.tobytes())
                    off_f, nb_f = _EXTENTS[ch]
                    extents[off_f] = blob_off[ch]
                    extents[nb_f] = int(blob.size)
                    blob_off[ch] += int(blob.size)
                else:
                    _copy(ch, np.ascontiguousarray(
                        mm[ch][seg.offset:seg.offset + seg.length]
                    ).tobytes())
            new_runs.append(dataclasses.replace(seg, offset=off, **extents))
            off += seg.length
        del mm  # drop the read maps over the old inodes before replacing
        for ch in channels:
            tmp[ch].flush()
            os.fsync(tmp[ch].fileno())  # bytes durable before the name moves
            tmp[ch].close()
            os.replace(self._path(dest, ch) + ".vacuum",
                       self._path(dest, ch))
        self._runs[dest] = new_runs
        self._sizes[dest] = off
        for ch, b in blob_off.items():
            self._blob_bytes[ch][dest] = b

    def merged_slices(self, dest: int, capacity: int, read_chunk: int = 4096):
        """Destination-aligned fixed-shape slices of the merged stream.

        Yields ``(sdp, smsg, covered)``: ``sdp``/``smsg`` are (capacity,)
        padded with the ``dst = P`` sentinel (payload 0), exactly the sorted
        IMS layout ``apply_list`` consumes in mode="basic"; ``covered`` is the
        (P,) bool mask of destinations whose ENTIRE message run is in this
        slice. Whole runs never straddle slices, so any vertex-local
        ``apply_list`` sees the same per-vertex list as the in-memory path.
        Buffers are freshly allocated per slice (safe to alias into jax).
        """
        counts = self.dest_counts(dest)
        max_run = int(counts.max()) if counts.size else 0
        if max_run > capacity:
            raise ValueError(
                f"slice capacity {capacity} < max per-vertex message run "
                f"{max_run}; raise msg_slice_cap (Pregel's compute() needs a "
                "vertex's whole message list resident)"
            )
        # plan cut points: greedily pack whole destination runs, ascending
        positions = np.nonzero(counts > 0)[0]
        plans: list[tuple[int, int, int]] = []  # (first_pos, last_pos, n_msgs)
        lo = 0
        acc = 0
        for idx, p in enumerate(positions):
            c = int(counts[p])
            if acc and acc + c > capacity:
                plans.append((int(positions[lo]), int(positions[idx - 1]), acc))
                lo, acc = idx, 0
            acc += c
        if acc:
            plans.append((int(positions[lo]), int(positions[-1]), acc))

        chunks = self.iter_merged(dest, read_chunk)
        carry_dp = np.empty((0,), np.int32)
        carry_msg = np.empty((0,), self.msg_dtype)
        for first, last, n_msgs in plans:
            sdp = np.full((capacity,), self.P, np.int32)
            smsg = np.zeros((capacity,), self.msg_dtype)
            filled = 0
            while filled < n_msgs:
                if carry_dp.size == 0:
                    carry_dp, carry_msg = next(chunks)[:2]
                take = min(n_msgs - filled, carry_dp.size)
                sdp[filled:filled + take] = carry_dp[:take]
                smsg[filled:filled + take] = carry_msg[:take]
                carry_dp, carry_msg = carry_dp[take:], carry_msg[take:]
                filled += take
            covered = np.zeros((self.P,), bool)
            covered[first:last + 1] = counts[first:last + 1] > 0
            yield sdp, smsg, covered

    # -- persistence (the log-backed use) ------------------------------------
    def save_index(self) -> None:
        index = dict(
            n_shards=self.n_shards, P=self.P,
            msg_dtype=self.msg_dtype.name, with_counts=self.with_counts,
            compress=self.compress,
            compress_payload=self.payload_scheme,
            payload_channels=self.payload_channels,
            sizes=self._sizes, blob_bytes=self._blob_bytes,
            runs=[[s.__dict__ for s in runs] for runs in self._runs],
        )
        tmp = os.path.join(self.dir, f".{INDEX}.tmp")
        with open(tmp, "w") as f:
            json.dump(index, f)
            f.flush()
            os.fsync(f.fileno())  # the index is the recovery root: no
            # publish until the extents it describes are durable
        os.replace(tmp, os.path.join(self.dir, INDEX))

    @classmethod
    def open(cls, directory: str) -> "MessageRunStore":
        with open(os.path.join(directory, INDEX)) as f:
            m = json.load(f)
        store = cls(directory, m["n_shards"], m["P"],
                    np.dtype(m["msg_dtype"]), with_counts=m["with_counts"],
                    create=False, compress=m.get("compress", False),
                    compress_payload=m.get("compress_payload") or False,
                    payload_channels=m.get("payload_channels"))
        store._sizes = list(m["sizes"])
        blob = m.get("blob_bytes")
        if blob is None and "dp_bytes" in m and store.compress:
            blob = {"dp": m["dp_bytes"]}  # pre-payload-codec index layout
        for ch in store._blob_channels():
            store._blob_bytes[ch] = list((blob or {}).get(
                ch, [0] * m["n_shards"]))
        store._runs = [
            [RunSegment(**s) for s in runs] for runs in m["runs"]
        ]
        # counts rebuild lazily, per destination, on first use (one chunked
        # scan of that destination's LIVE runs — compaction leaves dead file
        # regions that must not be counted): recovery reads one destination
        # per replayed step, so eagerly scanning all of them would multiply
        # recovery I/O by n for nothing
        store._stale_counts = {
            k for k in range(store.n_shards) if store._runs[k]
        }
        return store

    # -- accounting / lifecycle ----------------------------------------------
    def disk_bytes(self) -> int:
        total = 0
        for k in range(self.n_shards):
            for ch in self._channels():
                try:
                    total += os.path.getsize(self._path(k, ch))
                except OSError:
                    pass
        return total

    def clear_dest(self, dest: int) -> None:
        """Drop one destination's runs (its messages were applied; §3.3:
        an OMS is deleted once consumed — unless a log retains it)."""
        for ch in self._channels():
            fh = self._wfh.pop((dest, ch), None)
            if fh is not None:
                fh.close()
            try:
                os.remove(self._path(dest, ch))
            except OSError:
                pass
        self._runs[dest] = []
        self._sizes[dest] = 0
        for ch in self._blob_bytes:
            self._blob_bytes[ch][dest] = 0
        self._counts[dest] = 0
        self._stale_counts.discard(dest)

    def close(self) -> None:
        for fh in self._wfh.values():
            fh.close()
        self._wfh = {}

    def delete(self) -> None:
        self.close()
        shutil.rmtree(self.dir, ignore_errors=True)


class _Cursor:
    """Fixed-size read window over one sorted run (the merge's only per-run
    resident state). Tracks every store channel so compaction can rewrite
    payload AND count data together; on compressed stores an encoded
    channel's window is refilled by its streaming decoder (varint-delta for
    dp, payload codec for msg/cnt) instead of a memmap slice, keeping the
    same O(read_chunk) residency."""

    def __init__(self, mm: dict, seg: RunSegment, read_chunk: int,
                 channels: tuple[str, ...],
                 decoders: dict[str, object] | None = None):
        self._mm = mm
        self._channels = channels
        self._pos = seg.offset
        self._end = seg.offset + seg.length
        self._chunk = max(1, read_chunk)
        self._decs = decoders or {}
        self._bufs: tuple[np.ndarray, ...] = ()
        self._bpos = 0
        self._fill()

    def _fill(self) -> None:
        n = min(self._chunk, self._end - self._pos)
        bufs = []
        for ch in self._channels:
            dec = self._decs.get(ch)
            if dec is not None:
                vals = dec.take(n)
                bufs.append(np.asarray(
                    vals, np.int32 if ch != "msg" else vals.dtype))
            else:
                bufs.append(np.array(self._mm[ch][self._pos:self._pos + n]))
        self._bufs = tuple(bufs)
        self._pos += n
        self._bpos = 0

    @property
    def head(self) -> int:
        return int(self._bufs[0][self._bpos])

    @property
    def exhausted(self) -> bool:
        return self._bpos >= self._bufs[0].size and self._pos >= self._end

    def take_until(self, bound: int | None):
        """Return buffered elements with dp <= bound (>= 1 element; the heap
        guarantees head <= bound), refilling the window afterwards if empty."""
        dp = self._bufs[0]
        if bound is None:
            hi = dp.size
        else:
            hi = int(np.searchsorted(dp[self._bpos:], bound,
                                     side="right")) + self._bpos
        out = tuple(buf[self._bpos:hi] for buf in self._bufs)
        self._bpos = hi
        if self._bpos >= dp.size and self._pos < self._end:
            self._fill()
        return out
