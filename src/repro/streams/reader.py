"""Double-buffered prefetching stream reader — the paper's U_c ∥ U_s overlap
(C3) reproduced at the host/device boundary.

A background thread stages the next chunk of edge blocks from the
``EdgeStreamStore`` memmaps into a small pool of preallocated host buffers
while the device digests the current chunk. With ``depth=2`` this is classic
double buffering: one buffer in flight to the device, one being filled from
disk, so stream I/O hides behind compute whenever compute is the bottleneck
(and vice versa — exactly the full overlap GraphD argues for).

The schedule handed to :meth:`StreamReader.stream` is a list of
``(src_shard, dst_shard, block_ids)`` entries — typically the skip()-filtered
active blocks of every group for one superstep (see
``streams.schedule.plan_stream_schedule``). Blocks are staged in ``chunk_blocks``
groups so every chunk has ONE static shape: the jitted combine compiles once,
and partial chunks are padded with compute-neutral slots (``src = -1``).
"""

from __future__ import annotations

import queue
import sys
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.streams.store import EdgeStreamStore


@dataclass
class StagedChunk:
    """One staged group-chunk: host arrays of shape (chunk_blocks*edge_block,)."""

    src_shard: int
    dst_shard: int
    sp: np.ndarray
    dp: np.ndarray
    w: np.ndarray
    n_real_blocks: int
    _buf_id: int = -1  # pool slot, returned to the free list after consumption


@dataclass
class StreamStats:
    """Per-stream() accounting (surfaced by benchmarks)."""

    chunks: int = 0
    blocks_read: int = 0
    edges_staged: int = 0
    bytes_read: int = 0
    read_seconds: float = 0.0  # producer time spent filling buffers
    wait_seconds: float = 0.0  # consumer time spent blocked on the producer

    def throughput_edges_per_s(self) -> float:
        return self.edges_staged / self.read_seconds if self.read_seconds else 0.0


_DONE = object()


def prefetch_iter(iterable, depth: int = 2, *, on_item=None, on_wait=None,
                  wrap_exc=None, thread_name: str = "stream-prefetch"):
    """Run ``iterable`` in a background thread, ``depth`` items ahead — the
    same bounded-queue producer/consumer machinery :class:`StreamReader` uses
    for edge chunks, reusable for any staged stream (the msgstore external
    merge prefetches its destination-sorted apply slices through this, so
    merge-read I/O hides behind the apply compute exactly like edge reads
    hide behind the fold). Items must own their memory (no recycled buffers:
    the producer is ``depth`` items ahead of the consumer).

    Hooks (all optional — ``streams.channel.receive_iter`` is this function
    with receiver accounting and crash injection plugged in, so the tricky
    shutdown scaffolding exists exactly once):

    * ``on_item(seconds)`` — called on the PRODUCER thread after each item
      is produced, with the time producing it took; may raise to kill the
      producer (deterministic fault injection);
    * ``on_wait(seconds)`` — called on the consumer thread with the time it
      spent blocked waiting for each queue entry;
    * ``wrap_exc(exc) -> Exception`` — wraps a producer-side error before
      it is re-raised on the consumer (the original rides as __cause__).
    """
    if depth < 1:
        raise ValueError("depth must be >= 1")
    full: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def _put(item) -> bool:
        while not stop.is_set():
            try:
                full.put(item, timeout=0.05)
                return True
            except queue.Full:
                pass
        return False

    def _produce():
        try:
            it = iter(iterable)
            while True:
                t0 = time.perf_counter()
                try:
                    item = next(it)
                except StopIteration:
                    _put(_DONE)
                    return
                if on_item is not None:
                    on_item(time.perf_counter() - t0)
                if not _put(item):
                    return
        except BaseException as e:  # surface producer errors to the consumer
            _put(e)

    worker = threading.Thread(target=_produce, name=thread_name,
                              daemon=True)
    worker.start()
    try:
        while True:
            t0 = time.perf_counter()
            item = full.get()
            if on_wait is not None:
                on_wait(time.perf_counter() - t0)
            if item is _DONE:
                break
            if isinstance(item, BaseException):
                if wrap_exc is not None:
                    raise wrap_exc(item) from item
                raise item
            yield item
    finally:
        stop.set()
        while True:  # unblock a producer waiting on a full queue, then drain
            try:
                full.get_nowait()
            except queue.Empty:
                break
        worker.join(timeout=5.0)
        if worker.is_alive() and sys.exc_info()[1] is None:
            # a silent join-timeout here leaked the producer thread (and
            # whatever it holds open); stay quiet only when an exception is
            # already propagating — raising then would mask it
            raise RuntimeError(
                "prefetch producer thread did not stop within 5s"
            )


class StreamReader:
    """Background-thread prefetcher over an :class:`EdgeStreamStore`."""

    def __init__(self, store: EdgeStreamStore, chunk_blocks: int = 8,
                 depth: int = 2, owner_views: bool = False, residency=None):
        if depth < 1:
            raise ValueError("depth must be >= 1 (2 = double buffering)")
        self.store = store
        self.chunk_blocks = chunk_blocks
        self.depth = depth
        # optional BlockResidency (streams/residency.py): the producer asks
        # it for every chunk, so hot blocks are served from the bounded RAM
        # cache and only the cold tail costs disk I/O — the stats below then
        # count REAL reads, not staged blocks
        self.residency = residency
        self.stats = StreamStats()
        self._worker: threading.Thread | None = None
        self._stop = threading.Event()
        # owner_views: read each source shard's blocks through a view that
        # maps ONLY that shard's store row (manifest-driven row ownership —
        # the per-machine access pattern of a multi-process deployment,
        # exercised in-process by the pipelined engine)
        self._views: dict[int, EdgeStreamStore] | None = (
            {} if owner_views else None
        )

    def _reader_for(self, i: int) -> EdgeStreamStore:
        if self._views is None:
            return self.store
        view = self._views.get(i)
        if view is None:
            view = self._views[i] = self.store.owner_view(i)
        return view

    def staging_bytes(self) -> int:
        """Resident bytes pinned by one pass's buffer pool (a compiled-in
        constant — part of the O(1) streaming overhead, NOT a function of
        |E|): (depth+1) buffers of chunk_blocks*edge_block slots, 12 B each."""
        B = self.store.geom.edge_block
        return (self.depth + 1) * self.chunk_blocks * B * 12

    # -- the streaming loop --------------------------------------------------
    def stream(self, schedule):
        """Yield :class:`StagedChunk`s for ``schedule`` (list of
        ``(i, k, block_ids)``), prefetched ``depth`` chunks ahead by a
        background thread. The yielded buffers are only valid until the next
        iteration (the engine copies them to device on consumption)."""
        # guard against a producer left over from an aborted pass: stop it
        # before starting a new one, and never share buffers with it
        prev = self._worker
        if prev is not None and prev.is_alive():
            self._stop.set()
            prev.join(timeout=5.0)
            if prev.is_alive():
                raise RuntimeError(
                    "previous edge-stream prefetch thread did not stop; "
                    "refusing to start another pass"
                )
        self.stats = StreamStats()
        stats = self.stats
        CB = self.chunk_blocks
        B = self.store.geom.edge_block
        shape = (CB, B)
        # per-pass buffer pool (depth in-flight + 1 being consumed): a stale
        # producer from an earlier, abandoned pass can only ever touch its
        # own pass's buffers, never this one's
        pool = [
            (np.empty(shape, np.int32), np.empty(shape, np.int32),
             np.empty(shape, np.float32))
            for _ in range(self.depth + 1)
        ]
        full: queue.Queue = queue.Queue(maxsize=self.depth)
        free: queue.Queue = queue.Queue()
        for bid in range(len(pool)):
            free.put(bid)
        stop = self._stop = threading.Event()

        def _put(item) -> bool:
            while not stop.is_set():
                try:
                    full.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    pass
            return False

        def _produce():
            try:
                for i, k, ids in schedule:
                    for off in range(0, len(ids), CB):
                        bid = free.get()
                        if stop.is_set():
                            return
                        sp, dp, w = pool[bid]
                        t0 = time.perf_counter()
                        if self.residency is not None:
                            c, disk = self.residency.read_blocks(
                                self._reader_for(i), i, k,
                                ids[off:off + CB], sp, dp, w
                            )
                        else:
                            c = self._reader_for(i).read_blocks(
                                i, k, ids[off:off + CB], sp, dp, w
                            )
                            disk = c
                        stats.read_seconds += time.perf_counter() - t0
                        stats.chunks += 1
                        stats.blocks_read += disk
                        stats.bytes_read += disk * B * 12  # i32+i32+f32/edge
                        stats.edges_staged += int((sp[:c] >= 0).sum())
                        if not _put(StagedChunk(
                            src_shard=i, dst_shard=k,
                            sp=sp.reshape(-1), dp=dp.reshape(-1),
                            w=w.reshape(-1), n_real_blocks=c, _buf_id=bid,
                        )):
                            return
                _put(_DONE)
            except BaseException as e:  # surface disk errors to the consumer
                _put(e)

        worker = threading.Thread(target=_produce, name="edge-stream-prefetch",
                                  daemon=True)
        self._worker = worker
        worker.start()
        held: StagedChunk | None = None
        try:
            while True:
                t0 = time.perf_counter()
                item = full.get()
                stats.wait_seconds += time.perf_counter() - t0
                # the consumer has moved past the previous chunk — its buffer
                # can be refilled. The consumer MUST have finished reading it
                # (jnp may alias, not copy, these arrays on CPU; the engine
                # blocks on the fold's result before advancing)
                if held is not None:
                    free.put(held._buf_id)
                    held = None
                if item is _DONE:
                    break
                if isinstance(item, BaseException):
                    raise item
                held = item
                yield item
        finally:
            stop.set()
            # unblock a producer waiting on a free buffer, then drain
            free.put(0)
            worker.join(timeout=5.0)
            if worker.is_alive() and sys.exc_info()[1] is None:
                # same leak guard as prefetch_iter: a staging thread that
                # outlives its pass keeps store FDs (and mmap views) open
                raise RuntimeError(
                    "edge-stream staging thread did not stop within 5s"
                )
