"""Per-superstep read planning for the streamed engine (skip() before I/O)."""

from __future__ import annotations

import numpy as np

from repro.streams.store import EdgeStreamStore


def plan_stream_schedule(store: EdgeStreamStore, active: np.ndarray, *,
                         by_dest: bool = False):
    """skip()-filtered sequential read plan for one streamed superstep.

    ``active`` is the (n, P) host active bitmap. Returns
    ``(schedule, density, max_grp)``:

    * ``schedule`` — list of ``(src_shard, dst_shard, block_ids)``;
      destination-major (each destination's accumulator completes as early
      as possible, mirroring the ring's one-destination-at-a-time order) and
      ascending block ids within a group, so every group scan is one
      sequential read of the group-aligned on-disk layout;
    * ``density`` — fraction of nonempty blocks that are active (the same
      dispatch signal the in-memory engine derives from ``StepStats``);
    * ``max_grp`` — max active blocks in any group (Table-style accounting).

    With ``by_dest=True`` the first element is instead a length-n list whose
    entry k is dest shard k's slice of the same destination-major schedule
    (possibly empty). The combiner-less streamed path consumes this shape:
    it finishes one destination's message spill, merge-applies it, and frees
    its runs before the next destination's edges are even read — peak
    message-spill disk is the largest single destination, not the whole
    superstep's traffic.

    Blocks failing the §3.2 skip() test never appear in the schedule, so the
    reader never touches them on disk.
    """
    n = store.geom.n_shards
    prefixes = [
        np.concatenate([[0], np.cumsum(active[i].astype(np.int64))])
        for i in range(n)
    ]
    grouped: list[list] = [[] for _ in range(n)]
    total_active = 0
    max_grp = 0
    for k in range(n):
        for i in range(n):
            ids = store.active_blocks(i, k, prefixes[i])
            if ids.size:
                grouped[k].append((i, k, ids))
                total_active += int(ids.size)
                max_grp = max(max_grp, int(ids.size))
    density = total_active / max(store.nonempty_blocks(), 1)
    if by_dest:
        return grouped, density, max_grp
    return [entry for per_dest in grouped for entry in per_dest], density, max_grp
