"""Out-of-core streaming (paper §3–§4): the on-disk edge-block store, the
double-buffered prefetching reader behind the engine's ``streamed`` mode,
the disk-spilled outgoing-message (OMS) run store with its §3.3.1 external
merge for combiner-less programs, the full-duplex outbox→inbox channel
layer that overlaps transmission AND receiver digest with compute (§4),
the varint-delta codec behind the ``compress=`` knobs, and the payload
codec behind ``compress_payload=``.
"""

from repro.streams.store import EdgeStreamStore, StoreGeometry
from repro.streams.reader import (
    StagedChunk, StreamReader, StreamStats, prefetch_iter,
)
from repro.streams.schedule import plan_stream_schedule
from repro.streams.msgstore import MessageRunStore, RunSegment
from repro.streams.channel import (
    ChannelError, ChannelReceiver, ChannelStats, FaultPoint, ShardChannels,
    receive_iter,
)
from repro.streams.codec import (
    PayloadDecoder, PayloadEncoder, VarintDeltaDecoder, decode_payload,
    decode_varint_delta, encode_payload, encode_varint_delta,
)

__all__ = [
    "EdgeStreamStore",
    "StoreGeometry",
    "StagedChunk",
    "StreamReader",
    "StreamStats",
    "prefetch_iter",
    "plan_stream_schedule",
    "MessageRunStore",
    "RunSegment",
    "ChannelError",
    "ChannelReceiver",
    "ChannelStats",
    "FaultPoint",
    "ShardChannels",
    "receive_iter",
    "PayloadDecoder",
    "PayloadEncoder",
    "VarintDeltaDecoder",
    "decode_payload",
    "decode_varint_delta",
    "encode_payload",
    "encode_varint_delta",
]
