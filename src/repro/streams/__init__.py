"""Out-of-core streaming (paper §3): the on-disk edge-block store, the
double-buffered prefetching reader behind the engine's ``streamed`` mode, and
the disk-spilled outgoing-message (OMS) run store with its §3.3.1 external
merge for combiner-less programs.
"""

from repro.streams.store import EdgeStreamStore, StoreGeometry
from repro.streams.reader import (
    StagedChunk, StreamReader, StreamStats, prefetch_iter,
)
from repro.streams.schedule import plan_stream_schedule
from repro.streams.msgstore import MessageRunStore, RunSegment

__all__ = [
    "EdgeStreamStore",
    "StoreGeometry",
    "StagedChunk",
    "StreamReader",
    "StreamStats",
    "prefetch_iter",
    "plan_stream_schedule",
    "MessageRunStore",
    "RunSegment",
]
