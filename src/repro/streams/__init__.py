"""Out-of-core edge streaming (paper §3): the on-disk edge-block store and
the double-buffered prefetching reader behind the engine's ``streamed`` mode.
"""

from repro.streams.store import EdgeStreamStore, StoreGeometry
from repro.streams.reader import StagedChunk, StreamReader, StreamStats
from repro.streams.schedule import plan_stream_schedule

__all__ = [
    "EdgeStreamStore",
    "StoreGeometry",
    "StagedChunk",
    "StreamReader",
    "StreamStats",
    "plan_stream_schedule",
]
