"""On-disk edge-block store — the local edge stream S^E of the paper (§3.1).

GraphD's memory theorem (each machine needs only O(|V|/n) RAM) holds because
edges never live in memory: they are written once at partition time, in the
per-destination group layout of §3.3.1, and *streamed* back every superstep.
``EdgeStreamStore`` is that disk tier:

* three flat binary files (``sp.bin``/``dp.bin``/``w.bin``), each a memmap of
  logical shape ``(n, n, n_blocks, edge_block)`` in row-major order, so the
  blocks of one ``(src_shard, dst_shard)`` group are **contiguous on disk**
  and a group scan is one sequential read — the access pattern the paper's
  streaming analysis assumes;
* a JSON ``manifest.json`` with the static geometry plus a content signature
  (used by checkpoint recovery to refuse restoring state against the wrong
  edge streams);
* the skip() metadata (``blk_lo``/``blk_hi`` per block, §3.2) in
  ``blocks.npz``, kept host-resident — O(n · n_blocks) ints, not O(|E|) —
  so inactive blocks are *never read off disk*.

Padded slots carry ``src_pos = -1`` exactly like the in-memory layout, so a
staged block is compute-neutral in the engine's combine.
"""

from __future__ import annotations

import hashlib
import json
import os
from dataclasses import dataclass

import numpy as np

MANIFEST = "manifest.json"
BLOCKS = "blocks.npz"
_FILES = {"sp": np.int32, "dp": np.int32, "w": np.float32}
FORMAT_VERSION = 1


@dataclass(frozen=True)
class StoreGeometry:
    """Static shape of the on-disk layout (mirrors PartitionedGraph statics)."""

    n_shards: int
    n_vertices: int
    n_edges: int
    P: int
    E_cap: int
    edge_block: int
    n_blocks: int

    @property
    def shape(self) -> tuple[int, int, int, int]:
        n = self.n_shards
        return (n, n, self.n_blocks, self.edge_block)


class EdgeStreamStore:
    """Memmap-backed, write-once edge-block store with a block manifest."""

    def __init__(self, directory: str, geom: StoreGeometry,
                 blk_lo: np.ndarray, blk_hi: np.ndarray, signature: str):
        self.dir = directory
        self.geom = geom
        self.blk_lo = blk_lo  # (n, n, n_blocks) int32, P sentinel when empty
        self.blk_hi = blk_hi  # (n, n, n_blocks) int32, -1 sentinel when empty
        self._signature = signature
        self._mm = {
            name: np.memmap(os.path.join(directory, f"{name}.bin"),
                            dtype=dt, mode="r", shape=geom.shape)
            for name, dt in _FILES.items()
        }

    # -- construction --------------------------------------------------------
    @classmethod
    def create(
        cls,
        directory: str,
        src_pos: np.ndarray,  # (n, n, E_cap) int32, -1 padding
        dst_pos: np.ndarray,  # (n, n, E_cap) int32
        eweight: np.ndarray,  # (n, n, E_cap) float32
        *,
        edge_block: int,
        P: int,
        n_vertices: int,
        n_edges: int,
    ) -> "EdgeStreamStore":
        """Spill the per-destination edge groups to disk (done once, at
        partition time — the paper's graph-loading pass)."""
        n = src_pos.shape[0]
        E_cap = src_pos.shape[2]
        assert E_cap % edge_block == 0
        n_blocks = E_cap // edge_block
        geom = StoreGeometry(
            n_shards=n, n_vertices=n_vertices, n_edges=n_edges, P=P,
            E_cap=E_cap, edge_block=edge_block, n_blocks=n_blocks,
        )
        os.makedirs(directory, exist_ok=True)
        arrays = dict(
            sp=np.ascontiguousarray(src_pos, dtype=np.int32),
            dp=np.ascontiguousarray(dst_pos, dtype=np.int32),
            w=np.ascontiguousarray(eweight, dtype=np.float32),
        )
        for name, arr in arrays.items():
            mm = np.memmap(os.path.join(directory, f"{name}.bin"),
                           dtype=_FILES[name], mode="w+", shape=geom.shape)
            mm[:] = arr.reshape(geom.shape)
            mm.flush()
            del mm

        # skip() metadata: per-block source range (same contract as the
        # device layout's blk_lo/blk_hi)
        from repro.graph.partition import block_ranges

        blk_lo, blk_hi = block_ranges(arrays["sp"].reshape(geom.shape), P)
        np.savez(os.path.join(directory, BLOCKS), blk_lo=blk_lo, blk_hi=blk_hi)

        signature = cls._digest(geom, blk_lo, blk_hi, arrays)
        manifest = dict(
            version=FORMAT_VERSION, signature=signature,
            files={k: f"{k}.bin" for k in _FILES},
            **geom.__dict__,
        )
        tmp = os.path.join(directory, f".{MANIFEST}.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)
        os.replace(tmp, os.path.join(directory, MANIFEST))  # atomic publish
        return cls(directory, geom, blk_lo, blk_hi, signature)

    @classmethod
    def from_partition(cls, pg, directory: str) -> "EdgeStreamStore":
        """Spill a (fully materialized) PartitionedGraph's edge groups."""
        return cls.create(
            directory,
            np.asarray(pg.src_pos), np.asarray(pg.dst_pos),
            np.asarray(pg.eweight),
            edge_block=pg.edge_block, P=pg.P,
            n_vertices=pg.n_vertices, n_edges=pg.n_edges,
        )

    @classmethod
    def open(cls, directory: str) -> "EdgeStreamStore":
        with open(os.path.join(directory, MANIFEST)) as f:
            m = json.load(f)
        if m.get("version") != FORMAT_VERSION:
            raise ValueError(f"unsupported stream-store version {m.get('version')}")
        geom = StoreGeometry(**{k: m[k] for k in StoreGeometry.__dataclass_fields__})
        z = np.load(os.path.join(directory, BLOCKS))
        return cls(directory, geom, z["blk_lo"], z["blk_hi"], m["signature"])

    @staticmethod
    def _digest(geom: StoreGeometry, blk_lo, blk_hi, arrays) -> str:
        """Content signature: geometry + skip metadata + the edge data
        itself (two stores with equal topology but different weights must
        not look interchangeable to checkpoint recovery)."""
        h = hashlib.sha256()
        h.update(json.dumps(geom.__dict__, sort_keys=True).encode())
        h.update(np.ascontiguousarray(blk_lo).tobytes())
        h.update(np.ascontiguousarray(blk_hi).tobytes())
        for name in sorted(arrays):
            h.update(np.ascontiguousarray(arrays[name]).tobytes())
        return h.hexdigest()[:16]

    # -- identity / accounting -----------------------------------------------
    def signature(self) -> dict:
        """Stable identity of the edge streams, recorded in checkpoint
        manifests so recovery can detect a store/state mismatch."""
        return dict(store="edge-stream", signature=self._signature,
                    n_shards=self.geom.n_shards, n_edges=self.geom.n_edges)

    def disk_bytes(self) -> int:
        return sum(
            os.path.getsize(os.path.join(self.dir, f"{name}.bin"))
            for name in _FILES
        )

    # -- skip() (§3.2) -------------------------------------------------------
    def active_blocks(self, i: int, k: int, prefix: np.ndarray) -> np.ndarray:
        """Block ids of group (i, k) whose source range [lo, hi] contains an
        active vertex; ``prefix`` is the inclusive prefix sum (P+1,) of shard
        i's active bitmap. Returned ascending => the read is sequential."""
        lo = self.blk_lo[i, k]
        hi = self.blk_hi[i, k]
        nonempty = hi >= 0
        cnt = prefix[np.clip(hi + 1, 0, self.geom.P)] - prefix[np.clip(lo, 0, self.geom.P)]
        return np.nonzero(nonempty & (cnt > 0))[0].astype(np.int64)

    def nonempty_blocks(self) -> int:
        return int((self.blk_hi >= 0).sum())

    # -- reads ---------------------------------------------------------------
    def read_blocks(self, i: int, k: int, ids: np.ndarray,
                    out_sp: np.ndarray, out_dp: np.ndarray,
                    out_w: np.ndarray) -> int:
        """Read blocks ``ids`` of group (i, k) into the staging buffers
        (shape (chunk_blocks, edge_block) each); unused tail rows are padded
        (sp = -1) so the staged chunk is compute-neutral. Returns the number
        of real blocks staged."""
        c = len(ids)
        out_sp[c:] = -1
        out_dp[c:] = 0
        out_w[c:] = 0.0
        if c:
            self._mm["sp"][i, k].take(ids, axis=0, out=out_sp[:c])
            self._mm["dp"][i, k].take(ids, axis=0, out=out_dp[:c])
            self._mm["w"][i, k].take(ids, axis=0, out=out_w[:c])
        return c

    def group_edges(self, i: int, k: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Whole-group read (tests / tooling — not the streaming hot path)."""
        return (np.array(self._mm["sp"][i, k]), np.array(self._mm["dp"][i, k]),
                np.array(self._mm["w"][i, k]))
