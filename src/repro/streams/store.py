"""On-disk edge-block store — the local edge stream S^E of the paper (§3.1).

GraphD's memory theorem (each machine needs only O(|V|/n) RAM) holds because
edges never live in memory: they are written once at partition time, in the
per-destination group layout of §3.3.1, and *streamed* back every superstep.
``EdgeStreamStore`` is that disk tier:

* three flat binary files (``sp.bin``/``dp.bin``/``w.bin``); uncompressed,
  each is a memmap of logical shape ``(n, n, n_blocks, edge_block)`` in
  row-major order, so the blocks of one ``(src_shard, dst_shard)`` group are
  **contiguous on disk** and a group scan is one sequential read — the
  access pattern the paper's streaming analysis assumes. With
  ``compress=True`` the two position channels are stored as per-block
  varint-delta blobs (``streams/codec.py``; ``sp`` is sorted within a group,
  so its deltas are tiny) with an int64 offset table, and with
  ``compress_payload=True`` the weight channel is stored as per-block
  payload-codec blobs (lossless byte-shuffle + DEFLATE) the same way —
  both shrink the stream the paper's sequential-bandwidth argument pays
  for every superstep;
* a JSON ``manifest.json`` with the static geometry, a content signature
  (used by checkpoint recovery to refuse restoring state against the wrong
  edge streams), and a **row-ownership table**: per channel, the byte extent
  of every source shard's row, so machine i can map *only its own* stream
  S^E_i (``open(dir, owner=i)`` / :meth:`owner_view`) — the stepping stone
  to multi-process deployment where no machine ever maps a peer's edges;
* the skip() metadata (``blk_lo``/``blk_hi`` per block, §3.2) in
  ``blocks.npz``, kept host-resident — O(n · n_blocks) ints, not O(|E|) —
  so inactive blocks are *never read off disk*.

Padded slots carry ``src_pos = -1`` exactly like the in-memory layout, so a
staged block is compute-neutral in the engine's combine.
"""

from __future__ import annotations

import hashlib
import json
import os
import zlib
from dataclasses import dataclass

import numpy as np

import repro.fault as _fault
from repro.fault import BlobCorruption
from repro.streams.codec import (
    PAYLOAD_RATIO_ESTIMATE, decode_payload, decode_varint_delta,
    encode_payload, encode_varint_delta,
)


def _spill_write(f, data: bytes, crc: int, path: str) -> int:
    """Append ``data``, returning the running CRC32 of the pristine bytes.

    Routes through the installed chaos injector (site ``io.write.store``)
    so partition-time spills are fault-injectable like every other tier.
    """
    crc = zlib.crc32(data, crc)
    inj = _fault.active()
    if inj is not None:
        inj.file_write(f, data, site="io.write.store", path=path)
    else:
        f.write(data)
    return crc

MANIFEST = "manifest.json"
BLOCKS = "blocks.npz"
_FILES = {"sp": np.int32, "dp": np.int32, "w": np.float32}
_COMPRESSED_CHANNELS = ("sp", "dp")  # varint-delta (position structure)
_PAYLOAD_CHANNELS = ("w",)  # payload codec (no delta structure)
FORMAT_VERSION = 3  # v1/v2 readable: v2 added compress + row ownership,
#                     v3 added the payload-compressed weight channel

#: bytes per edge slot across the three channels (int32 sp + int32 dp +
#: float32 w) — the unit of every edge-tier byte model (device groups, disk
#: streams, staging pools). Kept next to the format it describes.
EDGE_SLOT_BYTES = sum(np.dtype(dt).itemsize for dt in _FILES.values())

#: conservative planning estimate of the varint-delta codec's shrink on the
#: position channels (PR 3 measured ~0.50x on RMAT streams; planners that
#: promise less than the codec delivers stay feasible).
COMPRESS_RATIO_ESTIMATE = 0.6

#: position-channel (sp+dp) vs weight-channel bytes of one edge slot
_POS_BYTES = 8
_W_BYTES = 4


def estimate_edge_disk_bytes(n_shards: int, E_cap: int,
                             compress: bool = False,
                             compress_payload: bool = False) -> int:
    """Predicted on-disk bytes of one shard's edge streams (its n
    per-destination groups) — the planner-side mirror of
    :meth:`EdgeStreamStore.disk_bytes`. ``compress`` shrinks the position
    channels by the varint estimate; ``compress_payload`` the weight
    channel by the payload-codec estimate."""
    pos = _POS_BYTES * (COMPRESS_RATIO_ESTIMATE if compress else 1.0)
    w = _W_BYTES * (PAYLOAD_RATIO_ESTIMATE if compress_payload else 1.0)
    return int(n_shards * E_cap * (pos + w))


@dataclass(frozen=True)
class StoreGeometry:
    """Static shape of the on-disk layout (mirrors PartitionedGraph statics)."""

    n_shards: int
    n_vertices: int
    n_edges: int
    P: int
    E_cap: int
    edge_block: int
    n_blocks: int

    @property
    def shape(self) -> tuple[int, int, int, int]:
        n = self.n_shards
        return (n, n, self.n_blocks, self.edge_block)


class EdgeStreamStore:
    """Memmap-backed, write-once edge-block store with a block manifest.

    ``owner`` restricts the instance to ONE source shard's row: only the
    bytes listed for that row in the manifest's ownership table are mapped,
    and reads for any other source raise — the per-machine view of the
    paper's deployment, emulated in-process by the pipelined engine.
    """

    def __init__(self, directory: str, geom: StoreGeometry,
                 blk_lo: np.ndarray, blk_hi: np.ndarray, signature: str,
                 *, compress: bool = False, compress_payload: bool = False,
                 row_bytes: dict[str, list[int]] | None = None,
                 block_index: dict[str, np.ndarray] | None = None,
                 owner: int | None = None):
        self.dir = directory
        self.geom = geom
        self.blk_lo = blk_lo  # (n, n, n_blocks) int32, P sentinel when empty
        self.blk_hi = blk_hi  # (n, n, n_blocks) int32, -1 sentinel when empty
        self.compress = bool(compress)
        self.compress_payload = bool(compress_payload)
        self.owner = owner
        self._signature = signature
        self._row_bytes = row_bytes or self._default_row_bytes(geom)
        self._block_index = block_index or {}
        if owner is not None and not 0 <= owner < geom.n_shards:
            raise ValueError(f"owner={owner} outside 0..{geom.n_shards - 1}")
        n, nb, B = geom.n_shards, geom.n_blocks, geom.edge_block
        rows = (owner, owner + 1) if owner is not None else (0, n)
        self._mm = {}
        for name, dt in _FILES.items():
            path = os.path.join(directory, f"{name}.bin")
            off = self._row_bytes[name][rows[0]]
            length = self._row_bytes[name][rows[1]] - off
            if self._is_blob(name):
                # byte-granular map of the owned rows' blobs only
                self._mm[name] = np.memmap(path, dtype=np.uint8, mode="r",
                                           offset=off, shape=(length,))
            else:
                self._mm[name] = np.memmap(
                    path, dtype=dt, mode="r", offset=off,
                    shape=(rows[1] - rows[0], n, nb, B),
                )

    def _is_blob(self, name: str) -> bool:
        """Channels stored as per-block compressed blobs."""
        return (self.compress and name in _COMPRESSED_CHANNELS) or (
            self.compress_payload and name in _PAYLOAD_CHANNELS
        )

    @staticmethod
    def _default_row_bytes(geom: StoreGeometry) -> dict[str, list[int]]:
        """Uncompressed layout: every channel row is one fixed stride."""
        n, nb, B = geom.n_shards, geom.n_blocks, geom.edge_block
        out = {}
        for name, dt in _FILES.items():
            stride = n * nb * B * np.dtype(dt).itemsize
            out[name] = [r * stride for r in range(n + 1)]
        return out

    def _row(self, name: str, i: int) -> np.ndarray:
        """The (n_dest, n_blocks, B) view of source row ``i`` (raw channels)."""
        if self.owner is not None:
            if i != self.owner:
                raise PermissionError(
                    f"store view owns only source shard {self.owner}'s rows; "
                    f"refusing to read shard {i}'s edge stream"
                )
            return self._mm[name][0]
        return self._mm[name][i]

    def _blob(self, name: str, i: int, k: int, b: int) -> np.ndarray:
        """One block's varint blob (compressed channels)."""
        if self.owner is not None and i != self.owner:
            raise PermissionError(
                f"store view owns only source shard {self.owner}'s rows; "
                f"refusing to read shard {i}'s edge stream"
            )
        idx = self._block_index[name]
        nb = self.geom.n_blocks
        flat = (i * self.geom.n_shards + k) * nb + b
        base = self._row_bytes[name][self.owner] if self.owner is not None else 0
        return self._mm[name][idx[flat] - base:idx[flat + 1] - base]

    # -- construction --------------------------------------------------------
    @classmethod
    def create(
        cls,
        directory: str,
        src_pos: np.ndarray,  # (n, n, E_cap) int32, -1 padding
        dst_pos: np.ndarray,  # (n, n, E_cap) int32
        eweight: np.ndarray,  # (n, n, E_cap) float32
        *,
        edge_block: int,
        P: int,
        n_vertices: int,
        n_edges: int,
        compress: bool = False,
        compress_payload: bool = False,
    ) -> "EdgeStreamStore":
        """Spill the per-destination edge groups to disk (done once, at
        partition time — the paper's graph-loading pass). ``compress``
        varint-delta encodes the position channels; ``compress_payload``
        payload-encodes the weight channel (losslessly), each as per-block
        blobs behind an offset table."""
        n = src_pos.shape[0]
        E_cap = src_pos.shape[2]
        assert E_cap % edge_block == 0
        n_blocks = E_cap // edge_block
        geom = StoreGeometry(
            n_shards=n, n_vertices=n_vertices, n_edges=n_edges, P=P,
            E_cap=E_cap, edge_block=edge_block, n_blocks=n_blocks,
        )
        os.makedirs(directory, exist_ok=True)
        arrays = dict(
            sp=np.ascontiguousarray(src_pos, dtype=np.int32),
            dp=np.ascontiguousarray(dst_pos, dtype=np.int32),
            w=np.ascontiguousarray(eweight, dtype=np.float32),
        )
        row_bytes: dict[str, list[int]] = {}
        index_arrays: dict[str, np.ndarray] = {}
        file_crcs: dict[str, str] = {}
        for name, arr in arrays.items():
            as_varint = compress and name in _COMPRESSED_CHANNELS
            as_payload = compress_payload and name in _PAYLOAD_CHANNELS
            path = os.path.join(directory, f"{name}.bin")
            crc = 0
            if as_varint or as_payload:
                enc = (encode_varint_delta if as_varint
                       else encode_payload)
                blocks = arr.reshape(n * n * n_blocks, edge_block)
                idx = np.zeros(len(blocks) + 1, np.int64)
                with open(path, "wb") as f:
                    for j, blk in enumerate(blocks):
                        blob = enc(blk)
                        crc = _spill_write(f, blob, crc, path)
                        idx[j + 1] = idx[j] + len(blob)
                index_arrays[name] = idx
                row_stride = n * n_blocks  # blocks per source row
                row_bytes[name] = [
                    int(idx[r * row_stride]) for r in range(n + 1)
                ]
            else:
                shaped = arr.reshape(geom.shape)
                with open(path, "wb") as f:
                    for r in range(n):  # per-row chunks: O(row) copy, not O(file)
                        crc = _spill_write(
                            f, np.ascontiguousarray(shaped[r]).tobytes(),
                            crc, path)
                stride = n * n_blocks * edge_block * np.dtype(
                    _FILES[name]).itemsize
                row_bytes[name] = [r * stride for r in range(n + 1)]
            file_crcs[name] = f"{crc & 0xFFFFFFFF:08x}"

        # skip() metadata: per-block source range (same contract as the
        # device layout's blk_lo/blk_hi)
        from repro.graph.partition import block_ranges

        blk_lo, blk_hi = block_ranges(arrays["sp"].reshape(geom.shape), P)
        np.savez(os.path.join(directory, BLOCKS), blk_lo=blk_lo, blk_hi=blk_hi,
                 **{f"{name}_idx": idx for name, idx in index_arrays.items()})

        signature = cls._digest(geom, blk_lo, blk_hi, arrays)
        manifest = dict(
            version=FORMAT_VERSION, signature=signature,
            files={k: f"{k}.bin" for k in _FILES},
            # per-file CRC32 of the bytes as written: read-path integrity
            # for the write-once edge tier (verify_integrity())
            crc32=file_crcs,
            compress=bool(compress),
            compress_payload=bool(compress_payload),
            # manifest-driven row ownership: machine i maps only the byte
            # extent [row_bytes[ch][i], row_bytes[ch][i+1]) of each channel
            row_ownership=dict(axis="src_shard", row_bytes=row_bytes),
            **geom.__dict__,
        )
        tmp = os.path.join(directory, f".{MANIFEST}.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())  # manifest durable before the name appears
        os.replace(tmp, os.path.join(directory, MANIFEST))  # atomic publish
        return cls(directory, geom, blk_lo, blk_hi, signature,
                   compress=compress, compress_payload=compress_payload,
                   row_bytes=row_bytes, block_index=index_arrays)

    @classmethod
    def from_partition(cls, pg, directory: str, compress: bool = False,
                       compress_payload: bool = False) -> "EdgeStreamStore":
        """Spill a (fully materialized) PartitionedGraph's edge groups."""
        return cls.create(
            directory,
            np.asarray(pg.src_pos), np.asarray(pg.dst_pos),
            np.asarray(pg.eweight),
            edge_block=pg.edge_block, P=pg.P,
            n_vertices=pg.n_vertices, n_edges=pg.n_edges,
            compress=compress, compress_payload=compress_payload,
        )

    @classmethod
    def open(cls, directory: str, owner: int | None = None) -> "EdgeStreamStore":
        with open(os.path.join(directory, MANIFEST)) as f:
            m = json.load(f)
        if m.get("version") not in (1, 2, FORMAT_VERSION):
            raise ValueError(f"unsupported stream-store version {m.get('version')}")
        geom = StoreGeometry(**{k: m[k] for k in StoreGeometry.__dataclass_fields__})
        z = np.load(os.path.join(directory, BLOCKS))
        compress = m.get("compress", False)
        compress_payload = m.get("compress_payload", False)
        ownership = m.get("row_ownership") or {}
        row_bytes = ownership.get("row_bytes")
        block_index = {
            name: z[f"{name}_idx"]
            for name in _COMPRESSED_CHANNELS + _PAYLOAD_CHANNELS
            if f"{name}_idx" in z.files
        }
        return cls(directory, geom, z["blk_lo"], z["blk_hi"], m["signature"],
                   compress=compress, compress_payload=compress_payload,
                   row_bytes=row_bytes, block_index=block_index, owner=owner)

    def owner_view(self, shard: int) -> "EdgeStreamStore":
        """A view of this store that maps ONLY ``shard``'s source row — what
        machine ``shard`` would open in a multi-process deployment."""
        return EdgeStreamStore(
            self.dir, self.geom, self.blk_lo, self.blk_hi, self._signature,
            compress=self.compress, compress_payload=self.compress_payload,
            row_bytes=self._row_bytes,
            block_index=self._block_index, owner=shard,
        )

    @staticmethod
    def _digest(geom: StoreGeometry, blk_lo, blk_hi, arrays) -> str:
        """Content signature: geometry + skip metadata + the edge data
        itself (two stores with equal topology but different weights must
        not look interchangeable to checkpoint recovery). Computed over the
        LOGICAL arrays, so a compressed and an uncompressed spill of the
        same graph are interchangeable to recovery — as they should be."""
        h = hashlib.sha256()
        h.update(json.dumps(geom.__dict__, sort_keys=True).encode())
        h.update(np.ascontiguousarray(blk_lo).tobytes())
        h.update(np.ascontiguousarray(blk_hi).tobytes())
        for name in sorted(arrays):
            h.update(np.ascontiguousarray(arrays[name]).tobytes())
        return h.hexdigest()[:16]

    def verify_integrity(self) -> None:
        """Recompute each channel file's CRC32 against the manifest record.

        Raises :class:`repro.fault.BlobCorruption` naming the first file
        whose bytes no longer match what partition time wrote. Called by
        recovering workers before checkpoint-lineage replay (an O(|E|)
        sequential read — cheap next to the replay itself) and by the chaos
        harness; silently a no-op on legacy manifests without checksums.
        """
        with open(os.path.join(self.dir, MANIFEST)) as f:
            m = json.load(f)
        for name, want in (m.get("crc32") or {}).items():
            path = os.path.join(self.dir, f"{name}.bin")
            crc = 0
            with open(path, "rb") as fh:
                while True:
                    chunk = fh.read(1 << 22)
                    if not chunk:
                        break
                    crc = zlib.crc32(chunk, crc)
            got = f"{crc & 0xFFFFFFFF:08x}"
            if got != want:
                raise BlobCorruption(
                    path,
                    f"edge channel file {name}.bin: manifest crc32 {want} "
                    f"!= read crc32 {got}",
                    directory=self.dir,
                )

    # -- identity / accounting -----------------------------------------------
    def signature(self) -> dict:
        """Stable identity of the edge streams, recorded in checkpoint
        manifests so recovery can detect a store/state mismatch."""
        return dict(store="edge-stream", signature=self._signature,
                    n_shards=self.geom.n_shards, n_edges=self.geom.n_edges)

    def disk_bytes(self) -> int:
        return sum(
            os.path.getsize(os.path.join(self.dir, f"{name}.bin"))
            for name in _FILES
        )

    def block_bytes(self) -> int:
        """DECODED bytes of one staged edge block across the three channels
        — the admission/accounting unit of the hot-block residency cache
        (streams/residency.py), independent of on-disk compression."""
        return self.geom.edge_block * EDGE_SLOT_BYTES

    # -- skip() (§3.2) -------------------------------------------------------
    def active_blocks(self, i: int, k: int, prefix: np.ndarray) -> np.ndarray:
        """Block ids of group (i, k) whose source range [lo, hi] contains an
        active vertex; ``prefix`` is the inclusive prefix sum (P+1,) of shard
        i's active bitmap. Returned ascending => the read is sequential."""
        lo = self.blk_lo[i, k]
        hi = self.blk_hi[i, k]
        nonempty = hi >= 0
        cnt = prefix[np.clip(hi + 1, 0, self.geom.P)] - prefix[np.clip(lo, 0, self.geom.P)]
        return np.nonzero(nonempty & (cnt > 0))[0].astype(np.int64)

    def nonempty_blocks(self) -> int:
        return int((self.blk_hi >= 0).sum())

    # -- reads ---------------------------------------------------------------
    def read_blocks(self, i: int, k: int, ids: np.ndarray,
                    out_sp: np.ndarray, out_dp: np.ndarray,
                    out_w: np.ndarray) -> int:
        """Read blocks ``ids`` of group (i, k) into the staging buffers
        (shape (chunk_blocks, edge_block) each); unused tail rows are padded
        (sp = -1) so the staged chunk is compute-neutral. Returns the number
        of real blocks staged."""
        c = len(ids)
        out_sp[c:] = -1
        out_dp[c:] = 0
        out_w[c:] = 0.0
        if not c:
            return 0
        B = self.geom.edge_block
        if self.compress:
            for j, b in enumerate(ids):
                out_sp[j] = decode_varint_delta(self._blob("sp", i, k, int(b)))
                out_dp[j] = decode_varint_delta(self._blob("dp", i, k, int(b)))
        else:
            self._row("sp", i)[k].take(ids, axis=0, out=out_sp[:c])
            self._row("dp", i)[k].take(ids, axis=0, out=out_dp[:c])
        if self.compress_payload:
            for j, b in enumerate(ids):
                out_w[j] = decode_payload(
                    self._blob("w", i, k, int(b)), np.float32, B)
        else:
            self._row("w", i)[k].take(ids, axis=0, out=out_w[:c])
        return c

    def group_edges(self, i: int, k: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Whole-group read (tests / tooling — not the streaming hot path)."""
        nb, B = self.geom.n_blocks, self.geom.edge_block
        if self.compress:
            sp = np.empty((nb, B), np.int32)
            dp = np.empty((nb, B), np.int32)
            for b in range(nb):
                sp[b] = decode_varint_delta(self._blob("sp", i, k, b))
                dp[b] = decode_varint_delta(self._blob("dp", i, k, b))
        else:
            sp = np.array(self._row("sp", i)[k])
            dp = np.array(self._row("dp", i)[k])
        if self.compress_payload:
            w = np.empty((nb, B), np.float32)
            for b in range(nb):
                w[b] = decode_payload(self._blob("w", i, k, b), np.float32, B)
        else:
            w = np.array(self._row("w", i)[k])
        return sp, dp, w
