"""Tiered block residency — the adaptive semi-external tier.

GraphD's streamed mode proves the O(|V|/n) bound by reading *every* active
edge block from disk every superstep. GraphMP/GraphH (PAPERS.md) show that a
machine with spare RAM above that floor can do 2-5x better by pinning the
hot part of the edge stream in memory and streaming only the cold tail.
This module is that tier: a :class:`BlockResidency` sits between the
prefetching reader and the ``EdgeStreamStore`` and decides, per edge block,
*where* the bytes come from — the bounded in-RAM hot cache or the memmap.

Three invariants make the cache invisible to the computation:

* **bit-identity** — a cached block is a byte-exact copy of what
  ``read_blocks`` produced for it, taken the moment it was read; serving it
  later fills the same staging rows with the same values, so every result
  (including reassociation-sensitive float sums) is bit-identical to pure
  streaming at ANY budget, 0 included (``tests/test_equivalence.py`` pins
  this for all 8 algorithms);
* **bounded RAM** — admission is refused beyond ``capacity_bytes``; the
  planner sizes that budget as the ``hot_cache`` tier of
  ``estimate_memory()``, so the resident footprint stays within the
  ``MemoryBudget`` like every other tier;
* **stable copies** — the reader's staging buffers are recycled (the
  consumer may alias them); cached rows are copied out before the buffer is
  returned to the pool, never referenced.

Ranking: per-block *activity metadata* (access count across supersteps,
real-edge count as the density tiebreak) persists for the engine's lifetime
— blocks touched every superstep outrank one-off reads, and among equally
hot blocks the denser one yields more served edges per cached byte. The
same metadata feeds the selective-scheduling counters: blocks the §3.2
skip() test never scheduled are tallied as ``skipped`` (late SSSP/HashMin
rounds skip nearly everything), so residency behavior is observable from
``SuperstepRecord`` / ``JobResult.summary()`` without a profiler.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.streams.store import EdgeStreamStore


@dataclass
class ResidencyStats:
    """Cumulative residency accounting (per-superstep deltas are taken by
    the engine via :meth:`BlockResidency.counters`)."""

    hits: int = 0  # blocks served from the hot cache (no disk I/O)
    misses: int = 0  # blocks that fell through to the memmap store
    admissions: int = 0  # blocks copied into the cache
    evictions: int = 0  # cached blocks dropped for hotter ones
    skipped: int = 0  # blocks never scheduled at all (skip() selective I/O)

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class BlockResidency:
    """Bounded hot-block cache over one :class:`EdgeStreamStore` geometry.

    ``capacity_bytes`` bounds the decoded bytes pinned (each block costs
    ``edge_block * EDGE_SLOT_BYTES``); 0 degenerates to a pass-through that
    only counts misses — pure streaming with observability.
    """

    def __init__(self, store: EdgeStreamStore, capacity_bytes: int):
        self.capacity_bytes = max(int(capacity_bytes), 0)
        self.block_bytes = store.block_bytes()
        self.stats = ResidencyStats()
        # (src_shard, dst_shard, block_id) -> (sp, dp, w) stable row copies
        self._cache: dict[tuple[int, int, int], tuple] = {}
        # persisted activity metadata: key -> [access count, real edges]
        self._heat: dict[tuple[int, int, int], list] = {}
        self._bytes = 0

    # -- observability -------------------------------------------------------
    @property
    def cached_bytes(self) -> int:
        return self._bytes

    @property
    def cached_blocks(self) -> int:
        return len(self._cache)

    def counters(self) -> tuple[int, int, int, int]:
        """(hits, misses, evictions, skipped) — snapshot for delta-taking."""
        s = self.stats
        return (s.hits, s.misses, s.evictions, s.skipped)

    def note_skipped(self, n_blocks: int) -> None:
        """Record blocks the skip() test kept off the schedule entirely —
        the selective-scheduling win the cache rides on top of."""
        self.stats.skipped += int(n_blocks)

    # -- the read path -------------------------------------------------------
    def read_blocks(self, store: EdgeStreamStore, i: int, k: int, ids,
                    out_sp: np.ndarray, out_dp: np.ndarray,
                    out_w: np.ndarray) -> tuple[int, int]:
        """Fill the staging rows for ``ids`` of group (i, k) — cached blocks
        from RAM, the rest via ``store.read_blocks`` — and pad the tail
        exactly like the store does. Returns ``(n_blocks, n_disk_blocks)``
        so the reader's byte accounting counts only real I/O."""
        c = len(ids)
        cache = self._cache
        heat = self._heat
        keys = [(i, k, int(b)) for b in ids]
        miss = []
        for j, key in enumerate(keys):
            h = heat.get(key)
            if h is None:
                heat[key] = h = [0, -1]
            h[0] += 1
            if key in cache:
                sp, dp, w = cache[key]
                out_sp[j] = sp
                out_dp[j] = dp
                out_w[j] = w
            else:
                miss.append(j)
        # read contiguous runs of misses straight into their staging rows
        # (a view of exactly the run's rows: the store pads only past its
        # own c, which is empty for an exact-length view)
        r = 0
        while r < len(miss):
            j0 = miss[r]
            r1 = r + 1
            while r1 < len(miss) and miss[r1] == miss[r1 - 1] + 1:
                r1 += 1
            j1 = miss[r1 - 1] + 1
            store.read_blocks(i, k, ids[j0:j1], out_sp[j0:j1],
                              out_dp[j0:j1], out_w[j0:j1])
            r = r1
        for j in miss:
            key = keys[j]
            h = heat[key]
            if h[1] < 0:  # first sight: record block density for ranking
                h[1] = int((out_sp[j] >= 0).sum())
            self._admit(key, out_sp[j], out_dp[j], out_w[j])
        out_sp[c:] = -1
        out_dp[c:] = 0
        out_w[c:] = 0.0
        self.stats.hits += c - len(miss)
        self.stats.misses += len(miss)
        return c, len(miss)

    # -- admission / eviction ------------------------------------------------
    def _rank(self, key) -> tuple[int, int]:
        h = self._heat.get(key)
        return (h[0], h[1]) if h is not None else (0, 0)

    def _admit(self, key, sp, dp, w) -> None:
        if self.block_bytes > self.capacity_bytes:
            return  # budget 0 (or sub-block): pure pass-through
        if key in self._cache:
            return
        while self._bytes + self.block_bytes > self.capacity_bytes:
            # evict the coldest resident block — but only for a strictly
            # hotter newcomer, so equal-heat blocks never thrash
            cold = min(self._cache, key=self._rank)
            if self._rank(cold) >= self._rank(key):
                return
            del self._cache[cold]
            self._bytes -= self.block_bytes
            self.stats.evictions += 1
        self._cache[key] = (sp.copy(), dp.copy(), w.copy())
        self._bytes += self.block_bytes
        self.stats.admissions += 1
