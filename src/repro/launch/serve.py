"""Serving launcher: batched prefill + greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-4b \
        --reduced --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.data.tokens import synthetic_batch
from repro.models.transformer import init_params
from repro.serving.cache import cache_bytes, make_caches
from repro.serving.engine import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.key(0))
    max_len = args.prompt_len + args.gen
    caches = make_caches(cfg, args.batch, max_len=max_len)
    print(f"[serve] {cfg.name}: cache {cache_bytes(caches)/2**20:.1f} MiB "
          f"for B={args.batch} L={max_len}")
    batch = synthetic_batch(cfg, 0, args.prompt_len, args.batch)
    t0 = time.perf_counter()
    out = greedy_generate(cfg, params, batch["tokens"], caches, args.gen,
                          media=batch.get("media"))
    dt = time.perf_counter() - t0
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s incl. compile)")
    print("[serve] sample tokens:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
