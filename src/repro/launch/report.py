"""Render dryrun_results.json into the EXPERIMENTS.md §Dry-run / §Roofline
tables.

    PYTHONPATH=src python -m repro.launch.report dryrun_results.json
"""

from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b >= 2**30:
        return f"{b/2**30:.2f} GiB"
    if b >= 2**20:
        return f"{b/2**20:.1f} MiB"
    return f"{b/2**10:.0f} KiB"


def fmt_t(s):
    if s == 0:
        return "0"
    if s < 1e-3:
        return f"{s*1e6:.0f} µs"
    if s < 1:
        return f"{s*1e3:.1f} ms"
    return f"{s:.2f} s"


def dryrun_table(results):
    lines = [
        "| arch | shape | mesh | compile | per-chip args | HLO FLOPs/chip | "
        "HLO bytes/chip | collective B/chip |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"SKIP ({r['reason'].split(' — ')[0]}) | – | – | – | – |"
            )
            continue
        if not r.get("ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"**FAIL** | – | – | – | – |"
            )
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compile_s']}s | {fmt_bytes(r['argument_bytes'])} | "
            f"{r['flops_per_chip']:.3g} | {r['bytes_per_chip']:.3g} | "
            f"{r['collective_bytes_per_chip']:.3g} |"
        )
    return "\n".join(lines)


def roofline_table(results):
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | dominant | "
        "useful/HLO FLOPs | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if not r.get("ok"):
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_t(r['t_compute_s'])} | "
            f"{fmt_t(r['t_memory_s'])} | {fmt_t(r['t_collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.4f} |"
        )
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    results.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    single = [r for r in results if r["mesh"] == "singlepod"]
    multi = [r for r in results if r["mesh"] == "multipod"]
    print("### Dry-run (single pod, 16x16)\n")
    print(dryrun_table(single))
    if multi:
        print("\n### Dry-run (multi-pod, 2x16x16)\n")
        print(dryrun_table(multi))
    print("\n### Roofline (single pod)\n")
    print(roofline_table(single))


if __name__ == "__main__":
    main()
