"""Insert generated dry-run/roofline tables into EXPERIMENTS.md markers.

    PYTHONPATH=src python -m repro.launch.finalize_report
"""

import io
import json
import sys
from contextlib import redirect_stdout

from repro.launch.report import dryrun_table, roofline_table


def main():
    with open("dryrun_results.json") as f:
        results = json.load(f)
    try:
        with open("graphd_dryrun.json") as f:
            gd = json.load(f)
    except FileNotFoundError:
        gd = []
    results.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    single = [r for r in results if r["mesh"] == "singlepod"]
    multi = [r for r in results if r["mesh"] == "multipod"]

    dr = (
        "### Dry-run (single pod, 16×16 = 256 chips)\n\n"
        + dryrun_table(single)
        + "\n\n### Dry-run (multi-pod, 2×16×16 = 512 chips)\n\n"
        + dryrun_table(multi)
        + "\n\n### Dry-run — GraphD (the paper's system, flat machine ring)\n\n"
        + dryrun_table(gd)
        + "\n\nAll compiles succeeded (`ok`) or are declared skips "
        "(long_500k × pure-full-attention, per the assignment). Peak "
        "per-chip resident bytes = argument bytes (exact, sharded "
        "params+optimizer+caches) — see `peak_bytes_model` in the JSON for "
        "the modeled activation add-on; every cell fits 16 GB/chip HBM.\n"
    )
    rf = (
        "### Roofline (single pod; per-chip per-step seconds)\n\n"
        + roofline_table(single)
        + "\n\n### Roofline (multi-pod)\n\n"
        + roofline_table(multi)
        + "\n\nGraphD cell: see §Perf cell C for the analytic derivation "
        "(the ring loop's HLO costs are counted once per round by XLA).\n"
    )

    with open("EXPERIMENTS.md") as f:
        txt = f.read()
    txt = txt.replace("<!-- DRYRUN_TABLES -->", dr)
    txt = txt.replace("<!-- ROOFLINE_TABLES -->", rf)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(txt)
    print("EXPERIMENTS.md updated:",
          len(single), "single-pod +", len(multi), "multi-pod cells +",
          len(gd), "graphd")


if __name__ == "__main__":
    main()
