"""True multi-process deployment: one worker process per shard.

``run_processes(job)`` turns a planned streamed :class:`~repro.core.job
.GraphDJob` into n real OS processes. Each worker opens ONLY its owner view
of the edge store (``EdgeStreamStore.open(dir, owner=w)`` maps just shard
w's byte extent), holds only its own vertex rows, and talks to its peers
exclusively through the shared filesystem:

* **outbox** — per (step, source) :class:`MessageRunStore` in the exact
  inbox-run-file wire format of ``streams.channel`` (combined groups are
  ``append_combined`` sparse runs, combiner-less spills are per-chunk
  ``append_raw`` runs), published by an atomically-renamed announce marker;
* **inbox** — each worker copies the runs addressed to it, ascending source
  (= the threaded sender's transmit order), into a local store and digests
  them through the real :class:`~repro.streams.channel.ChannelReceiver`
  with the SAME jitted :class:`~repro.core.engine.StreamKernels` the
  threaded engine runs — so a 3-process run is bit-identical to the
  single-process full-duplex streamed run;
* **coordinator** — the job process drives ``core.coordinator
  .FileCoordinator`` barriers: per-superstep arrive/commit records,
  shard-ascending aggregator + halt-vote reduction, and heartbeat liveness.
  A worker that dies mid-superstep (kill -9 included) stops beating; the
  coordinator respawns just that shard with ``--recover-to``, which replays
  forward from the latest checkpoint over the worker's own message log
  (paper §3.4 / [19] single-shard fast recovery) and rejoins the barrier.

``launch_opts={"transport": "sockets"}`` swaps the shared-filesystem
exchange for the real TCP transport (``repro.launch.net``): runs stream
over persistent per-peer connections while the fold is still producing
(§4's transmit ∥ compute), receivers feed them straight into the same
ChannelReceiver digest path, and the coordinator protocol rides one
multiplexed connection per worker (event-driven commits, pushed aborts,
in-band heartbeats). Each sender keeps the step's runs in a LOCAL per-step
outbox store — the replay log the reconnect-with-resume handshake serves —
so crash recovery keeps the same bit-identical story with no shared
filesystem on the message hot path. The run results are bit-identical
between both transports: every run round-trips the same MessageRunStore
transforms and arrives in the same source-ascending digest order.

Under the socket transport the coordinator itself is a separate OS
process (``python -m repro.launch.procs coord <spec_dir>``): it hosts the
CoordServer plus the superstep commit loop, write-ahead-logs every commit
under ``procs_dir/coord-wal/`` and publishes its listening address to
``procs_dir/coord-addr.json``. The launcher is a thin supervisor — it
respawns a crashed coordinator (bounded by ``coord_restart_limit``) and
respawns failed workers with ``--recover-to`` taken from the WAL. Workers
reconnect to a respawned coordinator through the address file, so a
``kill -9`` of the coordinator mid-barrier loses nothing: the successor
restores the WAL, workers replay their stranded arrivals, and the run's
results stay bit-identical.

Worker processes are started as ``python -m repro.launch.procs worker
<spec_dir> <shard>``. This module keeps its import-time dependencies to the
standard library + the coordinator + the (stdlib-only) chaos layer so a
worker can start its heartbeat BEFORE paying the jax import.
"""

from __future__ import annotations

import argparse
import errno
import json
import os
import pickle
import re
import shutil
import signal
import subprocess
import sys
import threading
import time

import numpy as np

import repro.fault as _fault
from repro.core.coordinator import (
    FileCoordinator, RunAborted, WorkerFailed, atomic_write_json,
)
from repro.fault import (
    BlobCorruption,
    FaultEvent,
    FaultInjector,
    FaultSchedule,
    RetryExhausted,
    RetryPolicy,
    TierFault,
    failure_record,
    find_in_chain,
    write_record,
)

SPEC = "spec.json"
PROGRAM = "program.pkl"
_STEP_DIR = re.compile(r"^step-(\d+)$")
_WAL_COMMIT = re.compile(r"^commit-(\d+)\.json$")

# respawn budget per run: recovery is for crashes, not crash loops
MAX_RECOVERIES = 3
# extra seconds a freshly spawned worker gets before heartbeat staleness
# counts against it (interpreter start + first beat)
SPAWN_GRACE = 5.0
# errnos that mean "a storage tier failed", not "a bug": classified as
# TierFault so the failure record names the tier (spill vs checkpoint)
_DISK_ERRNOS = frozenset({errno.ENOSPC, errno.EIO, errno.EDQUOT})


# --------------------------------------------------------------------------
# shared-filesystem layout (one helper per path, used by both sides)
# --------------------------------------------------------------------------

def _shard_dir(procs_dir: str, w: int) -> str:
    return os.path.join(procs_dir, f"shard-{w}")


def _outbox_dir(procs_dir: str, step: int, src: int) -> str:
    return os.path.join(procs_dir, "outbox", f"step-{step:06d}",
                        f"src-{src}")


def _announce_path(procs_dir: str, step: int, src: int) -> str:
    return os.path.join(procs_dir, "announce", f"step-{step:06d}",
                        f"src-{src}.json")


def _result_path(procs_dir: str, w: int) -> str:
    return os.path.join(procs_dir, "result", f"shard-{w}.npz")


def _wal_dir(procs_dir: str) -> str:
    return os.path.join(procs_dir, "coord-wal")


def _coord_addr_path(procs_dir: str) -> str:
    return os.path.join(procs_dir, "coord-addr.json")


def _failure_path(procs_dir: str, w: int) -> str:
    return os.path.join(procs_dir, "failures", f"shard-{w}.json")


def _recover_request_path(procs_dir: str, w: int) -> str:
    return os.path.join(procs_dir, f"recover-{w}.json")


def _abort_request_path(procs_dir: str) -> str:
    return os.path.join(procs_dir, "abort-request.json")


def _save_npz_atomic(path: str, **arrays) -> None:
    tmp = path + ".tmp.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
        f.flush()
        os.fsync(f.fileno())  # arrays durable before the name appears
    os.replace(tmp, path)


# --------------------------------------------------------------------------
# launcher (runs in the job process)
# --------------------------------------------------------------------------

def _src_root() -> str:
    """The import root to hand worker processes (the directory holding the
    ``repro`` package)."""
    import repro.core as core

    return os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(core.__file__))
    ))


def _write_spec(job, procs_dir: str, coord_dir: str, *, start_step: int,
                target: int, bootstrap: str, ckpt_step: int | None,
                heartbeat_interval: float, heartbeat_timeout: float,
                transport: str = "files", coord_addr=None,
                kill_net=None, **extra) -> None:
    pg, cfg = job.pg, job.plan.config
    rec = cfg.recovery
    spec = dict(
        n_shards=int(pg.n_shards),
        P=int(pg.P),
        n_vertices=int(pg.n_vertices),
        value_dtype=str(np.dtype(job.program.value_dtype)),
        msg_dtype=str(np.dtype(job.program.msg_dtype)),
        store_dir=job.store.dir,
        logs_dir=(job.message_log.dir if rec.log_messages else None),
        ckpt_dir=(job.checkpointer.dir if job.checkpointer else None),
        ckpt_keep=(job.checkpointer.keep if job.checkpointer else 0),
        store_signature=job.store.signature(),
        procs_dir=procs_dir,
        coord_dir=coord_dir,
        config=cfg.to_json(),
        checkpoint_every=int(rec.checkpoint_every),
        log_messages=bool(rec.log_messages),
        start_step=int(start_step),
        target=int(target),
        num_supersteps=job.program.num_supersteps,
        bootstrap=bootstrap,
        ckpt_step=ckpt_step,
        heartbeat_interval=heartbeat_interval,
        heartbeat_timeout=heartbeat_timeout,
        transport=transport,
        coord_addr=coord_addr,
        kill_net=kill_net,
        **extra,
    )
    atomic_write_json(os.path.join(procs_dir, SPEC), spec)
    with open(os.path.join(procs_dir, PROGRAM), "wb") as f:
        pickle.dump(job.program, f)
    # per-shard partition rows: a worker maps O(P) state, never the stacks
    for w in range(pg.n_shards):
        d = _shard_dir(procs_dir, w)
        os.makedirs(d, exist_ok=True)
        _save_npz_atomic(
            os.path.join(d, "rows.npz"),
            degree=np.asarray(pg.degree[w]),
            vmask=np.asarray(pg.vmask[w]),
            old_ids=np.asarray(pg.old_ids[w]),
            gids=np.asarray(pg.gids[w]),
        )


def _finalize_checkpoint_dir(ckpt_dir: str, step: int, n_shards: int, P: int,
                             dtype: str, meta, keep: int = 2) -> None:
    """Coordinator half of the distributed checkpoint: every worker has
    already dumped its ``shard-w.npz`` into the ``.tmp`` dir; write the
    manifest (the Checkpointer wire format, so ``restore``/``restore_shard``
    read it unchanged) and publish with the atomic rename.

    Idempotent: a restarted coordinator replays its WAL and may finalize a
    step that the previous incarnation already published — if the final dir
    exists and the tmp dir is gone, the work is done and we return."""
    tmp = os.path.join(ckpt_dir, f".tmp-step-{step:06d}")
    final = os.path.join(ckpt_dir, f"step-{step:06d}")
    if os.path.isdir(final) and not os.path.isdir(tmp):
        return
    for w in range(n_shards):
        if not os.path.exists(os.path.join(tmp, f"shard-{w}.npz")):
            raise RuntimeError(
                f"checkpoint step {step}: worker {w} voted ckpt but its "
                "shard file is missing"
            )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(dict(step=step, n_shards=n_shards, P=P, dtype=dtype,
                       meta=meta), f)
        f.flush()
        os.fsync(f.fileno())  # recovery trusts any published step dir; the
        # manifest must be durable before the rename makes it visible
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # keep-newest gc, mirroring Checkpointer._gc
    steps = sorted(
        int(name[len("step-"):]) for name in os.listdir(ckpt_dir)
        if name.startswith("step-") and name[len("step-"):].isdigit()
    )
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step-{s:06d}"),
                      ignore_errors=True)


def run_processes(job, max_supersteps: int = 10_000, *,
                  verbose: bool = False, on_step=None):
    """Run ``job`` with one worker process per shard; returns
    ``((values, active), history)`` exactly like ``GraphDEngine.run``.
    ``on_step`` is called as ``on_step(record, None)`` — the coordinator
    never holds the distributed state, only the barrier records."""
    from repro.core.engine import SuperstepRecord

    from repro.core.config import ConfigError

    program, pg, store = job.program, job.pg, job.store
    cfg = job.plan.config
    if cfg.channel.payload_scheme == "auto":
        # defensive: GraphDJob downgrades auto -> lossless for processes
        # launches; reaching here means a caller bypassed the job facade.
        # The auto-pick's first-superstep sample is engine-local state; n
        # worker processes would each decide independently and diverge.
        raise ConfigError(
            "channel.compress_payload='auto' conflicts with "
            "launch='processes': the auto-pick is a single-process engine "
            "feature and n workers need one fixed wire format — pass "
            "'lossless' (or False) explicitly"
        )
    n = pg.n_shards
    from repro.core.config import validate_launch_opts

    opts = validate_launch_opts(dict(job.launch_opts or {}))
    transport = opts.get("transport", "files")
    heartbeat_interval = float(opts.get("heartbeat_interval", 0.25))
    heartbeat_timeout = float(opts.get("heartbeat_timeout", 10.0))
    # crash drill (tests / CI): {"shard": w, "step": s} SIGKILLs worker w
    # mid-superstep s — after it announced its outbox, before it arrives
    kill_spec = opts.get("kill")
    # deprecated alias for a faults= net.send torn_kill event; worker_main
    # translates it into the schedule so one injector drives both
    kill_net = opts.get("kill_net")
    can_recover = (job.checkpointer is not None
                   and cfg.recovery.log_messages)

    procs_dir = job._dir("procs", job._tag)
    coord_dir = os.path.join(procs_dir, "coord")
    # a fresh launch owns the transport namespace: stale barrier records,
    # WAL commits, failure records or half-written outboxes from a previous
    # (crashed) launch would open this run's barriers early or trip the
    # supervisor into phantom recoveries
    for sub in ("coord", "outbox", "announce", "result", "coord-wal",
                "failures"):
        shutil.rmtree(os.path.join(procs_dir, sub), ignore_errors=True)
    if os.path.isdir(procs_dir):
        for name in os.listdir(procs_dir):
            if name.startswith("shard-"):  # socket senders' per-step
                # outbox + the local (log-less) inbox
                for sub in ("outbox", "inbox"):
                    shutil.rmtree(os.path.join(procs_dir, name, sub),
                                  ignore_errors=True)
            elif (name in ("coord-addr.json", "abort-request.json",
                           "failure-summary.json", "coord.log")
                  or name.startswith("recover-")):
                try:
                    os.unlink(os.path.join(procs_dir, name))
                except OSError:
                    pass
    os.makedirs(procs_dir, exist_ok=True)

    target = min(
        program.num_supersteps
        if program.num_supersteps is not None
        else max_supersteps,
        max_supersteps,
    )
    state = job._state
    start_step = job._next_step
    restored_from = None
    ckpt_step = None
    if state is not None:
        bootstrap = "state"
        vals = np.asarray(state[0])
        act = np.asarray(state[1])
        for w in range(n):
            d = _shard_dir(procs_dir, w)
            os.makedirs(d, exist_ok=True)
            _save_npz_atomic(os.path.join(d, "boot.npz"),
                             values=vals[w], active=act[w])
    elif job.checkpointer is not None and job.checkpointer.latest() is not None:
        ckpt_step = job.checkpointer.latest()
        d = os.path.join(job.checkpointer.dir, f"step-{ckpt_step:06d}")
        with open(os.path.join(d, "manifest.json")) as f:
            got = json.load(f).get("meta")
        expected = store.signature()
        if got is not None and got != expected:
            raise ValueError(
                f"checkpoint step-{ckpt_step:06d} was written against "
                f"different edge streams: manifest meta {got} != expected "
                f"{expected}"
            )
        bootstrap = "checkpoint"
        start_step = ckpt_step
        restored_from = ckpt_step
    else:
        bootstrap = "init"

    if start_step >= target:
        # nothing to run: resolve the state in-process, exactly like the
        # engine's empty loop would
        if state is None:
            if job.checkpointer is not None and ckpt_step is not None:
                v, a, _ = job.checkpointer.restore(
                    expected_meta=store.signature())
                state = (v, a)
            else:
                state = job.engine.init()
        return state, []

    # socket tunables + chaos schedule ride the spec into every process
    net = dict(
        handshake_timeout=float(opts.get("handshake_timeout", 5.0)),
        connect_timeout=float(opts.get("connect_timeout", 5.0)),
        send_timeout=float(opts.get("send_timeout", 60.0)),
        coord_connect_timeout=float(opts.get("coord_connect_timeout", 10.0)),
        retry=opts.get("retry"),
    )
    _write_spec(job, procs_dir, coord_dir, start_step=start_step,
                target=target, bootstrap=bootstrap, ckpt_step=ckpt_step,
                heartbeat_interval=heartbeat_interval,
                heartbeat_timeout=heartbeat_timeout,
                transport=transport, coord_addr=None,
                kill_net=kill_net, net=net, faults=opts.get("faults"),
                coord_kill=opts.get("coord_kill"),
                coord_addr_path=_coord_addr_path(procs_dir))
    if transport == "sockets":
        return _run_sockets(job, opts, n=n, procs_dir=procs_dir,
                            start_step=start_step, target=target,
                            restored_from=restored_from,
                            can_recover=can_recover, verbose=verbose,
                            on_step=on_step)
    coord = FileCoordinator(coord_dir, n,
                            heartbeat_interval=heartbeat_interval,
                            heartbeat_timeout=heartbeat_timeout)

    src_root = _src_root()
    procs: list[subprocess.Popen | None] = [None] * n
    grace = [0.0] * n
    recoveries = 0
    job._last_run_recoveries = 0  # audit: how many respawns this run took
    job._last_run_coord_restarts = 0  # files: the launcher IS the coord

    def _spawn(w: int, recover_to: int | None = None) -> None:
        d = _shard_dir(procs_dir, w)
        os.makedirs(d, exist_ok=True)
        cmd = [sys.executable, "-m", "repro.launch.procs", "worker",
               procs_dir, str(w)]
        if recover_to is not None:
            cmd += ["--recover-to", str(recover_to)]
        env = dict(os.environ)
        env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
        with open(os.path.join(d, "worker.log"), "ab") as logf:
            procs[w] = subprocess.Popen(cmd, stdout=logf,
                                        stderr=subprocess.STDOUT, env=env)
        # the parent's copy of the log fd is closed by the with-block; the
        # child holds its own.  Grace deadlines live on the monotonic
        # clock: an NTP step during spawn must not shrink (or stretch)
        # the window a worker gets to reach its first heartbeat.
        grace[w] = time.monotonic() + heartbeat_timeout + SPAWN_GRACE

    def _killall() -> None:
        for p in procs:
            if p is not None and p.poll() is None:
                p.kill()
        for p in procs:
            if p is not None:
                try:
                    p.wait(timeout=10)
                except subprocess.TimeoutExpired:
                    pass

    def _fail(w: int, reason: str, record: dict | None = None) -> None:
        # the structured failure summary is the chaos-soak artifact: name
        # the failing tier/site in JSON before the run goes down loudly
        write_record(os.path.join(procs_dir, "failure-summary.json"),
                     failure_record("launch-failed", shard=w, message=reason,
                                    record=record))
        coord.abort(reason)
        _killall()
        raise WorkerFailed(w, reason, record=record)

    def _recover(w: int, recover_to: int, why: str,
                 record: dict | None = None) -> None:
        nonlocal recoveries
        if not can_recover:
            _fail(w, f"worker {w} {why} and the job has no checkpoint + "
                     "message-log recovery wiring (checkpoint_every=)",
                  record=record)
        if recoveries >= MAX_RECOVERIES:
            _fail(w, f"worker {w} {why} after {recoveries} recoveries — "
                     "crash loop, giving up", record=record)
        recoveries += 1
        job._last_run_recoveries = recoveries
        p = procs[w]
        if p is not None and p.poll() is None:
            p.kill()
            p.wait()
        if verbose:
            print(f"  [procs] worker {w} {why}; respawning with "
                  f"--recover-to {recover_to}")
        _spawn(w, recover_to=recover_to)

    def _liveness(step_or_none):
        """One poll tick: a worker that exited, or whose heartbeat went
        stale past its grace window, is recovered (or the run aborts)."""
        def check(got):
            now = time.monotonic()  # same clock as the grace deadlines
            for w in range(n):
                if w in got:
                    continue
                p = procs[w]
                exited = p is not None and p.poll() is not None
                silent = now > grace[w] and coord.stale(w)
                if exited:
                    rec = _read_failure(procs_dir, w)
                    _recover(w, step_or_none,
                             _describe_exit(rec, p.returncode, step_or_none),
                             record=rec)
                elif silent:
                    _recover(w, step_or_none,
                             "went heartbeat-silent "
                             f"(> {heartbeat_timeout:.1f}s) "
                             f"mid-superstep {step_or_none}")
        return check

    history: list[SuperstepRecord] = []
    every = job.checkpointer.every if job.checkpointer is not None else 0
    # socket-transport channel accounting across the run (zero for files);
    # surfaced as job._last_run_net for benchmarks and audits
    net_totals = dict(net_send_s=0.0, net_stall_s=0.0, net_recv_s=0.0,
                      net_recv_stall_s=0.0, net_wire_bytes=0.0,
                      net_frames=0.0)
    job._last_run_net = dict(net_totals)
    ok = False
    try:
        for w in range(n):
            _spawn(w)
        nonempty = max(store.nonempty_blocks(), 1)
        for s in range(start_step, target):
            t0 = time.perf_counter()
            if kill_spec is not None and int(kill_spec["step"]) == s:
                kw = int(kill_spec["shard"])
                kill_spec = None
                # kill -9 mid-superstep: the victim has published its
                # outbox (so peers are not re-sent to) but has not applied
                # or arrived — the recovery path must replay this step
                coord.wait_file(_announce_path(procs_dir, s, kw), kw)
                p = procs[kw]
                if p is not None and p.poll() is None:
                    p.kill()
            arrivals = coord.wait_arrivals(s, on_wait=_liveness(s))
            totals = coord.reduce_arrivals(arrivals)
            for key in net_totals:
                net_totals[key] += float(totals.get(key, 0.0))
            ckpt_landed = False
            if every and (s + 1) % every == 0:
                _finalize_checkpoint_dir(
                    job.checkpointer.dir, s + 1, n, pg.P,
                    str(np.dtype(program.value_dtype)),
                    store.signature(), keep=job.checkpointer.keep,
                )
                ckpt_landed = True
            halt = (
                (program.num_supersteps is None and totals["n_active"] == 0)
                or s + 1 >= target
            )
            coord.publish_commit(s, totals, halt=halt,
                                 ckpt_landed=ckpt_landed)
            dt = time.perf_counter() - t0
            rec = SuperstepRecord(
                step=s, n_active=totals["n_active"],
                n_msgs=totals["n_msgs"], agg=totals["agg"],
                density=totals["active_blocks"] / nonempty,
                mode="streamed", seconds=dt,
                restored_from=restored_from if s == start_step else None,
                blocks_read=totals.get("blocks_read", 0),
                cache_hits=totals.get("cache_hits", 0),
                cache_evictions=totals.get("cache_evictions", 0),
                blocks_skipped=totals.get("blocks_skipped", 0),
            )
            history.append(rec)
            if verbose:
                print(
                    f"  superstep {s:4d}: active={rec.n_active:>9d} "
                    f"msgs={rec.n_msgs:>10d} agg={rec.agg:.6g} "
                    f"density={rec.density:.4f} "
                    f"[streamed procs x{n}] {dt*1e3:.1f} ms"
                )
            if on_step is not None:
                on_step(rec, None)
            if halt:
                break
        last_step = history[-1].step if history else start_step - 1
        # results: every worker publishes its final rows and exits 0; a
        # worker that dies between its last commit and the result write is
        # recovered like any other (replays to last_step + 1, sees the halt
        # commit, writes the result)
        deadline_check = _liveness(last_step + 1)
        poll = FileCoordinator.POLL  # result wait backs off like barriers
        while True:
            missing = [w for w in range(n)
                       if not os.path.exists(_result_path(procs_dir, w))]
            if not missing:
                break
            deadline_check(set(range(n)) - set(missing))
            time.sleep(poll)
            poll = min(poll * FileCoordinator.POLL_GROWTH,
                       FileCoordinator.POLL_MAX)
        vals, acts = [], []
        for w in range(n):
            z = np.load(_result_path(procs_dir, w))
            vals.append(z["values"])
            acts.append(z["active"])
        for p in procs:
            if p is not None:
                try:
                    p.wait(timeout=30)
                except subprocess.TimeoutExpired:
                    p.kill()
        ok = True
    finally:
        if not ok:
            if coord.aborted() is None:
                coord.abort("launcher failed")
            _killall()
        job._last_run_net = net_totals
    import jax.numpy as jnp

    return (jnp.asarray(np.stack(vals)), jnp.asarray(np.stack(acts))), history


# --------------------------------------------------------------------------
# failure records (written by dying workers, folded in by the supervisor)
# --------------------------------------------------------------------------

def _read_failure(procs_dir: str, w: int) -> dict | None:
    """Consume worker ``w``'s classified failure record, if it published
    one before exiting (records land atomically BEFORE the exit code, so
    an observed exit implies a readable record or none at all)."""
    path = _failure_path(procs_dir, w)
    try:
        with open(path) as f:
            rec = json.load(f)
    except (OSError, ValueError):
        return None
    try:
        os.unlink(path)
    except OSError:
        pass
    return rec


def _describe_exit(rec: dict | None, returncode, step) -> str:
    """One human line for a worker exit, naming the failing tier/site when
    the worker classified itself before dying."""
    at = f" mid-superstep {step}" if step is not None else ""
    if rec is None:
        return f"exited with code {returncode}{at}"
    kind = rec.get("kind")
    msg = rec.get("message", "")
    if kind == "disk-fault":
        return (f"hit a disk fault in the {rec.get('tier', '?')} tier{at}: "
                f"{msg}")
    if kind == "corruption":
        return f"found a corrupt blob{at} (quarantined for replay): {msg}"
    if kind == "retry-exhausted":
        return f"exhausted its retry budget{at}: {msg}"
    return f"exited with code {returncode}{at}: {msg or kind}"


def _classify_failure(exc: BaseException, shard: int) -> dict | None:
    """Turn a worker's terminal exception into a structured failure record,
    or None when it is an unclassified bug (exit 1, stack trace only)."""
    t = find_in_chain(exc, TierFault)
    if t is not None:
        s = t.summary()
        return failure_record(s.pop("kind"), shard=shard, step=s.pop("step"),
                              message=str(t), **s)
    b = find_in_chain(exc, BlobCorruption)
    if b is not None:
        s = b.summary()
        return failure_record(s.pop("kind"), shard=shard, message=str(b), **s)
    r = find_in_chain(exc, RetryExhausted)
    if r is not None:
        s = r.summary()
        return failure_record(s.pop("kind"), shard=shard, message=str(r), **s)
    # a disk errno that escaped tier wrapping (e.g. raised on the socket
    # sender's transmit thread and re-surfaced as its RuntimeError) is
    # still a spill-tier fault, not a bug
    o = find_in_chain(exc, OSError)
    if o is not None and getattr(o, "errno", None) in _DISK_ERRNOS:
        t = TierFault("spill", cause=o)
        s = t.summary()
        s.pop("step")
        return failure_record(s.pop("kind"), shard=shard, message=str(t), **s)
    return None


def _quarantine(corrupt: BlobCorruption) -> None:
    """Move the corrupt blob's directory aside so bad bytes are never
    consumed twice. The quarantined step is by construction uncommitted —
    a torn run cannot have passed its barrier — so the respawned worker
    re-receives those messages fresh (senders' outbox logs / announce
    markers still serve them)."""
    d = corrupt.directory
    if not d or not os.path.isdir(d):
        return
    try:
        # not a publish: an EVICTION from the lineage. If a crash undoes
        # the un-fsynced rename, the dir reappears under its old name and
        # the CRC check re-detects it on the next read — no reader can
        # ever trust the bytes either way.
        os.rename(d, d + ".quarantine")  # analysis: allow[atomic-publish] eviction, not publication; re-detected if undone
    except OSError:
        shutil.rmtree(d, ignore_errors=True)


def _sweep_partial(spec: dict, shard: int) -> None:
    """Drop this worker's torn write products before exiting on a disk
    fault, so neither the respawn nor the post-mortem ever reads a blob
    with no index: an un-announced files-transport outbox never published
    its index (markers land only after ``save_index``), and a checkpoint
    tmp shard file without its manifest is re-dumped by the respawn."""
    procs_dir = spec["procs_dir"]
    ob_root = os.path.join(procs_dir, "outbox")
    if os.path.isdir(ob_root):
        for name in os.listdir(ob_root):
            m = _STEP_DIR.match(name)
            if not m:
                continue
            s = int(m.group(1))
            d = os.path.join(ob_root, name, f"src-{shard}")
            if (os.path.isdir(d) and not
                    os.path.exists(_announce_path(procs_dir, s, shard))):
                shutil.rmtree(d, ignore_errors=True)
    ckpt_dir = spec.get("ckpt_dir")
    if ckpt_dir and os.path.isdir(ckpt_dir):
        for name in os.listdir(ckpt_dir):
            if name.startswith(".tmp-step-"):
                try:
                    os.unlink(os.path.join(ckpt_dir, name,
                                           f"shard-{shard}.npz"))
                except OSError:
                    pass


# --------------------------------------------------------------------------
# socket-transport supervision (the coordinator is its own child process)
# --------------------------------------------------------------------------

def _read_wal_commit(wal: str, step: int) -> dict | None:
    try:
        with open(os.path.join(wal, f"commit-{step:06d}.json")) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def _wal_last_commit(wal: str) -> int:
    last = -1
    try:
        names = os.listdir(wal)
    except OSError:
        return last
    for name in names:
        m = _WAL_COMMIT.match(name)
        if m:
            last = max(last, int(m.group(1)))
    return last


def _run_sockets(job, opts, *, n, procs_dir, start_step, target,
                 restored_from, can_recover, verbose, on_step):
    """Socket-transport launch: spawn the coordinator as its own process
    (:func:`coord_main`) plus one worker per shard, then supervise. The
    launcher holds NO barrier state — it tails the coordinator's WAL into
    the run history — so ``kill -9`` on the coordinator costs exactly one
    respawn (bounded by ``coord_restart_limit``) and zero committed
    supersteps."""
    from repro.core.engine import SuperstepRecord

    store = job.store
    heartbeat_timeout = float(opts.get("heartbeat_timeout", 10.0))
    restart_limit = int(opts.get("coord_restart_limit", 3))
    retry = RetryPolicy.from_opts(opts.get("retry"))
    src_root = _src_root()
    wal = _wal_dir(procs_dir)
    addr_path = _coord_addr_path(procs_dir)
    os.makedirs(wal, exist_ok=True)

    procs: list[subprocess.Popen | None] = [None] * n
    coord_proc = None
    incarnation = 0
    coord_restarts = 0
    recoveries = 0
    job._last_run_recoveries = 0
    job._last_run_coord_restarts = 0

    def _env():
        env = dict(os.environ)
        env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
        return env

    def _spawn_coord() -> None:
        nonlocal coord_proc
        cmd = [sys.executable, "-m", "repro.launch.procs", "coord",
               procs_dir, "--incarnation", str(incarnation)]
        with open(os.path.join(procs_dir, "coord.log"), "ab") as logf:
            coord_proc = subprocess.Popen(cmd, stdout=logf,
                                          stderr=subprocess.STDOUT,
                                          env=_env())

    def _wait_addr() -> None:
        # trust only an address stamped with the CURRENT incarnation: a
        # predecessor's file still names a dead port
        deadline = time.monotonic() + max(retry.deadline, 30.0)
        while True:
            try:
                with open(addr_path) as f:
                    if int(json.load(f).get("incarnation", -1)) == \
                            incarnation:
                        return
            except (OSError, ValueError):
                pass
            if coord_proc.poll() is not None:
                raise WorkerFailed(
                    -1, f"coordinator incarnation {incarnation} exited "
                        f"with code {coord_proc.returncode} before "
                        "publishing its address")
            if time.monotonic() > deadline:
                raise WorkerFailed(
                    -1, f"coordinator incarnation {incarnation} never "
                        "published its address")
            time.sleep(0.05)

    def _spawn(w: int, recover_to: int | None = None) -> None:
        d = _shard_dir(procs_dir, w)
        os.makedirs(d, exist_ok=True)
        cmd = [sys.executable, "-m", "repro.launch.procs", "worker",
               procs_dir, str(w)]
        if recover_to is not None:
            cmd += ["--recover-to", str(recover_to)]
        with open(os.path.join(d, "worker.log"), "ab") as logf:
            procs[w] = subprocess.Popen(cmd, stdout=logf,
                                        stderr=subprocess.STDOUT,
                                        env=_env())

    def _killall() -> None:
        victims = [p for p in procs + [coord_proc] if p is not None]
        for p in victims:
            if p.poll() is None:
                p.kill()
        for p in victims:
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                pass

    def _abort_run(w: int, reason: str, record: dict | None = None) -> None:
        write_record(os.path.join(procs_dir, "failure-summary.json"),
                     failure_record("launch-failed", shard=w, message=reason,
                                    record=record))
        # ask the coordinator to abort (stragglers exit via K_ABORT if any
        # survive the kill), then kill everything
        atomic_write_json(_abort_request_path(procs_dir),
                          dict(reason=str(reason)))
        _killall()
        raise WorkerFailed(w, reason, record=record)

    def _respawn_worker(w: int, recover_to: int | None, why: str,
                        record: dict | None = None) -> None:
        nonlocal recoveries
        if not can_recover:
            _abort_run(w, f"worker {w} {why} and the job has no checkpoint "
                          "+ message-log recovery wiring "
                          "(checkpoint_every=)", record=record)
        if recoveries >= MAX_RECOVERIES:
            _abort_run(w, f"worker {w} {why} after {recoveries} recoveries "
                          "— crash loop, giving up", record=record)
        recoveries += 1
        job._last_run_recoveries = recoveries
        p = procs[w]
        if p is not None and p.poll() is None:
            p.kill()
            p.wait()
        if recover_to is None:
            recover_to = max(_wal_last_commit(wal) + 1, start_step)
        if verbose:
            print(f"  [procs] worker {w} {why}; respawning with "
                  f"--recover-to {recover_to}")
        _spawn(w, recover_to=recover_to)

    history: list = []
    net_totals = dict(net_send_s=0.0, net_stall_s=0.0, net_recv_s=0.0,
                      net_recv_stall_s=0.0, net_wire_bytes=0.0,
                      net_frames=0.0)
    job._last_run_net = dict(net_totals)
    nonempty = max(store.nonempty_blocks(), 1)
    next_hist = start_step
    ok = False

    def _drain_wal() -> None:
        nonlocal next_hist
        while True:
            rec = _read_wal_commit(wal, next_hist)
            if rec is None:
                return
            s = int(rec["step"])
            r = SuperstepRecord(
                step=s, n_active=int(rec["n_active"]),
                n_msgs=int(rec["n_msgs"]), agg=float(rec["agg"]),
                density=float(rec.get("active_blocks", 0)) / nonempty,
                mode="streamed", seconds=float(rec.get("seconds", 0.0)),
                restored_from=restored_from if s == start_step else None,
                blocks_read=int(rec.get("blocks_read", 0)),
                cache_hits=int(rec.get("cache_hits", 0)),
                cache_evictions=int(rec.get("cache_evictions", 0)),
                blocks_skipped=int(rec.get("blocks_skipped", 0)),
            )
            history.append(r)
            next_hist = s + 1
            for key in net_totals:
                net_totals[key] += float(rec.get(key, 0.0))
            if verbose:
                print(
                    f"  superstep {s:4d}: active={r.n_active:>9d} "
                    f"msgs={r.n_msgs:>10d} agg={r.agg:.6g} "
                    f"density={r.density:.4f} "
                    f"[streamed procs x{n}] {r.seconds*1e3:.1f} ms"
                )
            if on_step is not None:
                on_step(r, None)

    try:
        _spawn_coord()
        _wait_addr()
        for w in range(n):
            _spawn(w)
        while True:
            _drain_wal()
            rc = coord_proc.poll()
            if rc == 0:
                break  # run complete: every result file landed
            if rc == 2:
                # coordinator aborted the run: surface the structured cause
                reason = "run aborted"
                try:
                    with open(os.path.join(wal, "abort.json")) as f:
                        reason = str(json.load(f)["reason"])
                except (OSError, ValueError, KeyError):
                    pass
                record = None
                for w in range(n):
                    record = record or _read_failure(procs_dir, w)
                _killall()
                shard = (int(record["shard"])
                         if record and record.get("shard") is not None
                         else -1)
                write_record(
                    os.path.join(procs_dir, "failure-summary.json"),
                    failure_record("launch-failed", shard=shard,
                                   message=reason, record=record))
                raise WorkerFailed(shard, reason, record=record)
            if rc is not None:
                # crashed (the kill -9 drill lands here): bounded respawn;
                # the successor restores the WAL and resumes mid-run
                if coord_restarts >= restart_limit:
                    _abort_run(-1, f"coordinator crashed (exit {rc}) after "
                                   f"{coord_restarts} restarts — giving up")
                coord_restarts += 1
                incarnation += 1
                job._last_run_coord_restarts = coord_restarts
                if verbose:
                    print(f"  [procs] coordinator crashed (exit {rc}); "
                          f"respawning incarnation {incarnation}")
                _spawn_coord()
                _wait_addr()
            for w in range(n):
                # the coordinator judges heartbeat staleness but cannot
                # respawn processes; it files a recover request instead
                req_path = _recover_request_path(procs_dir, w)
                if os.path.exists(req_path):
                    try:
                        with open(req_path) as f:
                            req = json.load(f)
                    except (OSError, ValueError):
                        req = None
                    try:
                        os.unlink(req_path)
                    except OSError:
                        pass
                    if req is not None:
                        _respawn_worker(
                            w, int(req["recover_to"]),
                            str(req.get("why", "went heartbeat-silent")),
                            record=_read_failure(procs_dir, w))
                        continue
                p = procs[w]
                if p is None or p.poll() is None:
                    continue
                if p.returncode in (0, 3):
                    # 0: result written post-halt; 3: told to abort — the
                    # cause surfaces through the coordinator exit path
                    procs[w] = None
                    continue
                rec = _read_failure(procs_dir, w)
                _respawn_worker(w, None,
                                _describe_exit(rec, p.returncode,
                                               _wal_last_commit(wal) + 1),
                                record=rec)
            time.sleep(0.05)
        _drain_wal()
        vals, acts = [], []
        for w in range(n):
            z = np.load(_result_path(procs_dir, w))
            vals.append(z["values"])
            acts.append(z["active"])
        for p in procs:
            if p is not None:
                try:
                    p.wait(timeout=30)
                except subprocess.TimeoutExpired:
                    p.kill()
        ok = True
    finally:
        if not ok:
            _killall()
        job._last_run_net = net_totals
    import jax.numpy as jnp

    return (jnp.asarray(np.stack(vals)), jnp.asarray(np.stack(acts))), history


# --------------------------------------------------------------------------
# coordinator process (sockets transport; stdlib + launch.net only)
# --------------------------------------------------------------------------

def coord_main(procs_dir: str, incarnation: int = 0) -> int:
    """Host the CoordServer plus the barrier/commit loop as a standalone
    process. Exit codes: 0 = run completed (every result file landed),
    2 = run aborted (reason WAL-logged); anything else is a crash, which
    the launcher answers with a successor incarnation — the successor
    restores the WAL and carries on mid-run."""
    with open(os.path.join(procs_dir, SPEC)) as f:
        spec = json.load(f)
    from repro.launch.net import CoordServer

    n = int(spec["n_shards"])
    hb_t = float(spec["heartbeat_timeout"])
    net = spec.get("net") or {}
    coord = CoordServer(
        n, heartbeat_timeout=hb_t,
        handshake_timeout=float(net.get("handshake_timeout", 5.0)),
        wal_dir=_wal_dir(procs_dir),
    )
    coord.start()
    try:
        # publish AFTER the WAL restore: a worker that reads this address
        # may immediately CHELLO and expect restored commit state
        atomic_write_json(_coord_addr_path(procs_dir),
                          dict(incarnation=int(incarnation),
                               addr=list(coord.addr)))
        return _coord_loop(spec, coord, procs_dir, int(incarnation))
    except RunAborted:
        return 2
    except Exception as e:
        import traceback

        traceback.print_exc()
        coord.abort(f"coordinator failed: {e}")
        return 2
    finally:
        coord.close()


def _coord_loop(spec: dict, coord, procs_dir: str, incarnation: int) -> int:
    n = int(spec["n_shards"])
    start_step = int(spec["start_step"])
    target = int(spec["target"])
    every = int(spec["checkpoint_every"]) if spec.get("ckpt_dir") else 0
    hb_t = float(spec["heartbeat_timeout"])
    num_supersteps = spec.get("num_supersteps")
    # the kill -9 drill arms in the first incarnation only: the successor
    # must prove recovery, not re-die
    drill = spec.get("coord_kill") if incarnation == 0 else None
    abort_path = _abort_request_path(procs_dir)

    def _poll_control() -> None:
        """Abort requests degrade the run to a clean loud stop."""
        coord.check_abort()
        if os.path.exists(abort_path):
            try:
                with open(abort_path) as f:
                    reason = str(json.load(f).get("reason",
                                                  "abort requested"))
            except (OSError, ValueError):
                reason = "abort requested"
            coord.abort(reason)
            raise RunAborted(reason)

    def _request_recover(step, got) -> None:
        """File a recover request for every heartbeat-stale worker; the
        launcher owns process lifecycles, so the respawn is its job. The
        grace grant keeps the request from being refiled while the
        replacement boots and reconnects."""
        for w in range(n):
            if w in got or not coord.stale(w):
                continue
            recover_to = max(coord.last_commit_step() + 1, start_step)
            atomic_write_json(
                _recover_request_path(procs_dir, w),
                dict(shard=w, recover_to=recover_to,
                     why=f"went heartbeat-silent (> {hb_t:.1f}s) "
                         f"mid-superstep {step}"))
            coord.grant_grace(w, hb_t + SPAWN_GRACE)

    # resume: never re-run a superstep the WAL already committed — workers
    # past that barrier would strand. Arrivals for the current (in-flight)
    # step are replayed by the reconnecting clients.
    last = coord.last_commit_step()
    start = max(last + 1, start_step)
    halted = last >= 0 and bool(coord.commit(last).get("halt"))

    if not halted:
        for s in range(start, target):
            t0 = time.perf_counter()
            while True:
                got = coord.arrivals(s)
                if (drill is not None and int(drill["step"]) == s
                        and len(got) >= int(drill.get("after_arrivals", 1))):
                    # mid-barrier kill -9: arrivals received, commit not
                    # yet WALed — the successor must re-collect them
                    os.kill(os.getpid(), signal.SIGKILL)
                if len(got) == n:
                    break
                _poll_control()
                _request_recover(s, got)
                time.sleep(0.05)
            totals = coord.reduce_arrivals(got)
            ckpt_landed = False
            if every and (s + 1) % every == 0:
                _finalize_checkpoint_dir(
                    spec["ckpt_dir"], s + 1, n, int(spec["P"]),
                    spec["value_dtype"], spec.get("store_signature"),
                    keep=int(spec.get("ckpt_keep", 2)) or 2,
                )
                ckpt_landed = True
            halt = ((num_supersteps is None and totals["n_active"] == 0)
                    or s + 1 >= target)
            coord.publish_commit(
                s, totals, halt=halt, ckpt_landed=ckpt_landed,
                extra=dict(seconds=time.perf_counter() - t0))
            if halt:
                break

    # wait for every worker's result file; a worker that dies between its
    # last commit and the result write is recovered like any other
    while True:
        missing = [w for w in range(n)
                   if not os.path.exists(_result_path(procs_dir, w))]
        if not missing:
            return 0
        _poll_control()
        _request_recover("result", set(range(n)) - set(missing))
        time.sleep(0.05)


# --------------------------------------------------------------------------
# worker (runs in its own process; everything below main() may import jax)
# --------------------------------------------------------------------------

def _latest_checkpoint_step(ckpt_dir: str, at_most: int) -> int | None:
    """Latest published checkpoint step <= ``at_most`` — read directly from
    the directory: workers never construct a Checkpointer (its constructor
    sweeps ``.tmp-step-*`` dirs that peers may be writing into)."""
    steps = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_DIR.match(name)
        if m and os.path.isdir(os.path.join(ckpt_dir, name)):
            s = int(m.group(1))
            if s <= at_most:
                steps.append(s)
    return max(steps) if steps else None


class _Worker:
    """One shard's superstep loop over either transport: shared-filesystem
    run files (default) or the TCP socket layer (``server`` is its
    PeerServer and a PeerSender transmit thread is wired to it)."""

    def __init__(self, spec: dict, program, shard: int, coord,
                 server=None, peer_addrs=None):
        import jax.numpy as jnp

        from repro.core.checkpoint import RunFileMessageLog
        from repro.core.config import EngineConfig
        from repro.core.engine import StreamKernels
        from repro.streams.reader import StreamReader
        from repro.streams.residency import BlockResidency
        from repro.streams.store import EdgeStreamStore

        self.spec = spec
        self.program = program
        self.w = shard
        self.coord = coord
        self.n = int(spec["n_shards"])
        self.P = int(spec["P"])
        self.cfg = EngineConfig.from_json(spec["config"])
        self.msg_dtype = np.dtype(spec["msg_dtype"])
        self.comb = program.combiner
        self.procs_dir = spec["procs_dir"]
        # the owner view: this process maps ONLY shard w's store row
        self.store = EdgeStreamStore.open(spec["store_dir"], owner=shard)
        # stream.cache_bytes is the PER-SHARD hot-cache budget: each worker
        # process owns exactly one shard, so the per-process division of the
        # planner's budget is simply cache_bytes — no further split needed
        # (the single-process engine scales by n_shards instead).
        self.residency = BlockResidency(self.store,
                                        self.cfg.stream.cache_bytes)
        # shard w's share of the store's nonempty blocks — the baseline the
        # per-step skip() tally is measured against (blk_hi is manifest
        # metadata, present even on an owner view)
        self.own_nonempty = int((self.store.blk_hi[shard] >= 0).sum())
        self.reader = StreamReader(self.store, self.cfg.stream.chunk_blocks,
                                   self.cfg.stream.depth,
                                   residency=self.residency)
        self.kern = StreamKernels(program, self.n, int(spec["n_vertices"]),
                                  self.P)
        z = np.load(os.path.join(_shard_dir(self.procs_dir, shard),
                                 "rows.npz"))
        self.degree = jnp.asarray(z["degree"])
        self.vmask = jnp.asarray(z["vmask"])
        self.old_ids = jnp.asarray(z["old_ids"])
        self.gids = jnp.asarray(z["gids"])
        self.log = None
        if spec["log_messages"]:
            # per-worker log lineage: one run-file index per store dir, so
            # n writers need n directories (logs/shard-w/step-NNNNNN)
            self.log = RunFileMessageLog(
                os.path.join(spec["logs_dir"], f"shard-{shard}"))
            self.log.configure(
                self.n, self.P, self.msg_dtype,
                e0=self.comb.e0 if self.comb is not None else 0,
                combined=self.comb is not None,
                compress=self.cfg.channel.compress,
                compress_payload=self.cfg.channel.compress_payload,
            )
        # slice-cap growth persists across supersteps, like the engine's
        self._slice_cap_eff = self.cfg.spill.slice_cap
        # -- socket transport wiring (None under the file transport) -------
        self.server = server
        self.sender = None
        self.net_stats = None
        if server is not None:
            from repro.launch.net import PeerSender
            from repro.streams.channel import ChannelStats
            from repro.streams.msgstore import MessageRunStore

            self.net_stats = ChannelStats()
            outbox_root = os.path.join(_shard_dir(self.procs_dir, shard),
                                       "outbox")
            n, P = self.n, self.P
            cfg, comb, mdt = self.cfg, self.comb, self.msg_dtype

            def make_store(step):
                # the sender's per-step replay log, in the SAME store
                # transform as the file transport's outbox — what goes on
                # the wire is what append_combined/append_raw produce
                d = os.path.join(outbox_root, f"step-{step:06d}")
                shutil.rmtree(d, ignore_errors=True)
                return MessageRunStore(
                    d, n, P, mdt, with_counts=comb is not None,
                    compress=cfg.channel.compress,
                    compress_payload=cfg.channel.compress_payload,
                )

            net = spec.get("net") or {}
            self.sender = PeerSender(
                shard, n, make_store, inflight=cfg.channel.inflight,
                stats=self.net_stats, check_abort=coord.check_abort,
                connect_timeout=float(net.get("connect_timeout", 5.0)),
                send_timeout=float(net.get("send_timeout", 60.0)),
                retry=RetryPolicy.from_opts(net.get("retry")),
            )
            self.sender.set_addrs(peer_addrs)
            # a respawned peer's new data address flows straight into the
            # transmit thread, which reconnects and resumes from its outbox
            coord.on_peer_update = self.sender.update_addr
            self.sender.start()

    # -- state bootstrap -------------------------------------------------------
    def bootstrap(self):
        import jax.numpy as jnp

        spec, w = self.spec, self.w
        boot = os.path.join(_shard_dir(self.procs_dir, w), "boot.npz")
        if spec["bootstrap"] == "state" and os.path.exists(boot):
            z = np.load(boot)
            return jnp.asarray(z["values"]), jnp.asarray(z["active"])
        if spec["bootstrap"] == "checkpoint":
            return self.restore_shard(int(spec["ckpt_step"]))
        return self.kern.init(jnp.int32(w), self.degree, self.vmask,
                              self.old_ids, self.gids)

    def restore_shard(self, step: int):
        import jax.numpy as jnp

        d = os.path.join(self.spec["ckpt_dir"], f"step-{step:06d}")
        z = np.load(os.path.join(d, f"shard-{self.w}.npz"))
        return jnp.asarray(z["values"]), jnp.asarray(z["active"])

    # -- send phase ------------------------------------------------------------
    def _own_schedule(self, active_w) -> list:
        prefix = np.concatenate(
            [[0], np.cumsum(np.asarray(active_w).astype(np.int64))]
        )
        out = []
        for k in range(self.n):
            ids = self.store.active_blocks(self.w, k, prefix)
            if ids.size:
                out.append((self.w, k, ids))
        return out

    def _send(self, s: int, values_w, active_w) -> None:
        """Fold/spill shard w's outgoing groups for step ``s`` into the
        outbox store and publish the announce marker. Idempotent: a marker
        already on disk means a pre-crash incarnation finished the send
        (markers land only after ``save_index``), so recovery skips it —
        peers may already have consumed those runs."""
        import jax
        import jax.numpy as jnp

        from repro.streams.msgstore import MessageRunStore

        marker = _announce_path(self.procs_dir, s, self.w)
        if os.path.exists(marker):
            return
        schedule = self._own_schedule(active_w)
        # §3.2 selective scheduling: every owned block skip() left off this
        # step's plan is disk I/O that never happens — tally it here (and
        # not on the marker short-circuit, so a recovery respawn does not
        # double-count) for the arrival record's residency counters
        self.residency.note_skipped(
            self.own_nonempty
            - sum(len(ids) for (_, _, ids) in schedule)
        )
        step = jnp.int32(s)
        obox = MessageRunStore(
            _outbox_dir(self.procs_dir, s, self.w), self.n, self.P,
            self.msg_dtype, with_counts=self.comb is not None,
            compress=self.cfg.channel.compress,
            compress_payload=self.cfg.channel.compress_payload,
        )
        for (_, k, ids) in schedule:
            if self.comb is not None:
                A = self.comb.identity((self.P,), self.program.msg_dtype)
                cnt = jnp.zeros((self.P,), jnp.int32)
                for chunk in self.reader.stream([(self.w, k, ids)]):
                    A, cnt = self.kern.fold(
                        A, cnt, values_w, self.degree, active_w,
                        jnp.asarray(chunk.sp), jnp.asarray(chunk.dp),
                        jnp.asarray(chunk.w), step,
                    )
                    # the staging buffers are recycled by the prefetcher:
                    # the fold must be materialized before the next chunk
                    jax.block_until_ready(cnt)
                # the shared append_combined wire format (streams/msgstore)
                obox.append_combined(k, np.asarray(A), np.asarray(cnt),
                                     tag=self.w)
            else:
                for chunk in self.reader.stream([(self.w, k, ids)]):
                    msg, dp, valid = self.kern.msgs(
                        values_w, self.degree, active_w,
                        chunk.sp, chunk.dp, chunk.w, step,
                    )
                    # np.asarray blocks AND copies out of the recycled
                    # staging buffers, exactly like the engine's spill
                    obox.append_raw(k, np.asarray(dp), np.asarray(msg),
                                    np.asarray(valid), tag=self.w)
        obox.save_index()
        obox.close()
        os.makedirs(os.path.dirname(marker), exist_ok=True)
        atomic_write_json(marker, dict(src=self.w, step=s))

    def _send_net(self, s: int, values_w, active_w) -> None:
        """Socket-transport send phase: the same fold/spill as :meth:`_send`
        but each group goes to the PeerSender the moment it is folded — the
        transmit thread appends it to the step's outbox store (the replay
        log) and frames it onto the destination's connection while the next
        group is still folding. No idempotence marker: re-sent runs after a
        respawn are deduplicated by the resume protocol's sequence check."""
        import jax
        import jax.numpy as jnp

        schedule = self._own_schedule(active_w)
        self.residency.note_skipped(
            self.own_nonempty
            - sum(len(ids) for (_, _, ids) in schedule)
        )
        step = jnp.int32(s)
        for (_, k, ids) in schedule:
            if self.comb is not None:
                A = self.comb.identity((self.P,), self.program.msg_dtype)
                cnt = jnp.zeros((self.P,), jnp.int32)
                for chunk in self.reader.stream([(self.w, k, ids)]):
                    A, cnt = self.kern.fold(
                        A, cnt, values_w, self.degree, active_w,
                        jnp.asarray(chunk.sp), jnp.asarray(chunk.dp),
                        jnp.asarray(chunk.w), step,
                    )
                    jax.block_until_ready(cnt)
                self.sender.send_combined(k, np.asarray(A),
                                          np.asarray(cnt), tag=self.w)
            else:
                for chunk in self.reader.stream([(self.w, k, ids)]):
                    msg, dp, valid = self.kern.msgs(
                        values_w, self.degree, active_w,
                        chunk.sp, chunk.dp, chunk.w, step,
                    )
                    self.sender.send_raw(k, np.asarray(dp), np.asarray(msg),
                                         np.asarray(valid), tag=self.w)
        self.sender.end_step()

    def _superstep_net(self, s: int, values_w, active_w, inbox):
        """One socket-transport superstep: a reader thread drains the n
        peer connections in ascending source order into the inbox (and the
        ChannelReceiver digest, when combining) WHILE the fold transmits —
        §4's full overlap, with the same digest sequence as the file path:
        per source, runs land in sender append order; sources complete
        ascending. Returns the engine-shaped ``(nv, na, nact, nm, ag)``."""
        import jax
        import jax.numpy as jnp

        from repro.streams.channel import ChannelReceiver

        self.server.begin_step(s)
        self.sender.begin_step(s)
        comb, stats = self.comb, self.net_stats
        receiver = None
        if comb is not None:
            P = self.P
            identity = lambda: (comb.identity((P,), self.program.msg_dtype),
                                jnp.zeros((P,), jnp.int32))

            def _digest(A, cnt, A_d, c_d):
                A, cnt = self.kern.digest(A, cnt, jnp.asarray(A_d),
                                          jnp.asarray(c_d))
                jax.block_until_ready(cnt)
                return A, cnt

            receiver = ChannelReceiver(inbox, _digest, identity, comb.e0,
                                       stats=stats)

        def on_run(hdr, dp, msg, cnt):
            t0 = time.perf_counter()
            lseg = inbox.append_run(
                self.w, dp, msg,
                cnt=cnt if comb is not None else None, tag=hdr["tag"])
            if receiver is not None:
                receiver.enqueue_digest(self.w, lseg)
            # reader busy time overlaps the fold exactly like digest time
            # (collect() accounts the stall side)
            stats.recv_seconds += time.perf_counter() - t0

        errs: list[BaseException] = []

        def drain():
            try:
                for j in range(self.n):
                    self.server.read_source(s, j, on_run,
                                            self.coord.check_abort)
                    if comb is None:
                        # per-source compaction, same as the file path —
                        # the run-table evolution the merge depends on
                        inbox.compact_tag(self.w, j,
                                          self.cfg.spill.merge_fanin,
                                          self.cfg.spill.read_chunk)
            except BaseException as e:  # surfaced on the compute thread
                errs.append(e)

        t = threading.Thread(target=drain, name="net-recv", daemon=True)
        t.start()
        try:
            self._send_net(s, values_w, active_w)
            while t.is_alive():
                t.join(0.2)
                self.sender.check_failed()
                self.coord.check_abort()
            if errs:
                raise errs[0]
            if comb is not None:
                A_r, cnt = receiver.collect(self.w)
                return self.kern.apply(
                    values_w, self.degree, self.vmask, self.old_ids,
                    self.gids, A_r, cnt, active_w, jnp.int32(s),
                    jnp.int32(self.w),
                )
            acc_v, acc_a, cnt_k = self._apply_list_merged(
                inbox, values_w, active_w, jnp.int32(s))
            nact, nm, ag = self.kern.finish(values_w, acc_v, acc_a, cnt_k,
                                            self.vmask)
            return acc_v, acc_a, nact, nm, ag
        finally:
            if receiver is not None:
                receiver.close()

    # -- receive phase ---------------------------------------------------------
    def _open_inbox(self, s: int):
        from repro.streams.msgstore import MessageRunStore

        if self.log is not None:
            return self.log.open_step(s)
        return MessageRunStore(
            os.path.join(_shard_dir(self.procs_dir, self.w), "inbox",
                         f"step-{s:06d}"),
            self.n, self.P, self.msg_dtype,
            with_counts=self.comb is not None,
            compress=self.cfg.channel.compress,
            compress_payload=self.cfg.channel.compress_payload,
        )

    def _pull_runs(self, s: int, src: int, inbox, receiver=None) -> None:
        """Copy source ``src``'s runs for this shard out of its announced
        outbox into the local inbox, preserving run boundaries and tags.
        Bounded memory: a combined run is <= P positions, an uncompacted
        raw run is <= one staged chunk's messages."""
        from repro.streams.msgstore import MessageRunStore

        self.coord.wait_file(
            _announce_path(self.procs_dir, s, src), self.w)
        src_store = MessageRunStore.open(_outbox_dir(self.procs_dir, s, src))
        try:
            for seg in src_store.runs(self.w):
                parts = src_store.read_run(self.w, seg)
                lseg = inbox.append_run(
                    self.w, parts[0], parts[1],
                    cnt=parts[2] if self.comb is not None else None,
                    tag=seg.tag,
                )
                if receiver is not None:
                    receiver.enqueue_digest(self.w, lseg)
        finally:
            src_store.close()

    def _receive_combined(self, s: int, values_w, active_w, inbox):
        """Digest ascending source through the real ChannelReceiver — the
        per-position digest sequence equals the threaded full-duplex path's
        (transmit order == source-ascending), so results are bit-identical."""
        import jax
        import jax.numpy as jnp

        from repro.streams.channel import ChannelReceiver

        comb, P = self.comb, self.P
        identity = lambda: (comb.identity((P,), self.program.msg_dtype),
                            jnp.zeros((P,), jnp.int32))

        def _digest(A, cnt, A_d, c_d):
            A, cnt = self.kern.digest(A, cnt, jnp.asarray(A_d),
                                      jnp.asarray(c_d))
            jax.block_until_ready(cnt)
            return A, cnt

        receiver = ChannelReceiver(inbox, _digest, identity, comb.e0)
        try:
            for j in range(self.n):
                self._pull_runs(s, j, inbox, receiver=receiver)
            A_r, cnt = receiver.collect(self.w)
        finally:
            receiver.close()
        return self.kern.apply(
            values_w, self.degree, self.vmask, self.old_ids, self.gids,
            A_r, cnt, active_w, jnp.int32(s), jnp.int32(self.w),
        )

    def _receive_nocomb(self, s: int, values_w, active_w, inbox):
        """Combiner-less receive: copy + per-source compaction reproduces
        the threaded engine's run-table evolution exactly, then the merged
        destination-aligned apply (its local mirror of
        ``_apply_list_merged``) folds the slices."""
        import jax.numpy as jnp

        for j in range(self.n):
            self._pull_runs(s, j, inbox)
            inbox.compact_tag(self.w, j, self.cfg.spill.merge_fanin,
                              self.cfg.spill.read_chunk)
        acc_v, acc_a, cnt_k = self._apply_list_merged(
            inbox, values_w, active_w, jnp.int32(s))
        nact, nm, ag = self.kern.finish(values_w, acc_v, acc_a, cnt_k,
                                        self.vmask)
        return acc_v, acc_a, nact, nm, ag

    def _apply_list_merged(self, mstore, values_w, active_w, step):
        """Worker-local mirror of ``GraphDEngine._apply_list_merged`` (same
        slice-cap growth, covered-overwrite accumulation, and padding-only
        fallback; the slice decomposition is results-neutral)."""
        import jax.numpy as jnp

        w = self.w
        counts = mstore.dest_counts(w)
        max_run = int(counts.max()) if counts.size else 0
        while self._slice_cap_eff < max_run:
            self._slice_cap_eff *= 2
        cap = self._slice_cap_eff
        cnt_k = jnp.asarray(
            np.minimum(counts, np.iinfo(np.int32).max).astype(np.int32)
        )
        shard = jnp.int32(w)
        acc_v = acc_a = None
        for sdp, smsg, covered in mstore.merged_slices(
                w, cap, self.cfg.spill.read_chunk):
            nv, na = self.kern.apply_list(
                values_w, self.degree, self.vmask, self.old_ids, self.gids,
                jnp.asarray(sdp), jnp.asarray(smsg), cnt_k, active_w, step,
                shard,
            )
            if acc_v is None:
                acc_v, acc_a = nv, na
            else:
                cov = jnp.asarray(covered)
                acc_v = jnp.where(cov, nv, acc_v)
                acc_a = jnp.where(cov, na, acc_a)
        if acc_v is None:  # no messages at all: one padding-only call
            acc_v, acc_a = self.kern.apply_list(
                values_w, self.degree, self.vmask, self.old_ids, self.gids,
                jnp.asarray(np.full((cap,), self.P, np.int32)),
                jnp.asarray(np.zeros((cap,), self.msg_dtype)),
                cnt_k, active_w, step, shard,
            )
        return acc_v, acc_a, cnt_k

    # -- recovery replay -------------------------------------------------------
    def replay(self, t: int, values_w, active_w):
        """Re-derive the step-``t`` state transition from this worker's own
        message log (which holds EVERY run addressed to it, its own group
        included — the live receive copies them all), digesting in append
        order = the live digest order, so replay is bit-identical."""
        import jax
        import jax.numpy as jnp

        from repro.streams.msgstore import MessageRunStore

        step = jnp.int32(t)
        store_t = MessageRunStore.open(self.log.step_dir(t))
        try:
            if self.comb is not None:
                comb = self.comb
                A_r = comb.identity((self.P,), self.program.msg_dtype)
                cnt = jnp.zeros((self.P,), jnp.int32)
                for seg in store_t.runs(self.w):
                    A_d, c_d = store_t.read_combined(self.w, seg, comb.e0)
                    A_r, cnt = self.kern.digest(A_r, cnt, jnp.asarray(A_d),
                                                jnp.asarray(c_d))
                    jax.block_until_ready(cnt)
                nv, na, *_ = self.kern.apply(
                    values_w, self.degree, self.vmask, self.old_ids,
                    self.gids, A_r, cnt, active_w, step, jnp.int32(self.w),
                )
                return nv, na
            acc_v, acc_a, _ = self._apply_list_merged(
                store_t, values_w, active_w, step)
            return acc_v, acc_a
        finally:
            store_t.close()

    # -- the superstep loop ----------------------------------------------------
    def run(self, recover_to: int | None = None) -> None:
        spec, coord, w = self.spec, self.coord, self.w
        start = int(spec["start_step"])
        target = int(spec["target"])
        every = int(spec["checkpoint_every"])
        if recover_to is not None:
            # read-path integrity: a respawn (especially one triggered by
            # a corruption quarantine) must not trust the edge tier
            # blindly — re-verify the store's per-channel CRCs first
            self.store.verify_integrity()
            C = _latest_checkpoint_step(spec["ckpt_dir"], recover_to)
            if C is None:
                # nothing checkpointed yet (e.g. the very first checkpoint
                # write faulted): the message logs for every committed step
                # are still intact — gc only runs after a checkpoint lands —
                # so replay the whole prefix on top of the bootstrap state
                values_w, active_w = self.bootstrap()
                C = int(spec["start_step"])
            else:
                values_w, active_w = self.restore_shard(C)
            for t in range(C, recover_to):
                values_w, active_w = self.replay(t, values_w, active_w)
            start = recover_to
            if start > int(spec["start_step"]):
                cm = coord.commit(start - 1)
                if cm is not None and cm.get("halt"):
                    # the job already halted; just republish the final rows
                    self._write_result(values_w, active_w)
                    return
        else:
            values_w, active_w = self.bootstrap()

        for s in range(start, target):
            inj = _fault.active()
            if inj is not None:  # step context for the file-write sites
                inj.set_step(s)
            # all edge-block reads happen inside _send's folds, through the
            # residency layer — the counter deltas around the step are this
            # shard's contribution to the coordinator's SuperstepRecord
            h0, m0, e0, k0 = self.residency.counters()
            st = self.net_stats
            ns0 = ((st.send_seconds, st.stall_seconds, st.recv_seconds,
                    st.recv_stall_seconds, st.wire_bytes, st.packets)
                   if st is not None else None)
            inbox = None
            try:
                if self.server is not None:
                    inbox = self._open_inbox(s)
                    nv, na, nact, nm, ag = self._superstep_net(
                        s, values_w, active_w, inbox)
                else:
                    self._send(s, values_w, active_w)
                    inbox = self._open_inbox(s)
                    if self.comb is not None:
                        nv, na, nact, nm, ag = self._receive_combined(
                            s, values_w, active_w, inbox)
                    else:
                        nv, na, nact, nm, ag = self._receive_nocomb(
                            s, values_w, active_w, inbox)
            except OSError as e:
                if e.errno in _DISK_ERRNOS:
                    # a spill/inbox blob write failed: name the tier so
                    # the failure record and the launcher's message do
                    raise TierFault("spill", s, e) from e
                raise
            finally:
                if inbox is not None:
                    if self.log is not None:
                        self.log.close_step(s)
                    else:
                        inbox.close()
                        inbox.delete()
            values_w, active_w = nv, na
            # next-frontier active blocks for this shard's source row (the
            # coordinator divides the sum by the store's nonempty blocks to
            # get the engine's density signal)
            nblocks = sum(
                len(ids) for (_, _, ids) in self._own_schedule(active_w)
            )
            ckpt = False
            if every and (s + 1) % every == 0 and spec["ckpt_dir"]:
                tmp = os.path.join(spec["ckpt_dir"],
                                   f".tmp-step-{s + 1:06d}")
                try:
                    os.makedirs(tmp, exist_ok=True)
                    inj = _fault.active()
                    if inj is not None:  # chaos: fail the shard dump
                        inj.check("io.write.ckpt", step=s + 1)
                    np.savez(os.path.join(tmp, f"shard-{w}.npz"),
                             values=np.asarray(values_w),
                             active=np.asarray(active_w))
                except OSError as e:
                    if e.errno in _DISK_ERRNOS:
                        raise TierFault("checkpoint", s + 1, e) from e
                    raise
                ckpt = True
            h1, m1, e1, k1 = self.residency.counters()
            stats = dict(
                n_active=int(nact), n_msgs=int(nm), agg=float(ag),
                active_blocks=int(nblocks), ckpt=ckpt,
                blocks_read=m1 - m0, cache_hits=h1 - h0,
                cache_evictions=e1 - e0, blocks_skipped=k1 - k0,
            )
            if ns0 is not None:  # per-step socket channel accounting deltas
                stats.update(
                    net_send_s=st.send_seconds - ns0[0],
                    net_stall_s=st.stall_seconds - ns0[1],
                    net_recv_s=st.recv_seconds - ns0[2],
                    net_recv_stall_s=st.recv_stall_seconds - ns0[3],
                    net_wire_bytes=st.wire_bytes - ns0[4],
                    net_frames=st.packets - ns0[5],
                )
            coord.arrive(s, w, stats)
            cm = coord.wait_commit(s, w)
            if self.log is not None and cm.get("ckpt_landed"):
                self.log.gc_before(s + 1)
            # every peer has consumed this step's messages (they arrived
            # before the commit could exist) — reclaim the outbox
            if self.sender is not None:
                self.sender.finish_step(s)
            else:
                shutil.rmtree(_outbox_dir(self.procs_dir, s, w),
                              ignore_errors=True)
            if cm.get("halt"):
                break
        self._write_result(values_w, active_w)

    def _write_result(self, values_w, active_w) -> None:
        path = _result_path(self.procs_dir, self.w)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        _save_npz_atomic(path, values=np.asarray(values_w),
                         active=np.asarray(active_w))


def _close_net(sender, server, coord, shard: int) -> None:
    """Close the worker's socket-transport pieces in dependency order
    (sender first: its transmit thread may still hold peer connections).
    Every failure is reported, only the first propagates — a close error
    must not shadow the ones after it."""
    first: BaseException | None = None
    for res in (sender, server, coord):
        if res is None:
            continue
        try:
            res.close()
        except Exception as e:
            print(f"worker {shard}: net close failed: {e}", file=sys.stderr)
            if first is None:
                first = e
    if first is not None:
        raise first


def worker_main(spec_dir: str, shard: int,
                recover_to: int | None = None) -> int:
    with open(os.path.join(spec_dir, SPEC)) as f:
        spec = json.load(f)
    n = int(spec["n_shards"])
    transport = spec.get("transport", "files")
    # arm the chaos schedule — FIRST incarnation only: the spec is shared
    # by every incarnation and a respawn must prove recovery, not re-trip
    # the drill that killed its predecessor
    if recover_to is None:
        sched = FaultSchedule.from_opts(spec.get("faults"))
        kn = spec.get("kill_net")
        if kn is not None and int(kn.get("shard", -1)) == int(shard):
            # deprecated alias for the PR 8 drill, now a schedule event:
            # header + half the payload on the wire, then SIGKILL
            sched.events.append(FaultEvent(
                site="net.send", kind="torn_kill", step=int(kn["step"]),
                after=int(kn.get("after_frames", 0))))
        if sched.events:
            _fault.install(FaultInjector(sched, shard=int(shard)))
    server = None
    peer_addrs = None
    net = spec.get("net") or {}
    if transport == "sockets":
        # stdlib-only wiring, started BEFORE the heavy imports below:
        # liveness (heartbeats) and peer registration must not depend on
        # import latency
        from repro.launch.net import CoordClient, PeerServer

        start_step = (recover_to if recover_to is not None
                      else int(spec["start_step"]))
        server = PeerServer(
            n, start_step=start_step,
            handshake_timeout=float(net.get("handshake_timeout", 5.0)))
        server.start()
        coord = CoordClient(
            tuple(spec["coord_addr"]) if spec.get("coord_addr") else None,
            shard,
            heartbeat_interval=float(spec["heartbeat_interval"]),
            addr_file=spec.get("coord_addr_path"),
            connect_timeout=float(net.get("coord_connect_timeout", 10.0)),
            retry=RetryPolicy.from_opts(net.get("retry")),
        )
        coord.start()
    else:
        coord = FileCoordinator(
            spec["coord_dir"], n,
            heartbeat_interval=float(spec["heartbeat_interval"]),
            heartbeat_timeout=float(spec["heartbeat_timeout"]),
        )
        # beat BEFORE the heavy imports below (pickle pulls in repro.core
        # and jax): liveness must not depend on import latency
        coord.start_heartbeat(shard)
    wk = None
    try:
        if server is not None:
            peer_addrs = coord.register(server.addr)
        with open(os.path.join(spec_dir, PROGRAM), "rb") as f:
            program = pickle.load(f)
        wk = _Worker(spec, program, shard, coord,
                     server=server, peer_addrs=peer_addrs)
        wk.run(recover_to=recover_to)
        return 0
    except RunAborted as e:
        print(f"worker {shard}: {e}", file=sys.stderr)
        return 3
    except Exception as e:
        import traceback

        traceback.print_exc()
        rec = _classify_failure(e, int(shard))
        if rec is not None:
            # a named fault: quarantine corrupt blobs, sweep this shard's
            # torn write products, and publish the structured record the
            # launcher folds into WorkerFailed / failure-summary.json
            corrupt = find_in_chain(e, BlobCorruption)
            if corrupt is not None:
                _quarantine(corrupt)
            _sweep_partial(spec, int(shard))
            write_record(_failure_path(spec["procs_dir"], int(shard)), rec)
            return 4
        return 1
    finally:
        # every socket-transport resource joins its threads on close (and
        # raises on leak) — a worker that cannot stop its net threads must
        # exit nonzero, not pretend it shut down cleanly
        _close_net(wk.sender if wk is not None else None, server,
                   coord if transport == "sockets" else None, shard)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.procs")
    sub = ap.add_subparsers(dest="cmd", required=True)
    wk = sub.add_parser("worker", help="run one shard's worker process")
    wk.add_argument("spec_dir")
    wk.add_argument("shard", type=int)
    wk.add_argument("--recover-to", type=int, default=None)
    co = sub.add_parser("coord",
                        help="run the coordinator process (sockets)")
    co.add_argument("spec_dir")
    co.add_argument("--incarnation", type=int, default=0)
    args = ap.parse_args(argv)
    if args.cmd == "coord":
        return coord_main(args.spec_dir, args.incarnation)
    return worker_main(args.spec_dir, args.shard, args.recover_to)


if __name__ == "__main__":
    sys.exit(main())
