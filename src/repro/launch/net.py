"""TCP socket transport for the multi-process launch (paper §4's network).

The file transport (PR 6) exchanges messages through shared-filesystem run
files, so "network" cost is really disk cost. This layer ships the SAME run
wire format — per-destination runs in the sender's canonical spill/combine
transform, received in ascending source order — over persistent per-peer
TCP connections, and multiplexes the coordinator protocol (barrier
arrivals, commits, heartbeats, abort) onto one coordinator connection per
worker instead of polled files. Equivalence is structural: every run still
round-trips through a :class:`MessageRunStore` on both ends (sender-side
per-step outbox = the replay log, receiver-side inbox = the digest source),
so the 8-algorithm matrix stays bit-identical to the file transport and the
threaded driver — float programs included.

Framing: ``>IBII`` header (magic, kind, payload length, CRC32 of payload),
then the payload. A short read or EOF mid-frame raises :class:`TornFrame`;
a CRC/magic mismatch raises :class:`FrameError`. Receivers treat both as
"this connection is dead": the torn frame is discarded and the reader waits
for the sender to reconnect — no partial run ever reaches an inbox.

Reconnect-with-resume: each sender keeps the step's outgoing runs in a
local outbox store (``shard-w/outbox/step-S``, deleted only after the
step's commit). A (re)connecting sender opens with ``HELLO{src, step}``;
the receiver replies ``RESUME{step, have, ended}`` where ``have`` counts
the runs it already appended from that source. The sender replays
``runs[have:]`` from its outbox — run index IS the sequence number, so
duplicates (``seq < have``) are discarded and the append order the digest
depends on is preserved across any number of connection drops, sender
respawns, or receiver respawns.

Deadlock-freedom of the ascending-source reader: worker w's reader drains
source 0 first while w's own sends proceed on the background transmit
thread, so source 0's transmissions always complete; induction on the
source index does the rest. TCP backpressure (bounded kernel buffers)
bounds the memory of not-yet-read sources.
"""

from __future__ import annotations

import json
import os
import queue
import select
import signal
import socket
import struct
import threading
import time
import zlib

import numpy as np

from repro.core.coordinator import FileCoordinator, RunAborted
from repro.streams.codec import (
    decode_payload,
    decode_varint_delta,
    encode_payload,
    encode_varint_delta,
)

# -- framing -------------------------------------------------------------------

MAGIC = 0x47445052  # "GDPR"(aph-D): run-frame magic
_HEADER = struct.Struct(">IBII")  # magic, kind, payload nbytes, payload crc32
MAX_FRAME = 1 << 30  # sanity cap: a length beyond this is stream garbage

# data plane (worker <-> worker)
K_HELLO = 1  # sender handshake: {src, step}
K_RESUME = 2  # receiver reply: {step, have, ended}
K_RUN = 3  # one message run (json subheader + channel blobs)
K_END = 4  # sender finished the step toward this destination: {step, n_runs}
# coordinator plane (worker <-> launcher)
K_CHELLO = 10  # worker registration: {shard, addr}
K_PEERS = 11  # launcher reply: {addrs, last_commit, abort}
K_PEER_UPDATE = 12  # a shard respawned at a new address: {shard, addr}
K_BEAT = 13  # heartbeat: {shard, seq}
K_ARRIVE = 14  # barrier arrival: the full per-shard stats record
K_COMMIT = 15  # commit broadcast: the commit record
K_ABORT = 16  # poison pill broadcast: {reason}


class TornFrame(ConnectionError):
    """EOF or short read mid-frame: the peer died with a frame in flight.
    The partial bytes are discarded — never fed to an inbox."""


class FrameError(ConnectionError):
    """Magic or CRC mismatch: the stream is corrupt past recovery; the
    connection is dropped and the resume handshake re-delivers."""


def _recv_exact(conn: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = conn.recv(n - len(buf))
        if not chunk:
            raise TornFrame(f"connection closed after {len(buf)}/{n} bytes")
        buf += chunk
    return bytes(buf)


def send_frame(conn: socket.socket, kind: int, payload: bytes) -> int:
    """One length-prefixed CRC'd frame; returns bytes put on the wire."""
    hdr = _HEADER.pack(MAGIC, kind, len(payload), zlib.crc32(payload))
    conn.sendall(hdr + payload)
    return _HEADER.size + len(payload)


def recv_frame(conn: socket.socket) -> tuple[int, bytes]:
    """The inverse: blocks for one complete frame, verifies magic + CRC."""
    magic, kind, length, crc = _HEADER.unpack(_recv_exact(conn, _HEADER.size))
    if magic != MAGIC or length > MAX_FRAME:
        raise FrameError(f"bad frame header (magic={magic:#x} len={length})")
    payload = _recv_exact(conn, length)
    if zlib.crc32(payload) != crc:
        raise FrameError("frame CRC mismatch")
    return kind, payload


def _send_json(conn: socket.socket, kind: int, obj) -> int:
    return send_frame(conn, kind, json.dumps(obj).encode())


# -- run frame codec -----------------------------------------------------------

_RUN_HLEN = struct.Struct(">I")


def encode_run(*, step: int, seq: int, tag: int, dp: np.ndarray,
               msg: np.ndarray, cnt: np.ndarray | None,
               compress: bool = False, scheme: str | None = None) -> bytes:
    """One run -> one RUN frame payload.

    The channel blobs reuse the store codecs (varint-delta on the sorted
    destination column, the payload codec on the value column) so the wire
    carries the same compressed representation as the disk exchange it
    replaces. ``cnt`` (combine counts) stays raw — exactness is its job.
    """
    dp = np.ascontiguousarray(dp, np.int32)
    n = int(dp.size)
    dp_b = encode_varint_delta(dp) if (compress and n) else dp.tobytes()
    marr = np.ascontiguousarray(msg)
    msg_b = encode_payload(marr, scheme) if (scheme and n) else marr.tobytes()
    cnt_b = b""
    if cnt is not None:
        cnt_b = np.ascontiguousarray(cnt, np.int32).tobytes()
    hdr = json.dumps(dict(
        step=int(step), seq=int(seq), tag=int(tag), n=n,
        dp_nb=len(dp_b), msg_nb=len(msg_b), cnt_nb=len(cnt_b),
        dp_enc=bool(compress and n),
        scheme=scheme if (scheme and n) else None,
        msg_dtype=marr.dtype.name, cnt=cnt is not None,
    )).encode()
    return b"".join((_RUN_HLEN.pack(len(hdr)), hdr, dp_b, msg_b, cnt_b))


def decode_run(payload: bytes):
    """Inverse of :func:`encode_run` -> ``(hdr, dp, msg, cnt)``."""
    (hlen,) = _RUN_HLEN.unpack_from(payload)
    hdr = json.loads(payload[_RUN_HLEN.size:_RUN_HLEN.size + hlen])
    off = _RUN_HLEN.size + hlen
    n = hdr["n"]
    dp_b = payload[off:off + hdr["dp_nb"]]
    off += hdr["dp_nb"]
    msg_b = payload[off:off + hdr["msg_nb"]]
    off += hdr["msg_nb"]
    cnt_b = payload[off:off + hdr["cnt_nb"]]
    if hdr["dp_enc"]:
        dp = np.asarray(decode_varint_delta(dp_b), np.int32)
    else:
        dp = np.frombuffer(dp_b, np.int32)
    dtype = np.dtype(hdr["msg_dtype"])
    if hdr["scheme"]:
        msg = np.asarray(decode_payload(msg_b, dtype, n, hdr["scheme"]))
    else:
        msg = np.frombuffer(msg_b, dtype)
    cnt = np.frombuffer(cnt_b, np.int32) if hdr["cnt"] else None
    return hdr, dp, msg, cnt


def _force_close(sock: socket.socket) -> None:
    """Close a socket another thread may be blocked on. ``close()`` alone
    does NOT interrupt a thread parked in ``accept()`` or ``recv()`` on
    Linux — it stays in the syscall until traffic arrives, which is never
    at teardown; ``shutdown()`` forces accept to return EINVAL and recv to
    return EOF first. Every cross-thread close must go through here, or
    the join-with-timeout discipline in the ``close()`` methods turns a
    silently parked thread into a hard RuntimeError."""
    try:
        sock.shutdown(socket.SHUT_RDWR)
    except OSError:
        pass
    try:
        sock.close()
    except OSError:
        pass


# -- data plane: receiver ------------------------------------------------------

class PeerServer:
    """One per worker: accepts the n persistent inbound connections (one
    per source, self included via loopback) and hands complete runs to the
    step's reader in ascending source order.

    The accept thread performs the HELLO/RESUME handshake and swaps the
    per-source connection slot; :meth:`read_source` owns all data-frame
    reading, so runs from source j are appended exactly in sequence order —
    the append order the combiner-less merge's cursor tie-break depends on.
    """

    def __init__(self, n_shards: int, start_step: int,
                 host: str = "127.0.0.1"):
        self.n = int(n_shards)
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, 0))
        self._sock.listen(self.n + 8)
        self.addr = self._sock.getsockname()
        self._cv = threading.Condition()
        self._conns: list[socket.socket | None] = [None] * self.n
        self._step = int(start_step)
        self._have = [0] * self.n  # runs appended per source, this step
        self._ended = [False] * self.n
        self._closed = False
        self._thread: threading.Thread | None = None

    def start(self) -> None:
        self._thread = threading.Thread(target=self._accept_loop,
                                        name="peer-accept", daemon=True)
        self._thread.start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return  # listener closed
            try:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                # a wedged peer must not pin the accept loop past close():
                # bound the handshake, then restore blocking for data frames
                conn.settimeout(5.0)
                kind, payload = recv_frame(conn)
                if kind != K_HELLO:
                    raise FrameError(f"expected HELLO, got kind={kind}")
                src = int(json.loads(payload)["src"])
                with self._cv:
                    reply = dict(step=self._step, have=self._have[src],
                                 ended=self._ended[src])
                    old, self._conns[src] = self._conns[src], conn
                    self._cv.notify_all()
                _send_json(conn, K_RESUME, reply)
                conn.settimeout(None)
                if old is not None:
                    _force_close(old)
            except (ConnectionError, OSError, KeyError, ValueError):
                try:
                    conn.close()
                except OSError:
                    pass

    def begin_step(self, step: int) -> None:
        with self._cv:
            self._step = int(step)
            self._have = [0] * self.n
            self._ended = [False] * self.n

    def read_source(self, step: int, src: int, on_run, check_abort) -> int:
        """Drain source ``src`` for ``step``: calls ``on_run(hdr, dp, msg,
        cnt)`` per fresh run, returns the run count once END arrives.

        Stale frames (an earlier step, replayed after a commit the sender
        had not seen) and duplicates (``seq < have``, replayed by the
        resume handshake) are discarded; a torn/corrupt connection is
        dropped and the loop waits for the sender to reconnect."""
        while True:
            with self._cv:
                conn = self._conns[src]
            if conn is None:
                check_abort()
                with self._cv:
                    if self._conns[src] is None:
                        self._cv.wait(0.1)
                continue
            try:
                ready, _, _ = select.select([conn], [], [], 0.25)
                if not ready:
                    check_abort()
                    continue
                kind, payload = recv_frame(conn)
            except (ConnectionError, OSError):
                self._drop(src, conn)
                check_abort()
                continue
            if kind == K_RUN:
                hdr, dp, msg, cnt = decode_run(payload)
                if hdr["step"] < step:
                    continue  # pre-reconnect leftovers of a committed step
                if hdr["step"] > step:
                    raise RuntimeError(
                        f"source {src} ran ahead: frame step {hdr['step']} "
                        f"while reading step {step}")
                if hdr["seq"] < self._have[src]:
                    continue  # resume-handshake replay duplicate
                if hdr["seq"] > self._have[src]:
                    raise RuntimeError(
                        f"sequence gap from source {src}: got {hdr['seq']}, "
                        f"expected {self._have[src]}")
                on_run(hdr, dp, msg, cnt)
                with self._cv:
                    self._have[src] += 1
            elif kind == K_END:
                if json.loads(payload)["step"] < step:
                    continue
                with self._cv:
                    self._ended[src] = True
                return self._have[src]
            else:
                raise RuntimeError(f"unexpected data frame kind={kind}")

    def _drop(self, src: int, conn: socket.socket) -> None:
        with self._cv:
            if self._conns[src] is conn:
                self._conns[src] = None
        try:
            conn.close()
        except OSError:
            pass

    def close(self) -> None:
        """Close the listener and every source connection, then join the
        accept thread — raising if it leaks (the ChannelSender contract:
        a thread we cannot stop keeps sockets open and makes this worker's
        inbox unsafe to reuse, so it must be an error, not a warning)."""
        self._closed = True
        _force_close(self._sock)
        with self._cv:  # the accept thread swaps slots under this lock
            conns = list(self._conns)
        for conn in conns:
            if conn is not None:
                _force_close(conn)
        if self._thread is not None and self._thread.ident is not None:
            self._thread.join(timeout=10.0)
            if self._thread.is_alive():
                raise RuntimeError(
                    "peer-accept thread failed to stop within 10s; "
                    "thread leaked")


# -- data plane: sender --------------------------------------------------------

class _Stop(Exception):
    """Internal: the sender was closed mid-wait."""


class PeerSender:
    """One per worker: a single transmit thread drains a FIFO op queue so
    runs leave in exactly the fold's emission order, overlapping the fold
    (§4's U_s ∥ U_c) the same way the threaded channel's sender does.

    Every run is appended to the step's local outbox store FIRST (the
    canonical spill/combine transform — same bytes as the file exchange)
    and the framed wire bytes are read back from it, so what is replayable
    is exactly what was sent. ``inflight`` bounds the queue the way the
    channel's sender does: the compute thread blocks (stall-accounted)
    when the network falls behind.
    """

    RECONNECT_POLL = 0.1
    RECONNECT_POLL_MAX = 1.0
    SEND_TIMEOUT = 60.0

    # GIL-atomic by review: _exc is write-once (transmit thread) and only
    # read after it is set; _stats scalars are monotonic stall/byte
    # counters — a torn read is a stale report, never a control decision
    _LOCKED_FIELDS = frozenset({"_exc", "_stats"})

    def __init__(self, me: int, n_shards: int, make_store, *,
                 inflight: int = 4, stats=None, check_abort=None,
                 kill_net: dict | None = None):
        self.me = int(me)
        self.n = int(n_shards)
        self._make_store = make_store  # step -> fresh MessageRunStore
        self._stats = stats
        self._check_abort = check_abort or (lambda: None)
        self._kill = kill_net
        self._kill_frames = 0
        self._addrs: list[tuple | None] = [None] * self.n
        self._conns: list[socket.socket | None] = [None] * self.n
        self._q: queue.Queue = queue.Queue()
        self._slots = threading.BoundedSemaphore(max(1, int(inflight)))
        self._sent = [0] * self.n  # runs appended (== next seq) per dest
        self._end_sent = [False] * self.n
        self._step: int | None = None
        self._store = None
        self._stores: dict[int, object] = {}  # kept until the step commits
        self._exc: BaseException | None = None
        self._closed = False
        self._thread = threading.Thread(target=self._loop, name="peer-send",
                                        daemon=True)

    # -- compute-thread surface ----------------------------------------------
    def set_addrs(self, addrs) -> None:
        self._addrs = [tuple(a) for a in addrs]

    def start(self) -> None:
        self._thread.start()

    def update_addr(self, shard: int, addr) -> None:
        """PEER_UPDATE arrived: shard respawned at a new address. The
        transmit thread reconnects and the RESUME handshake replays the
        outbox backlog."""
        self._addrs[int(shard)] = tuple(addr)
        self._q.put(("resync", int(shard)))

    def begin_step(self, step: int) -> None:
        """Synchronous: returns once the transmit thread swapped in the
        step's fresh outbox store (all prior-step ops drained first)."""
        ev = threading.Event()
        self._q.put(("begin", int(step), ev))
        self._wait(ev)

    def send_combined(self, dest: int, A, cnt, tag: int) -> None:
        self._acquire_slot()
        self._q.put(("comb", int(dest), A, cnt, int(tag)))

    def send_raw(self, dest: int, dp, msg, valid, tag: int) -> None:
        self._acquire_slot()
        self._q.put(("raw", int(dest), dp, msg, valid, int(tag)))

    def end_step(self) -> None:
        """Queue the END fan-out: ensures every destination's backlog is
        fully delivered (reconnecting + replaying as needed) before END."""
        ev = threading.Event()
        self._q.put(("end", ev))
        self._wait(ev)

    def finish_step(self, step: int) -> None:
        """The step committed: every receiver has everything, the outbox
        log is dead weight — delete it."""
        self._q.put(("drop", int(step)))

    def check_failed(self) -> None:
        if self._exc is not None:
            raise RuntimeError("socket sender failed") from self._exc

    def close(self) -> None:
        """Stop and JOIN the transmit thread, raising if it leaks. The quit
        op tears down connections and outbox stores from inside the thread
        (its own teardown path); ``_closed`` breaks any reconnect wait."""
        self._closed = True
        self._q.put(("quit",))
        if self._thread.ident is not None:
            self._thread.join(timeout=10.0)
            if self._thread.is_alive():
                raise RuntimeError(
                    "peer-send thread failed to stop within 10s; thread "
                    "leaked (outbox stores and sockets still held)")

    # -- plumbing --------------------------------------------------------------
    def _acquire_slot(self) -> None:
        self.check_failed()
        t0 = time.perf_counter()
        while not self._slots.acquire(timeout=0.5):
            self.check_failed()
            self._check_abort()
        if self._stats is not None:
            self._stats.stall_seconds += time.perf_counter() - t0

    def _wait(self, ev: threading.Event) -> None:
        while not ev.wait(0.5):
            self.check_failed()
            self._check_abort()

    # -- transmit thread -------------------------------------------------------
    def _loop(self) -> None:
        while True:
            op = self._q.get()
            if op[0] == "quit":
                self._teardown()
                return
            try:
                t0 = time.perf_counter()
                busy = self._dispatch(op)
                if busy and self._stats is not None:
                    self._stats.send_seconds += time.perf_counter() - t0
            except (_Stop, RunAborted):
                self._teardown()
                return
            except BaseException as e:  # surfaced via check_failed()
                self._exc = e
                self._teardown()
                return

    def _dispatch(self, op) -> bool:
        kind = op[0]
        if kind == "begin":
            _, step, ev = op
            self._step = step
            self._store = self._make_store(step)
            self._stores[step] = self._store
            self._sent = [0] * self.n
            self._end_sent = [False] * self.n
            self._kill_frames = 0
            ev.set()
            return False
        if kind == "comb":
            _, dest, A, cnt, tag = op
            seg = self._store.append_combined(dest, A, cnt, tag=tag)
            self._transmit_seg(dest, seg)
            self._slots.release()
            return True
        if kind == "raw":
            _, dest, dp, msg, valid, tag = op
            seg = self._store.append_raw(dest, dp, msg, valid, tag=tag)
            if seg is not None:  # all-invalid chunks never become runs
                self._transmit_seg(dest, seg)
            self._slots.release()
            return True
        if kind == "end":
            _, ev = op
            self._store.save_index()  # outbox becomes a valid replay log
            for dest in range(self.n):
                self._ensure_conn(dest)
                self._send_end(dest)
            ev.set()
            return True
        if kind == "resync":
            _, dest = op
            conn = self._conns[dest]
            self._conns[dest] = None
            if conn is not None:
                try:
                    conn.close()
                except OSError:
                    pass
            if self._step is not None:
                self._ensure_conn(dest)
                if self._end_sent[dest]:
                    self._send_end(dest, resend=True)
            return True
        if kind == "drop":
            store = self._stores.pop(op[1], None)
            if store is not None:
                store.delete()
            return False
        raise RuntimeError(f"unknown sender op {kind!r}")

    def _teardown(self) -> None:
        for conn in self._conns:
            if conn is not None:
                try:
                    conn.close()
                except OSError:
                    pass
        for store in self._stores.values():
            try:
                store.close()
            except OSError:
                pass

    def _transmit_seg(self, dest: int, seg) -> None:
        """Frame one just-appended run and send it; run index == seq."""
        seq = self._sent[dest]
        self._sent[dest] += 1
        if self._conns[dest] is None:
            self._ensure_conn(dest)
            return  # the handshake replay just delivered runs[have:], incl. this one
        self._send_run(dest, seq, seg)

    def _send_run(self, dest: int, seq: int, seg) -> None:
        conn = self._conns[dest]
        if conn is None:
            return  # dead conn: the run waits in the outbox for resync
        parts = self._store.read_run(dest, seg)
        cnt = parts[2] if self._store.with_counts else None
        payload = encode_run(step=self._step, seq=seq, tag=seg.tag,
                             dp=parts[0], msg=parts[1], cnt=cnt,
                             compress=self._store.compress,
                             scheme=self._store.payload_scheme)
        self._maybe_kill(conn, payload)
        try:
            wire = send_frame(conn, K_RUN, payload)
        except OSError:
            self._kill_conn(dest, conn)
            return
        if self._stats is not None:
            self._stats.wire_bytes += wire
            self._stats.packets += 1
            self._stats.payload_bytes += sum(
                p.nbytes for p in parts if p is not None)

    def _send_end(self, dest: int, resend: bool = False) -> None:
        conn = self._conns[dest]
        if conn is None and not resend:
            # END must land: a receiver blocked on this source would hang
            self._ensure_conn(dest)
            conn = self._conns[dest]
        if conn is None:
            return
        try:
            wire = _send_json(conn, K_END,
                              dict(step=self._step, n_runs=self._sent[dest]))
            if self._stats is not None and not resend:
                self._stats.wire_bytes += wire
                self._stats.packets += 1
        except OSError:
            self._kill_conn(dest, conn)
            if not resend:
                self._ensure_conn(dest)
                self._send_end(dest)
                return
        self._end_sent[dest] = True

    def _kill_conn(self, dest: int, conn: socket.socket) -> None:
        if self._conns[dest] is conn:
            self._conns[dest] = None
        try:
            conn.close()
        except OSError:
            pass

    def _ensure_conn(self, dest: int) -> None:
        """Connect + HELLO/RESUME handshake + backlog replay. Retries with
        backoff until the destination is reachable (a respawning worker) or
        the run aborts — the outbox store makes the wait safe."""
        if self._conns[dest] is not None:
            return
        delay = self.RECONNECT_POLL
        while True:
            if self._closed:
                raise _Stop()
            self._check_abort()
            addr = self._addrs[dest]
            try:
                conn = socket.create_connection(addr, timeout=5.0)
            except OSError:
                time.sleep(delay)
                delay = min(delay * 2, self.RECONNECT_POLL_MAX)
                continue
            try:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                conn.settimeout(self.SEND_TIMEOUT)
                _send_json(conn, K_HELLO, dict(src=self.me, step=self._step))
                kind, payload = recv_frame(conn)
                if kind != K_RESUME:
                    raise FrameError(f"expected RESUME, got kind={kind}")
                reply = json.loads(payload)
            except (ConnectionError, OSError, ValueError):
                try:
                    conn.close()
                except OSError:
                    pass
                time.sleep(delay)
                delay = min(delay * 2, self.RECONNECT_POLL_MAX)
                continue
            break
        self._conns[dest] = conn
        if reply["step"] == self._step:
            have = int(reply["have"])
        elif reply["step"] > self._step:
            # receiver already past our step (it saw the commit; we have
            # not yet) — it needs nothing more from this step
            have = self._sent[dest]
        else:
            # receiver behind (respawned, or between steps): it holds
            # nothing of our current step yet
            have = 0
        for seq, seg in enumerate(self._store.runs(dest)[have:self._sent[dest]],
                                  start=have):
            self._send_run(dest, seq, seg)

    def _maybe_kill(self, conn: socket.socket, payload: bytes) -> None:
        """Fault-injection hook (tests only): after ``after_frames`` RUN
        frames of the target step, write the header plus HALF the payload
        and die by SIGKILL — a frame torn mid-transmission."""
        k = self._kill
        if k is None or int(k.get("step", -1)) != self._step:
            return
        self._kill_frames += 1
        if self._kill_frames <= int(k.get("after_frames", 0)):
            return
        hdr = _HEADER.pack(MAGIC, K_RUN, len(payload), zlib.crc32(payload))
        try:
            conn.sendall(hdr + payload[:max(1, len(payload) // 2)])
        except OSError:
            pass
        os.kill(os.getpid(), signal.SIGKILL)


# -- coordinator plane ---------------------------------------------------------

class CoordServer:
    """The launcher's side of the coordinator plane: one listener, one
    persistent connection per worker, the FileCoordinator surface
    (wait_arrivals / reduce_arrivals / publish_commit / abort / stale)
    backed by in-memory state fed by per-connection reader threads —
    commits and aborts are PUSHED to workers, so their barrier waits are
    event-driven instead of polled files."""

    def __init__(self, n_shards: int, *, heartbeat_timeout: float = 10.0,
                 host: str = "127.0.0.1"):
        self.n = int(n_shards)
        self.heartbeat_timeout = float(heartbeat_timeout)
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, 0))
        self._sock.listen(self.n + 8)
        self.addr = self._sock.getsockname()
        self._cv = threading.Condition()
        self._conns: dict[int, socket.socket] = {}
        self._send_lock = threading.Lock()
        self._addrs: dict[int, tuple] = {}  # shard -> data-plane addr
        self._seen: set[int] = set()
        self._beats: dict[int, tuple] = {}  # shard -> (seq, monotonic recv)
        self._arrivals: dict[int, dict[int, dict]] = {}
        self._commits: dict[int, dict] = {}
        self._last_commit: dict | None = None
        self._abort: str | None = None
        self._closed = False
        self._threads: list[threading.Thread] = []  # accept + serve threads

    def start(self) -> None:
        t = threading.Thread(target=self._accept_loop, name="coord-accept",
                             daemon=True)
        with self._cv:
            self._threads.append(t)
        t.start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name="coord-conn", daemon=True)
            with self._cv:
                # prune finished serve threads so reconnect churn does not
                # grow the join list unboundedly
                self._threads = [x for x in self._threads if x.is_alive()]
                self._threads.append(t)
            t.start()

    def _serve_conn(self, conn: socket.socket) -> None:
        shard = None
        try:
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            # pre-CHELLO the conn is untracked, so close() cannot unblock
            # this recv — bound it instead, then restore blocking once the
            # conn is registered in _conns (close() closes those)
            conn.settimeout(5.0)
            kind, payload = recv_frame(conn)
            conn.settimeout(None)
            if kind != K_CHELLO:
                raise FrameError(f"expected CHELLO, got kind={kind}")
            msg = json.loads(payload)
            shard = int(msg["shard"])
            addr = tuple(msg["addr"])
            with self._cv:
                respawn = shard in self._seen
                self._seen.add(shard)
                self._addrs[shard] = addr
                old = self._conns.get(shard)
                self._conns[shard] = conn
                self._cv.notify_all()
            if old is not None:
                _force_close(old)
            if respawn:
                self._broadcast(K_PEER_UPDATE,
                                dict(shard=shard, addr=list(addr)),
                                exclude=shard)
            with self._cv:  # first launch: PEERS only once everyone is in
                while (len(self._addrs) < self.n and self._abort is None
                       and not self._closed):
                    self._cv.wait(0.1)
                if self._closed:
                    return
                reply = dict(
                    addrs=[list(self._addrs[j]) for j in range(self.n)]
                    if len(self._addrs) == self.n else None,
                    last_commit=self._last_commit, abort=self._abort)
            with self._send_lock:
                _send_json(conn, K_PEERS, reply)
            while True:
                kind, payload = recv_frame(conn)
                msg = json.loads(payload)
                if kind == K_BEAT:
                    with self._cv:  # heartbeat_age reads under the same lock
                        self._beats[shard] = (msg.get("seq"),
                                              time.monotonic())
                elif kind == K_ARRIVE:
                    with self._cv:
                        step = int(msg["step"])
                        self._arrivals.setdefault(step, {})[shard] = msg
                        self._cv.notify_all()
        except (ConnectionError, OSError, ValueError, KeyError):
            pass
        finally:
            with self._cv:
                if shard is not None and self._conns.get(shard) is conn:
                    del self._conns[shard]
            try:
                conn.close()
            except OSError:
                pass

    def _broadcast(self, kind: int, obj, exclude: int | None = None) -> None:
        with self._cv:
            conns = {w: c for w, c in self._conns.items() if w != exclude}
        for conn in conns.values():
            try:
                with self._send_lock:
                    _send_json(conn, kind, obj)
            except OSError:
                pass  # a dead worker's conn; liveness handles it

    # -- FileCoordinator surface (launcher side) -------------------------------
    def arrivals(self, step: int) -> dict[int, dict]:
        with self._cv:
            return dict(self._arrivals.get(int(step), {}))

    def wait_arrivals(self, step: int, on_wait=None) -> dict[int, dict]:
        step = int(step)
        while True:
            with self._cv:
                got = dict(self._arrivals.get(step, {}))
                if len(got) == self.n:
                    return got
                if on_wait is None:
                    self._cv.wait(0.25)
                    continue
            on_wait(got)  # liveness hook runs outside the lock
            with self._cv:
                if len(self._arrivals.get(step, {})) != len(got):
                    continue
                self._cv.wait(0.05)

    # identical shard-ascending reduction — totals stay bit-identical
    reduce_arrivals = staticmethod(FileCoordinator.reduce_arrivals)

    def publish_commit(self, step: int, totals: dict, *, halt: bool,
                       ckpt_landed: bool) -> dict:
        rec = dict(step=int(step), halt=bool(halt),
                   ckpt_landed=bool(ckpt_landed), **totals)
        with self._cv:
            self._commits[int(step)] = rec
            self._last_commit = rec
        self._broadcast(K_COMMIT, rec)
        return rec

    def commit(self, step: int) -> dict | None:
        with self._cv:
            return self._commits.get(int(step))

    def abort(self, reason: str) -> None:
        with self._cv:
            self._abort = str(reason)
            self._cv.notify_all()
        self._broadcast(K_ABORT, dict(reason=str(reason)))

    def aborted(self) -> str | None:
        return self._abort

    def check_abort(self) -> None:
        if self._abort is not None:
            raise RunAborted(f"run aborted by coordinator: {self._abort}")

    def heartbeat_age(self, shard: int) -> float:
        with self._cv:
            beat = self._beats.get(int(shard))
        if beat is None:
            return float("inf")
        return time.monotonic() - beat[1]

    def stale(self, shard: int) -> bool:
        return self.heartbeat_age(shard) > self.heartbeat_timeout

    def gc_steps(self, before: int) -> None:
        with self._cv:
            for s in [s for s in self._arrivals if s < before]:
                del self._arrivals[s]
            for s in [s for s in self._commits if s < before]:
                del self._commits[s]

    def close(self) -> None:
        """Close the listener and every worker connection, wake PEERS
        waiters, then join accept + serve threads — raising if any leak."""
        self._closed = True
        _force_close(self._sock)
        with self._cv:
            conns = list(self._conns.values())
            threads = list(self._threads)
            self._cv.notify_all()  # release any serve thread in PEERS wait
        for conn in conns:
            _force_close(conn)
        leaked = []
        for t in threads:
            if t.ident is None:
                continue
            t.join(timeout=10.0)
            if t.is_alive():
                leaked.append(t.name)
        if leaked:
            raise RuntimeError(
                f"coordinator threads failed to stop within 10s: "
                f"{', '.join(leaked)}; threads leaked")


class CoordClient:
    """The worker's side: stdlib-only (it starts BEFORE the heavy jax
    import, exactly like the file heartbeat, so liveness covers import
    time), one socket, a reader thread that turns pushed COMMIT/ABORT/
    PEER_UPDATE frames into event-driven barrier wakeups, and a heartbeat
    thread whose sequence numbers feed the launcher's staleness judgement."""

    def __init__(self, addr, shard: int, *,
                 heartbeat_interval: float = 0.25):
        self.shard = int(shard)
        self.heartbeat_interval = float(heartbeat_interval)
        self._sock = socket.create_connection(tuple(addr), timeout=30.0)
        self._sock.settimeout(None)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._wlock = threading.Lock()
        self._cv = threading.Condition()
        self._commits: dict[int, dict] = {}
        self._peers: dict | None = None
        self._abort: str | None = None
        self._closed = False
        self._stop = threading.Event()
        self._hello = threading.Event()  # beats must not precede CHELLO
        self.on_peer_update = None  # set by the worker once the sender exists
        self._threads: list[threading.Thread] = []

    def _send(self, kind: int, obj) -> None:
        with self._wlock:
            _send_json(self._sock, kind, obj)

    def start(self) -> None:
        self._threads = [
            threading.Thread(target=self._reader, name="coord-read",
                             daemon=True),
            threading.Thread(target=self._beats, name="coord-beat",
                             daemon=True),
        ]
        for t in self._threads:
            t.start()

    def register(self, data_addr) -> list[tuple]:
        """CHELLO with our data-plane address; blocks for PEERS (all n
        registered). Returns the peer address table; any commit the run
        already published is seeded into the local commit cache so a
        respawned worker sees its recovery baseline immediately."""
        self._send(K_CHELLO, dict(shard=self.shard, addr=list(data_addr)))
        self._hello.set()  # heartbeats may flow now that CHELLO framed first
        with self._cv:
            while self._peers is None and self._abort is None:
                self._cv.wait(0.2)
            self.check_abort()
            peers = self._peers
        last = peers.get("last_commit")
        if last is not None:
            with self._cv:
                self._commits[int(last["step"])] = last
        return [tuple(a) for a in peers["addrs"]]

    def _reader(self) -> None:
        try:
            while True:
                kind, payload = recv_frame(self._sock)
                msg = json.loads(payload)
                if kind == K_COMMIT:
                    with self._cv:
                        self._commits[int(msg["step"])] = msg
                        self._cv.notify_all()
                elif kind == K_PEERS:
                    with self._cv:
                        if msg.get("abort"):
                            self._abort = msg["abort"]
                        self._peers = msg
                        self._cv.notify_all()
                elif kind == K_PEER_UPDATE:
                    cb = self.on_peer_update
                    if cb is not None:
                        cb(int(msg["shard"]), tuple(msg["addr"]))
                elif kind == K_ABORT:
                    with self._cv:
                        self._abort = msg["reason"]
                        self._cv.notify_all()
        except (ConnectionError, OSError, ValueError):
            with self._cv:
                if not self._closed:
                    # a vanished coordinator is a poison pill: no barrier
                    # will ever open again
                    self._abort = self._abort or "coordinator connection lost"
                self._cv.notify_all()

    def _beats(self) -> None:
        while not self._hello.is_set():
            if self._stop.wait(0.01):
                return
        seq = 0
        while not self._stop.is_set():
            seq += 1
            try:
                self._send(K_BEAT, dict(shard=self.shard, seq=seq))
            except OSError:
                return  # reader flags the abort
            self._stop.wait(self.heartbeat_interval)

    # -- FileCoordinator surface (worker side) ---------------------------------
    def arrive(self, step: int, shard: int, stats: dict) -> None:
        self._send(K_ARRIVE, dict(shard=int(shard), step=int(step), **stats))

    def wait_commit(self, step: int, shard: int) -> dict:
        """Event-driven: sleeps on the condition the reader notifies when
        the commit frame lands — no polling loop, no stat syscalls."""
        step = int(step)
        with self._cv:
            while True:
                rec = self._commits.get(step)
                if rec is not None:
                    return rec
                if self._abort is not None:
                    raise RunAborted(
                        f"run aborted by coordinator: {self._abort}")
                self._cv.wait(1.0)

    def commit(self, step: int) -> dict | None:
        with self._cv:
            return self._commits.get(int(step))

    def aborted(self) -> str | None:
        with self._cv:
            return self._abort

    def check_abort(self) -> None:
        reason = self.aborted()
        if reason is not None:
            raise RunAborted(f"run aborted by coordinator: {reason}")

    def close(self) -> None:
        """Stop the beat thread, unblock the reader by closing the socket,
        and join both — raising if either leaks. ``_closed`` is set under
        the condition so the reader's its-not-an-abort check can't race."""
        with self._cv:
            self._closed = True
        self._stop.set()
        _force_close(self._sock)
        leaked = [t.name for t in self._threads
                  if t.ident is not None
                  and (t.join(timeout=10.0) or t.is_alive())]
        if leaked:
            raise RuntimeError(
                f"coordinator client threads failed to stop within 10s: "
                f"{', '.join(leaked)}; threads leaked")


# -- link probes (planner calibration) -----------------------------------------

def probe_link_throughput(n_bytes: int = 8 << 20,
                          chunk: int = 256 << 10) -> float:
    """Measured per-link throughput (bytes/s) through the REAL frame path:
    a loopback TCP connection, framed+CRC'd chunks, a concurrent reader —
    so the number the planner consumes includes framing and checksum cost
    and the pipelining a live link gets (send overlaps receive), which the
    old disk-bandwidth proxy could not express."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    got = [0]

    def drain(conn):
        try:
            while got[0] < n_bytes:
                _, payload = recv_frame(conn)
                got[0] += len(payload)
        except ConnectionError:
            pass

    out = socket.create_connection(srv.getsockname())
    out.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    inn, _ = srv.accept()
    t = threading.Thread(target=drain, args=(inn,), daemon=True)
    t.start()
    blob = b"\xa5" * chunk
    t0 = time.perf_counter()
    sent = 0
    while sent < n_bytes:
        send_frame(out, K_RUN, blob)
        sent += chunk
    t.join(timeout=30.0)
    elapsed = max(time.perf_counter() - t0, 1e-9)
    drain_leaked = t.is_alive()
    for s in (out, inn, srv):
        try:
            s.close()
        except OSError:
            pass
    if drain_leaked:
        raise RuntimeError("link-probe drain thread failed to stop within "
                           "30s; thread leaked")
    return sent / elapsed


def probe_file_throughput(directory: str, n_bytes: int = 8 << 20,
                          chunk: int = 256 << 10) -> float:
    """The file-exchange baseline the socket transport replaces — the full
    round trip a delivered byte used to make (launch/procs.py's outbox/
    announce/inbox exchange): the sender writes the outbox run and fsyncs
    before the atomic announce rename (a crashed sender must not announce
    garbage), then the receiver reads the announced run, copies it into its
    own local inbox store, and reads it back for the digest.  Two writes,
    two reads and a durability barrier per delivered byte, where the socket
    path frames each byte exactly once."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, "probe.bin")
    inbox = os.path.join(directory, "probe-inbox.bin")
    marker = os.path.join(directory, "probe.ok")
    blob = b"\xa5" * chunk
    t0 = time.perf_counter()
    with open(path, "wb") as f:
        written = 0
        while written < n_bytes:
            f.write(blob)
            written += chunk
        f.flush()
        os.fsync(f.fileno())
    with open(marker + ".tmp", "w") as f:
        f.write("ok")
    os.replace(marker + ".tmp", marker)
    with open(path, "rb") as rd, open(inbox, "wb") as wr:
        while True:
            buf = rd.read(chunk)
            if not buf:
                break
            wr.write(buf)
    with open(inbox, "rb") as f:
        while f.read(chunk):
            pass
    elapsed = max(time.perf_counter() - t0, 1e-9)
    for p in (path, inbox, marker):
        os.unlink(p)
    return written / elapsed
