"""TCP socket transport for the multi-process launch (paper §4's network).

The file transport (PR 6) exchanges messages through shared-filesystem run
files, so "network" cost is really disk cost. This layer ships the SAME run
wire format — per-destination runs in the sender's canonical spill/combine
transform, received in ascending source order — over persistent per-peer
TCP connections, and multiplexes the coordinator protocol (barrier
arrivals, commits, heartbeats, abort) onto one coordinator connection per
worker instead of polled files. Equivalence is structural: every run still
round-trips through a :class:`MessageRunStore` on both ends (sender-side
per-step outbox = the replay log, receiver-side inbox = the digest source),
so the 8-algorithm matrix stays bit-identical to the file transport and the
threaded driver — float programs included.

Framing: ``>IBII`` header (magic, kind, payload length, CRC32 of payload),
then the payload. A short read or EOF mid-frame raises :class:`TornFrame`;
a CRC/magic mismatch raises :class:`FrameError`. Receivers treat both as
"this connection is dead": the torn frame is discarded and the reader waits
for the sender to reconnect — no partial run ever reaches an inbox.

Reconnect-with-resume: each sender keeps the step's outgoing runs in a
local outbox store (``shard-w/outbox/step-S``, deleted only after the
step's commit). A (re)connecting sender opens with ``HELLO{src, step}``;
the receiver replies ``RESUME{step, have, ended}`` where ``have`` counts
the runs it already appended from that source. The sender replays
``runs[have:]`` from its outbox — run index IS the sequence number, so
duplicates (``seq < have``) are discarded and the append order the digest
depends on is preserved across any number of connection drops, sender
respawns, or receiver respawns.

Deadlock-freedom of the ascending-source reader: worker w's reader drains
source 0 first while w's own sends proceed on the background transmit
thread, so source 0's transmissions always complete; induction on the
source index does the rest. TCP backpressure (bounded kernel buffers)
bounds the memory of not-yet-read sources.

Fault tolerance: every reconnect path (peer connect, coordinator
reconnect) runs under one :class:`repro.fault.RetryPolicy` — bounded
attempts, exponential backoff with deterministic jitter, an overall
deadline — degrading to a loud :class:`repro.fault.RetryExhausted` with a
structured summary instead of hanging forever or dying on first error.
The chaos layer's :class:`repro.fault.FaultInjector` hooks the three
transport sites (``net.send`` in the data-plane sender, ``net.recv`` in
the data-plane reader, ``coord.send`` in the coordinator client), and the
:class:`CoordServer` write-ahead-logs barrier commits, peer addresses and
aborts under ``wal_dir`` so a respawned coordinator process resumes the
run exactly where the dead one left it.
"""

from __future__ import annotations

import json
import os
import queue
import select
import socket
import struct
import threading
import time
import zlib

import numpy as np

import repro.fault as _fault
from repro.core.coordinator import FileCoordinator, RunAborted, atomic_write_json
from repro.fault import RetryExhausted, RetryPolicy
from repro.streams.codec import (
    decode_payload,
    decode_varint_delta,
    encode_payload,
    encode_varint_delta,
)

# Default tunables; each is a documented ``launch_opts`` knob (validated in
# core/config.py) threaded through the worker spec to the constructors below.
HANDSHAKE_TIMEOUT = 5.0  # bound on HELLO/CHELLO frames from a fresh accept
CONNECT_TIMEOUT = 5.0  # per-attempt TCP connect bound, data plane
SEND_TIMEOUT = 60.0  # data-plane sendall bound (a wedged receiver)
COORD_CONNECT_TIMEOUT = 10.0  # per-attempt TCP connect bound, coord plane

# -- framing -------------------------------------------------------------------

MAGIC = 0x47445052  # "GDPR"(aph-D): run-frame magic
_HEADER = struct.Struct(">IBII")  # magic, kind, payload nbytes, payload crc32
MAX_FRAME = 1 << 30  # sanity cap: a length beyond this is stream garbage

# data plane (worker <-> worker)
K_HELLO = 1  # sender handshake: {src, step}
K_RESUME = 2  # receiver reply: {step, have, ended}
K_RUN = 3  # one message run (json subheader + channel blobs)
K_END = 4  # sender finished the step toward this destination: {step, n_runs}
# coordinator plane (worker <-> launcher)
K_CHELLO = 10  # worker registration: {shard, addr}
K_PEERS = 11  # launcher reply: {addrs, last_commit, abort}
K_PEER_UPDATE = 12  # a shard respawned at a new address: {shard, addr}
K_BEAT = 13  # heartbeat: {shard, seq}
K_ARRIVE = 14  # barrier arrival: the full per-shard stats record
K_COMMIT = 15  # commit broadcast: the commit record
K_ABORT = 16  # poison pill broadcast: {reason}


class TornFrame(ConnectionError):
    """EOF or short read mid-frame: the peer died with a frame in flight.
    The partial bytes are discarded — never fed to an inbox."""


class FrameError(ConnectionError):
    """Magic or CRC mismatch: the stream is corrupt past recovery; the
    connection is dropped and the resume handshake re-delivers."""


def _recv_exact(conn: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = conn.recv(n - len(buf))
        if not chunk:
            raise TornFrame(f"connection closed after {len(buf)}/{n} bytes")
        buf += chunk
    return bytes(buf)


def send_frame(conn: socket.socket, kind: int, payload: bytes) -> int:
    """One length-prefixed CRC'd frame; returns bytes put on the wire."""
    hdr = _HEADER.pack(MAGIC, kind, len(payload), zlib.crc32(payload))
    conn.sendall(hdr + payload)
    return _HEADER.size + len(payload)


def recv_frame(conn: socket.socket) -> tuple[int, bytes]:
    """The inverse: blocks for one complete frame, verifies magic + CRC."""
    magic, kind, length, crc = _HEADER.unpack(_recv_exact(conn, _HEADER.size))
    if magic != MAGIC or length > MAX_FRAME:
        raise FrameError(f"bad frame header (magic={magic:#x} len={length})")
    payload = _recv_exact(conn, length)
    if zlib.crc32(payload) != crc:
        raise FrameError("frame CRC mismatch")
    return kind, payload


def _send_json(conn: socket.socket, kind: int, obj) -> int:
    return send_frame(conn, kind, json.dumps(obj).encode())


# -- run frame codec -----------------------------------------------------------

_RUN_HLEN = struct.Struct(">I")


def encode_run(*, step: int, seq: int, tag: int, dp: np.ndarray,
               msg: np.ndarray, cnt: np.ndarray | None,
               compress: bool = False, scheme: str | None = None) -> bytes:
    """One run -> one RUN frame payload.

    The channel blobs reuse the store codecs (varint-delta on the sorted
    destination column, the payload codec on the value column) so the wire
    carries the same compressed representation as the disk exchange it
    replaces. ``cnt`` (combine counts) stays raw — exactness is its job.
    """
    dp = np.ascontiguousarray(dp, np.int32)
    n = int(dp.size)
    dp_b = encode_varint_delta(dp) if (compress and n) else dp.tobytes()
    marr = np.ascontiguousarray(msg)
    msg_b = encode_payload(marr, scheme) if (scheme and n) else marr.tobytes()
    cnt_b = b""
    if cnt is not None:
        cnt_b = np.ascontiguousarray(cnt, np.int32).tobytes()
    hdr = json.dumps(dict(
        step=int(step), seq=int(seq), tag=int(tag), n=n,
        dp_nb=len(dp_b), msg_nb=len(msg_b), cnt_nb=len(cnt_b),
        dp_enc=bool(compress and n),
        scheme=scheme if (scheme and n) else None,
        msg_dtype=marr.dtype.name, cnt=cnt is not None,
    )).encode()
    return b"".join((_RUN_HLEN.pack(len(hdr)), hdr, dp_b, msg_b, cnt_b))


def decode_run(payload: bytes):
    """Inverse of :func:`encode_run` -> ``(hdr, dp, msg, cnt)``."""
    (hlen,) = _RUN_HLEN.unpack_from(payload)
    hdr = json.loads(payload[_RUN_HLEN.size:_RUN_HLEN.size + hlen])
    off = _RUN_HLEN.size + hlen
    n = hdr["n"]
    dp_b = payload[off:off + hdr["dp_nb"]]
    off += hdr["dp_nb"]
    msg_b = payload[off:off + hdr["msg_nb"]]
    off += hdr["msg_nb"]
    cnt_b = payload[off:off + hdr["cnt_nb"]]
    if hdr["dp_enc"]:
        dp = np.asarray(decode_varint_delta(dp_b), np.int32)
    else:
        dp = np.frombuffer(dp_b, np.int32)
    dtype = np.dtype(hdr["msg_dtype"])
    if hdr["scheme"]:
        msg = np.asarray(decode_payload(msg_b, dtype, n, hdr["scheme"]))
    else:
        msg = np.frombuffer(msg_b, dtype)
    cnt = np.frombuffer(cnt_b, np.int32) if hdr["cnt"] else None
    return hdr, dp, msg, cnt


def _force_close(sock: socket.socket) -> None:
    """Close a socket another thread may be blocked on. ``close()`` alone
    does NOT interrupt a thread parked in ``accept()`` or ``recv()`` on
    Linux — it stays in the syscall until traffic arrives, which is never
    at teardown; ``shutdown()`` forces accept to return EINVAL and recv to
    return EOF first. Every cross-thread close must go through here, or
    the join-with-timeout discipline in the ``close()`` methods turns a
    silently parked thread into a hard RuntimeError."""
    try:
        sock.shutdown(socket.SHUT_RDWR)
    except OSError:
        pass
    try:
        sock.close()
    except OSError:
        pass


# -- data plane: receiver ------------------------------------------------------

class PeerServer:
    """One per worker: accepts the n persistent inbound connections (one
    per source, self included via loopback) and hands complete runs to the
    step's reader in ascending source order.

    The accept thread performs the HELLO/RESUME handshake and swaps the
    per-source connection slot; :meth:`read_source` owns all data-frame
    reading, so runs from source j are appended exactly in sequence order —
    the append order the combiner-less merge's cursor tie-break depends on.
    """

    def __init__(self, n_shards: int, start_step: int,
                 host: str = "127.0.0.1", *,
                 handshake_timeout: float = HANDSHAKE_TIMEOUT):
        self.n = int(n_shards)
        self.handshake_timeout = float(handshake_timeout)
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, 0))
        self._sock.listen(self.n + 8)
        self.addr = self._sock.getsockname()
        self._cv = threading.Condition()
        self._conns: list[socket.socket | None] = [None] * self.n
        self._step = int(start_step)
        self._have = [0] * self.n  # runs appended per source, this step
        self._ended = [False] * self.n
        self._closed = False
        self._thread: threading.Thread | None = None

    def start(self) -> None:
        self._thread = threading.Thread(target=self._accept_loop,
                                        name="peer-accept", daemon=True)
        self._thread.start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return  # listener closed
            try:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                # a wedged peer must not pin the accept loop past close():
                # bound the handshake, then restore blocking for data frames
                conn.settimeout(self.handshake_timeout)
                kind, payload = recv_frame(conn)
                if kind != K_HELLO:
                    raise FrameError(f"expected HELLO, got kind={kind}")
                src = int(json.loads(payload)["src"])
                with self._cv:
                    reply = dict(step=self._step, have=self._have[src],
                                 ended=self._ended[src])
                    old, self._conns[src] = self._conns[src], conn
                    self._cv.notify_all()
                _send_json(conn, K_RESUME, reply)
                conn.settimeout(None)
                if old is not None:
                    _force_close(old)
            except (ConnectionError, OSError, KeyError, ValueError):
                try:
                    conn.close()
                except OSError:
                    pass

    def begin_step(self, step: int) -> None:
        with self._cv:
            self._step = int(step)
            self._have = [0] * self.n
            self._ended = [False] * self.n

    def read_source(self, step: int, src: int, on_run, check_abort) -> int:
        """Drain source ``src`` for ``step``: calls ``on_run(hdr, dp, msg,
        cnt)`` per fresh run, returns the run count once END arrives.

        Stale frames (an earlier step, replayed after a commit the sender
        had not seen) and duplicates (``seq < have``, replayed by the
        resume handshake) are discarded; a torn/corrupt connection is
        dropped and the loop waits for the sender to reconnect."""
        while True:
            with self._cv:
                conn = self._conns[src]
            if conn is None:
                check_abort()
                with self._cv:
                    if self._conns[src] is None:
                        self._cv.wait(0.1)
                continue
            try:
                ready, _, _ = select.select([conn], [], [], 0.25)
                if not ready:
                    check_abort()
                    continue
                inj = _fault.active()
                if inj is not None:  # chaos: drop/reset/delay this receive
                    inj.net_recv(conn, step=step, src=src)
                kind, payload = recv_frame(conn)
            except (ConnectionError, OSError):
                self._drop(src, conn)
                check_abort()
                continue
            if kind == K_RUN:
                hdr, dp, msg, cnt = decode_run(payload)
                if hdr["step"] < step:
                    continue  # pre-reconnect leftovers of a committed step
                if hdr["step"] > step:
                    raise RuntimeError(
                        f"source {src} ran ahead: frame step {hdr['step']} "
                        f"while reading step {step}")
                if hdr["seq"] < self._have[src]:
                    continue  # resume-handshake replay duplicate
                if hdr["seq"] > self._have[src]:
                    raise RuntimeError(
                        f"sequence gap from source {src}: got {hdr['seq']}, "
                        f"expected {self._have[src]}")
                on_run(hdr, dp, msg, cnt)
                with self._cv:
                    self._have[src] += 1
            elif kind == K_END:
                if json.loads(payload)["step"] < step:
                    continue
                with self._cv:
                    self._ended[src] = True
                return self._have[src]
            else:
                raise RuntimeError(f"unexpected data frame kind={kind}")

    def _drop(self, src: int, conn: socket.socket) -> None:
        with self._cv:
            if self._conns[src] is conn:
                self._conns[src] = None
        try:
            conn.close()
        except OSError:
            pass

    def close(self) -> None:
        """Close the listener and every source connection, then join the
        accept thread — raising if it leaks (the ChannelSender contract:
        a thread we cannot stop keeps sockets open and makes this worker's
        inbox unsafe to reuse, so it must be an error, not a warning)."""
        self._closed = True
        _force_close(self._sock)
        with self._cv:  # the accept thread swaps slots under this lock
            conns = list(self._conns)
        for conn in conns:
            if conn is not None:
                _force_close(conn)
        if self._thread is not None and self._thread.ident is not None:
            self._thread.join(timeout=10.0)
            if self._thread.is_alive():
                raise RuntimeError(
                    "peer-accept thread failed to stop within 10s; "
                    "thread leaked")


# -- data plane: sender --------------------------------------------------------

class _Stop(Exception):
    """Internal: the sender was closed mid-wait."""


class PeerSender:
    """One per worker: a single transmit thread drains a FIFO op queue so
    runs leave in exactly the fold's emission order, overlapping the fold
    (§4's U_s ∥ U_c) the same way the threaded channel's sender does.

    Every run is appended to the step's local outbox store FIRST (the
    canonical spill/combine transform — same bytes as the file exchange)
    and the framed wire bytes are read back from it, so what is replayable
    is exactly what was sent. ``inflight`` bounds the queue the way the
    channel's sender does: the compute thread blocks (stall-accounted)
    when the network falls behind. Reconnects run under ``retry`` (a
    :class:`RetryPolicy`): exhausting the budget surfaces a
    :class:`RetryExhausted` through :meth:`check_failed` instead of
    waiting on an unreachable peer forever.
    """

    # GIL-atomic by review: _exc is write-once (transmit thread) and only
    # read after it is set; _stats scalars are monotonic stall/byte
    # counters — a torn read is a stale report, never a control decision
    _LOCKED_FIELDS = frozenset({"_exc", "_stats"})

    def __init__(self, me: int, n_shards: int, make_store, *,
                 inflight: int = 4, stats=None, check_abort=None,
                 connect_timeout: float = CONNECT_TIMEOUT,
                 send_timeout: float = SEND_TIMEOUT,
                 retry: RetryPolicy | None = None):
        self.me = int(me)
        self.n = int(n_shards)
        self._make_store = make_store  # step -> fresh MessageRunStore
        self._stats = stats
        self._check_abort = check_abort or (lambda: None)
        self.connect_timeout = float(connect_timeout)
        self.send_timeout = float(send_timeout)
        self._retry = retry if retry is not None else RetryPolicy()
        self._addrs: list[tuple | None] = [None] * self.n
        self._conns: list[socket.socket | None] = [None] * self.n
        self._q: queue.Queue = queue.Queue()
        self._slots = threading.BoundedSemaphore(max(1, int(inflight)))
        self._sent = [0] * self.n  # runs appended (== next seq) per dest
        self._end_sent = [False] * self.n
        # per-dest consecutive send-failure episode: (episode t0, count).
        # Transmit-thread confined.
        self._send_fail: dict[int, tuple[float, int]] = {}
        self._step: int | None = None
        self._store = None
        self._stores: dict[int, object] = {}  # kept until the step commits
        self._exc: BaseException | None = None
        self._closed = False
        self._thread = threading.Thread(target=self._loop, name="peer-send",
                                        daemon=True)

    # -- compute-thread surface ----------------------------------------------
    def set_addrs(self, addrs) -> None:
        self._addrs = [tuple(a) for a in addrs]

    def start(self) -> None:
        self._thread.start()

    def update_addr(self, shard: int, addr) -> None:
        """PEER_UPDATE arrived: shard respawned at a new address. The
        transmit thread reconnects and the RESUME handshake replays the
        outbox backlog."""
        self._addrs[int(shard)] = tuple(addr)
        self._q.put(("resync", int(shard)))

    def begin_step(self, step: int) -> None:
        """Synchronous: returns once the transmit thread swapped in the
        step's fresh outbox store (all prior-step ops drained first)."""
        ev = threading.Event()
        self._q.put(("begin", int(step), ev))
        self._wait(ev)

    def send_combined(self, dest: int, A, cnt, tag: int) -> None:
        self._acquire_slot()
        self._q.put(("comb", int(dest), A, cnt, int(tag)))

    def send_raw(self, dest: int, dp, msg, valid, tag: int) -> None:
        self._acquire_slot()
        self._q.put(("raw", int(dest), dp, msg, valid, int(tag)))

    def end_step(self) -> None:
        """Queue the END fan-out: ensures every destination's backlog is
        fully delivered (reconnecting + replaying as needed) before END."""
        ev = threading.Event()
        self._q.put(("end", ev))
        self._wait(ev)

    def finish_step(self, step: int) -> None:
        """The step committed: every receiver has everything, the outbox
        log is dead weight — delete it."""
        self._q.put(("drop", int(step)))

    def check_failed(self) -> None:
        if self._exc is not None:
            raise RuntimeError("socket sender failed") from self._exc

    def close(self) -> None:
        """Stop and JOIN the transmit thread, raising if it leaks. The quit
        op tears down connections and outbox stores from inside the thread
        (its own teardown path); ``_closed`` breaks any reconnect wait."""
        self._closed = True
        self._q.put(("quit",))
        if self._thread.ident is not None:
            self._thread.join(timeout=10.0)
            if self._thread.is_alive():
                raise RuntimeError(
                    "peer-send thread failed to stop within 10s; thread "
                    "leaked (outbox stores and sockets still held)")

    # -- plumbing --------------------------------------------------------------
    def _acquire_slot(self) -> None:
        self.check_failed()
        t0 = time.perf_counter()
        while not self._slots.acquire(timeout=0.5):
            self.check_failed()
            self._check_abort()
        if self._stats is not None:
            self._stats.stall_seconds += time.perf_counter() - t0

    def _wait(self, ev: threading.Event) -> None:
        while not ev.wait(0.5):
            self.check_failed()
            self._check_abort()

    # -- transmit thread -------------------------------------------------------
    def _loop(self) -> None:
        while True:
            op = self._q.get()
            if op[0] == "quit":
                self._teardown()
                return
            try:
                t0 = time.perf_counter()
                busy = self._dispatch(op)
                if busy and self._stats is not None:
                    self._stats.send_seconds += time.perf_counter() - t0
            except (_Stop, RunAborted):
                self._teardown()
                return
            except BaseException as e:  # surfaced via check_failed()
                self._exc = e
                self._teardown()
                return

    def _dispatch(self, op) -> bool:
        kind = op[0]
        if kind == "begin":
            _, step, ev = op
            self._step = step
            self._store = self._make_store(step)
            self._stores[step] = self._store
            self._sent = [0] * self.n
            self._end_sent = [False] * self.n
            ev.set()
            return False
        if kind == "comb":
            _, dest, A, cnt, tag = op
            seg = self._store.append_combined(dest, A, cnt, tag=tag)
            self._transmit_seg(dest, seg)
            self._slots.release()
            return True
        if kind == "raw":
            _, dest, dp, msg, valid, tag = op
            seg = self._store.append_raw(dest, dp, msg, valid, tag=tag)
            if seg is not None:  # all-invalid chunks never become runs
                self._transmit_seg(dest, seg)
            self._slots.release()
            return True
        if kind == "end":
            _, ev = op
            self._store.save_index()  # outbox becomes a valid replay log
            for dest in range(self.n):
                self._ensure_conn(dest)
                self._send_end(dest)
            ev.set()
            return True
        if kind == "resync":
            _, dest = op
            conn = self._conns[dest]
            self._conns[dest] = None
            if conn is not None:
                try:
                    conn.close()
                except OSError:
                    pass
            if self._step is not None:
                self._ensure_conn(dest)
                if self._end_sent[dest]:
                    self._send_end(dest, resend=True)
            return True
        if kind == "drop":
            store = self._stores.pop(op[1], None)
            if store is not None:
                store.delete()
            return False
        raise RuntimeError(f"unknown sender op {kind!r}")

    def _teardown(self) -> None:
        for conn in self._conns:
            if conn is not None:
                try:
                    conn.close()
                except OSError:
                    pass
        for store in self._stores.values():
            try:
                store.close()
            except OSError:
                pass

    def _transmit_seg(self, dest: int, seg) -> None:
        """Frame one just-appended run and send it; run index == seq."""
        seq = self._sent[dest]
        self._sent[dest] += 1
        if self._conns[dest] is None:
            self._ensure_conn(dest)
            return  # the handshake replay just delivered runs[have:], incl. this one
        self._send_run(dest, seq, seg)

    def _send_run(self, dest: int, seq: int, seg) -> None:
        conn = self._conns[dest]
        if conn is None:
            return  # dead conn: the run waits in the outbox for resync
        parts = self._store.read_run(dest, seg)
        cnt = parts[2] if self._store.with_counts else None
        payload = encode_run(step=self._step, seq=seq, tag=seg.tag,
                             dp=parts[0], msg=parts[1], cnt=cnt,
                             compress=self._store.compress,
                             scheme=self._store.payload_scheme)
        try:
            inj = _fault.active()
            if inj is not None:  # chaos: torn_kill/drop/reset/delay this frame
                hdr = _HEADER.pack(MAGIC, K_RUN, len(payload),
                                   zlib.crc32(payload))
                inj.net_send(conn, hdr, payload, step=self._step, dest=dest)
            wire = send_frame(conn, K_RUN, payload)
        except OSError as e:
            self._kill_conn(dest, conn)
            self._note_send_failure(dest, e)
            return
        self._send_fail.pop(dest, None)
        if self._stats is not None:
            self._stats.wire_bytes += wire
            self._stats.packets += 1
            self._stats.payload_bytes += sum(
                p.nbytes for p in parts if p is not None)

    def _send_end(self, dest: int, resend: bool = False) -> None:
        while True:
            conn = self._conns[dest]
            if conn is None and not resend:
                # END must land: a receiver blocked on this source would hang
                self._ensure_conn(dest)
                conn = self._conns[dest]
                if conn is None:
                    # the handshake replay itself failed (and noted the
                    # failure): giving up here would let the step "finish"
                    # with runs undelivered and the receiver parked forever
                    continue
            if conn is None:
                return
            try:
                wire = _send_json(
                    conn, K_END,
                    dict(step=self._step, n_runs=self._sent[dest]))
                if self._stats is not None and not resend:
                    self._stats.wire_bytes += wire
                    self._stats.packets += 1
            except OSError as e:
                self._kill_conn(dest, conn)
                self._note_send_failure(dest, e)
                if not resend:
                    continue  # reconnect (budget-bounded) and retry END
            else:
                self._send_fail.pop(dest, None)
            self._end_sent[dest] = True
            return

    def _kill_conn(self, dest: int, conn: socket.socket) -> None:
        if self._conns[dest] is conn:
            self._conns[dest] = None
        try:
            conn.close()
        except OSError:
            pass

    def _note_send_failure(self, dest: int, err: OSError) -> None:
        """Bound the send-failure EPISODE. A peer that keeps accepting
        connections but never takes a frame would otherwise livelock the
        reconnect->replay->fail cycle forever: every successful connect
        resets ``_ensure_conn``'s retry episode, so the connect-path
        budget never accumulates. Sends to a dest that have failed
        consecutively past the same policy's attempt/deadline budget
        surface the same loud :class:`RetryExhausted`; any delivered
        frame resets the episode."""
        site = f"peer-send:{self.me}->{dest}"
        t0, n = self._send_fail.get(dest, (time.monotonic(), 0))
        n += 1
        self._send_fail[dest] = (t0, n)
        elapsed = time.monotonic() - t0
        if (self._retry.max_attempts and n >= self._retry.max_attempts) \
                or elapsed > self._retry.deadline:
            raise RetryExhausted(site, self._retry, err,
                                 attempts=n, elapsed=elapsed)
        # back off before the caller's next attempt — sliced so close()
        # never waits behind a long sleep
        remaining = self._retry.delay_for(site, n)
        while remaining > 0 and not self._closed:
            step = min(remaining, 0.25)
            time.sleep(step)
            remaining -= step

    def _ensure_conn(self, dest: int) -> None:
        """Connect + HELLO/RESUME handshake + backlog replay. Retries under
        the :class:`RetryPolicy` while the destination is unreachable (a
        respawning worker) — the outbox store makes the wait safe — and
        raises :class:`RetryExhausted` when the budget runs out, so an
        unreachable peer becomes a loud structured failure, not a hang."""
        if self._conns[dest] is not None:
            return
        site = f"peer-connect:{self.me}->{dest}"
        stopped = False
        last: BaseException | None = None
        attempts = 0
        t0 = time.monotonic()

        def _stop() -> bool:
            nonlocal stopped
            if self._closed:
                stopped = True
                return True
            self._check_abort()  # RunAborted propagates through the generator
            return False

        for attempt in self._retry.attempts(site, should_stop=_stop):
            attempts = attempt
            if self._closed:
                raise _Stop()
            self._check_abort()
            addr = self._addrs[dest]
            try:
                conn = socket.create_connection(addr,
                                                timeout=self.connect_timeout)
            except OSError as e:
                last = e
                continue
            try:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                conn.settimeout(self.send_timeout)
                _send_json(conn, K_HELLO, dict(src=self.me, step=self._step))
                kind, payload = recv_frame(conn)
                if kind != K_RESUME:
                    raise FrameError(f"expected RESUME, got kind={kind}")
                reply = json.loads(payload)
            except (ConnectionError, OSError, ValueError) as e:
                last = e
                try:
                    conn.close()
                except OSError:
                    pass
                continue
            break
        else:
            if stopped or self._closed:
                raise _Stop()
            raise RetryExhausted(site, self._retry, last, attempts=attempts,
                                 elapsed=time.monotonic() - t0)
        self._conns[dest] = conn
        if reply["step"] == self._step:
            have = int(reply["have"])
        elif reply["step"] > self._step:
            # receiver already past our step (it saw the commit; we have
            # not yet) — it needs nothing more from this step
            have = self._sent[dest]
        else:
            # receiver behind (respawned, or between steps): it holds
            # nothing of our current step yet
            have = 0
        for seq, seg in enumerate(self._store.runs(dest)[have:self._sent[dest]],
                                  start=have):
            self._send_run(dest, seq, seg)


# -- coordinator plane ---------------------------------------------------------

class CoordServer:
    """The coordinator's side of the coordinator plane: one listener, one
    persistent connection per worker, the FileCoordinator surface
    (wait_arrivals / reduce_arrivals / publish_commit / abort / stale)
    backed by in-memory state fed by per-connection reader threads —
    commits and aborts are PUSHED to workers, so their barrier waits are
    event-driven instead of polled files.

    With ``wal_dir`` set, barrier commits, the peer address table, and any
    abort are write-ahead-logged (the tmp→fsync→replace idiom) BEFORE they
    take effect in memory, and a fresh server restores all three at
    construction — so a SIGKILLed coordinator process can be respawned and
    the run resumes from the last committed superstep instead of dying
    with it. A restarted server also grants every not-yet-reconnected
    worker a boot grace period: ``stale()`` only condemns a never-seen
    shard once ``heartbeat_timeout + boot_grace`` has elapsed since this
    server booted, so live workers mid-reconnect are not false-killed.
    """

    def __init__(self, n_shards: int, *, heartbeat_timeout: float = 10.0,
                 host: str = "127.0.0.1",
                 handshake_timeout: float = HANDSHAKE_TIMEOUT,
                 wal_dir: str | None = None,
                 boot_grace: float | None = None):
        self.n = int(n_shards)
        self.heartbeat_timeout = float(heartbeat_timeout)
        self.handshake_timeout = float(handshake_timeout)
        self.boot_grace = (float(boot_grace) if boot_grace is not None
                           else self.heartbeat_timeout)
        self.wal_dir = wal_dir
        self._boot = time.monotonic()
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, 0))
        self._sock.listen(self.n + 8)
        self.addr = self._sock.getsockname()
        self._cv = threading.Condition()
        self._conns: dict[int, socket.socket] = {}
        self._send_lock = threading.Lock()
        self._addrs: dict[int, tuple] = {}  # shard -> data-plane addr
        self._seen: set[int] = set()
        self._beats: dict[int, tuple] = {}  # shard -> (seq, monotonic recv)
        self._grace: dict[int, float] = {}  # shard -> monotonic stale waiver
        self._arrivals: dict[int, dict[int, dict]] = {}
        self._commits: dict[int, dict] = {}
        self._last_commit: dict | None = None
        self._abort: str | None = None
        self._closed = False
        self._threads: list[threading.Thread] = []  # accept + serve threads
        if wal_dir:
            os.makedirs(wal_dir, exist_ok=True)
            self._restore_wal()

    def _restore_wal(self) -> None:
        """Reload commits, peer addresses and any abort a predecessor
        coordinator logged. Every WAL record was published atomically, so
        a file either parses or does not exist — but a half-written
        leftover from a dead tmp is still skipped defensively."""
        for name in sorted(os.listdir(self.wal_dir)):
            if not (name.startswith("commit-") and name.endswith(".json")):
                continue
            try:
                with open(os.path.join(self.wal_dir, name)) as f:
                    rec = json.load(f)
                self._commits[int(rec["step"])] = rec
                self._last_commit = rec
            except (OSError, ValueError, KeyError):
                continue
        try:
            with open(os.path.join(self.wal_dir, "addrs.json")) as f:
                addrs = json.load(f)
            self._addrs = {int(w): tuple(a) for w, a in addrs.items()}
            # every restored shard counts as seen: its re-CHELLO is a
            # respawn, so peers get a PEER_UPDATE even if its data-plane
            # address survived the coordinator outage unchanged
            self._seen = set(self._addrs)
        except (OSError, ValueError):
            pass
        try:
            with open(os.path.join(self.wal_dir, "abort.json")) as f:
                self._abort = str(json.load(f)["reason"])
        except (OSError, ValueError, KeyError):
            pass

    def start(self) -> None:
        t = threading.Thread(target=self._accept_loop, name="coord-accept",
                             daemon=True)
        with self._cv:
            self._threads.append(t)
        t.start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 name="coord-conn", daemon=True)
            with self._cv:
                # prune finished serve threads so reconnect churn does not
                # grow the join list unboundedly
                self._threads = [x for x in self._threads if x.is_alive()]
                self._threads.append(t)
            t.start()

    def _serve_conn(self, conn: socket.socket) -> None:
        shard = None
        try:
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            # pre-CHELLO the conn is untracked, so close() cannot unblock
            # this recv — bound it instead, then restore blocking once the
            # conn is registered in _conns (close() closes those)
            conn.settimeout(self.handshake_timeout)
            kind, payload = recv_frame(conn)
            conn.settimeout(None)
            if kind != K_CHELLO:
                raise FrameError(f"expected CHELLO, got kind={kind}")
            msg = json.loads(payload)
            shard = int(msg["shard"])
            addr = tuple(msg["addr"])
            with self._cv:
                respawn = shard in self._seen
                self._seen.add(shard)
                self._addrs[shard] = addr
                old = self._conns.get(shard)
                self._conns[shard] = conn
                self._cv.notify_all()
                if self.wal_dir:
                    snap = {str(w): list(a) for w, a in self._addrs.items()}
            if self.wal_dir:
                atomic_write_json(os.path.join(self.wal_dir, "addrs.json"),
                                  snap)
            if old is not None:
                _force_close(old)
            if respawn:
                self._broadcast(K_PEER_UPDATE,
                                dict(shard=shard, addr=list(addr)),
                                exclude=shard)
            with self._cv:  # first launch: PEERS only once everyone is in
                while (len(self._addrs) < self.n and self._abort is None
                       and not self._closed):
                    self._cv.wait(0.1)
                if self._closed:
                    return
                reply = dict(
                    addrs=[list(self._addrs[j]) for j in range(self.n)]
                    if len(self._addrs) == self.n else None,
                    last_commit=self._last_commit, abort=self._abort)
            with self._send_lock:
                _send_json(conn, K_PEERS, reply)
            while True:
                kind, payload = recv_frame(conn)
                msg = json.loads(payload)
                if kind == K_BEAT:
                    with self._cv:  # heartbeat_age reads under the same lock
                        self._beats[shard] = (msg.get("seq"),
                                              time.monotonic())
                elif kind == K_ARRIVE:
                    with self._cv:
                        step = int(msg["step"])
                        self._arrivals.setdefault(step, {})[shard] = msg
                        self._cv.notify_all()
        except (ConnectionError, OSError, ValueError, KeyError):
            pass
        finally:
            with self._cv:
                if shard is not None and self._conns.get(shard) is conn:
                    del self._conns[shard]
            try:
                conn.close()
            except OSError:
                pass

    def _broadcast(self, kind: int, obj, exclude: int | None = None) -> None:
        with self._cv:
            conns = {w: c for w, c in self._conns.items() if w != exclude}
        for conn in conns.values():
            try:
                with self._send_lock:
                    _send_json(conn, kind, obj)
            except OSError:
                pass  # a dead worker's conn; liveness handles it

    # -- FileCoordinator surface (launcher side) -------------------------------
    def arrivals(self, step: int) -> dict[int, dict]:
        with self._cv:
            return dict(self._arrivals.get(int(step), {}))

    def wait_arrivals(self, step: int, on_wait=None) -> dict[int, dict]:
        step = int(step)
        while True:
            with self._cv:
                got = dict(self._arrivals.get(step, {}))
                if len(got) == self.n:
                    return got
                if on_wait is None:
                    self._cv.wait(0.25)
                    continue
            on_wait(got)  # liveness hook runs outside the lock
            with self._cv:
                if len(self._arrivals.get(step, {})) != len(got):
                    continue
                self._cv.wait(0.05)

    # identical shard-ascending reduction — totals stay bit-identical
    reduce_arrivals = staticmethod(FileCoordinator.reduce_arrivals)

    def publish_commit(self, step: int, totals: dict, *, halt: bool,
                       ckpt_landed: bool, extra: dict | None = None) -> dict:
        """Log the commit record (WAL first — a successor coordinator must
        never un-commit a barrier workers already advanced past), then
        publish it in memory and push it to every worker. ``extra`` rides
        extra launcher state (e.g. per-step seconds) into the record."""
        rec = dict(step=int(step), halt=bool(halt),
                   ckpt_landed=bool(ckpt_landed), **totals)
        if extra:
            rec.update(extra)
        if self.wal_dir:
            atomic_write_json(
                os.path.join(self.wal_dir, f"commit-{int(step):06d}.json"),
                rec)
        with self._cv:
            self._commits[int(step)] = rec
            self._last_commit = rec
        self._broadcast(K_COMMIT, rec)
        return rec

    def commit(self, step: int) -> dict | None:
        with self._cv:
            return self._commits.get(int(step))

    def last_commit_step(self) -> int:
        """The newest committed superstep (WAL-restored ones included), or
        -1 before any barrier has committed."""
        with self._cv:
            return int(self._last_commit["step"]) if self._last_commit else -1

    def abort(self, reason: str) -> None:
        if self.wal_dir:
            atomic_write_json(os.path.join(self.wal_dir, "abort.json"),
                              dict(reason=str(reason)))
        with self._cv:
            self._abort = str(reason)
            self._cv.notify_all()
        self._broadcast(K_ABORT, dict(reason=str(reason)))

    def aborted(self) -> str | None:
        return self._abort

    def check_abort(self) -> None:
        if self._abort is not None:
            raise RunAborted(f"run aborted by coordinator: {self._abort}")

    def heartbeat_age(self, shard: int) -> float:
        with self._cv:
            beat = self._beats.get(int(shard))
        if beat is None:
            return float("inf")
        return time.monotonic() - beat[1]

    def grant_grace(self, shard: int, seconds: float) -> None:
        """Waive staleness for ``shard`` until ``seconds`` from now — the
        liveness loop grants this to a worker it just respawned (or that
        must reconnect after a coordinator restart) so import/recovery
        time is not judged as heartbeat silence."""
        until = time.monotonic() + float(seconds)
        with self._cv:
            self._grace[int(shard)] = max(self._grace.get(int(shard), 0.0),
                                          until)

    def stale(self, shard: int) -> bool:
        now = time.monotonic()
        with self._cv:
            beat = self._beats.get(int(shard))
            grace_until = self._grace.get(int(shard), 0.0)
        if now < grace_until:
            return False
        if beat is None:
            # never heard from since THIS server booted: after a
            # coordinator restart every live worker looks beat-less until
            # its reconnect lands, so a fresh server grants the full
            # timeout plus boot_grace from boot before condemning anyone
            return now - self._boot > self.heartbeat_timeout + self.boot_grace
        return now - beat[1] > self.heartbeat_timeout

    def gc_steps(self, before: int) -> None:
        with self._cv:
            for s in [s for s in self._arrivals if s < before]:
                del self._arrivals[s]
            for s in [s for s in self._commits if s < before]:
                del self._commits[s]

    def close(self) -> None:
        """Close the listener and every worker connection, wake PEERS
        waiters, then join accept + serve threads — raising if any leak."""
        self._closed = True
        _force_close(self._sock)
        with self._cv:
            conns = list(self._conns.values())
            threads = list(self._threads)
            self._cv.notify_all()  # release any serve thread in PEERS wait
        for conn in conns:
            _force_close(conn)
        leaked = []
        for t in threads:
            if t.ident is None:
                continue
            t.join(timeout=10.0)
            if t.is_alive():
                leaked.append(t.name)
        if leaked:
            raise RuntimeError(
                f"coordinator threads failed to stop within 10s: "
                f"{', '.join(leaked)}; threads leaked")


class CoordClient:
    """The worker's side: stdlib-only (it starts BEFORE the heavy jax
    import, exactly like the file heartbeat, so liveness covers import
    time), one socket, a reader thread that turns pushed COMMIT/ABORT/
    PEER_UPDATE frames into event-driven barrier wakeups, and a heartbeat
    thread whose sequence numbers feed the coordinator's staleness
    judgement.

    Reconnect-with-resume: a lost coordinator connection is no longer a
    poison pill. The reader re-resolves the coordinator address (from
    ``addr_file`` when given — a respawned coordinator publishes a new
    port there), reconnects under ``retry``, re-sends CHELLO, and replays
    the one arrival that may be stranded un-committed; the coordinator's
    K_PEERS reply carries its WAL-restored ``last_commit`` so a commit
    broadcast lost in the outage is recovered too. Only an exhausted retry
    budget aborts the worker — with a structured summary in ``failure``.
    """

    def __init__(self, addr=None, shard: int = 0, *,
                 heartbeat_interval: float = 0.25,
                 addr_file: str | None = None,
                 connect_timeout: float = COORD_CONNECT_TIMEOUT,
                 retry: RetryPolicy | None = None):
        if addr is None and addr_file is None:
            raise ValueError("CoordClient needs addr or addr_file")
        self.shard = int(shard)
        self.heartbeat_interval = float(heartbeat_interval)
        self.connect_timeout = float(connect_timeout)
        self.retry = retry if retry is not None else RetryPolicy()
        self._addr = tuple(addr) if addr is not None else None
        self._addr_file = addr_file
        self.failure: dict | None = None  # RetryExhausted summary, if any
        self._wlock = threading.Lock()
        self._cv = threading.Condition()
        self._commits: dict[int, dict] = {}
        self._peers: dict | None = None
        self._abort: str | None = None
        self._closed = False
        self._stop = threading.Event()
        self._hello = threading.Event()  # beats must not precede CHELLO
        self._data_addr: list | None = None  # remembered for re-CHELLO
        self._pending_arrival: dict | None = None  # un-committed, replayable
        self.on_peer_update = None  # set by the worker once the sender exists
        self._threads: list[threading.Thread] = []
        self._sock = self._connect(f"coord-connect:{self.shard}")

    def _resolve_addr(self) -> tuple:
        """The coordinator's current address: re-read from ``addr_file``
        each attempt (a respawned coordinator listens on a new port), else
        the static address given at construction."""
        if self._addr_file is not None:
            with open(self._addr_file) as f:
                rec = json.load(f)
            return tuple(rec["addr"])
        return self._addr

    def _connect(self, site: str) -> socket.socket:
        last: BaseException | None = None
        attempts = 0
        t0 = time.monotonic()
        for attempt in self.retry.attempts(site,
                                           should_stop=self._stop.is_set):
            attempts = attempt
            try:
                sock = socket.create_connection(self._resolve_addr(),
                                                timeout=self.connect_timeout)
            except (OSError, ValueError, KeyError) as e:
                last = e  # incl. a missing/NOT-yet-republished addr_file
                continue
            sock.settimeout(None)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return sock
        raise RetryExhausted(site, self.retry, last, attempts=attempts,
                             elapsed=time.monotonic() - t0)

    def _send(self, kind: int, obj) -> None:
        payload = json.dumps(obj).encode()
        with self._wlock:
            inj = _fault.active()
            if inj is not None:  # chaos: drop/reset/delay the coord plane
                hdr = _HEADER.pack(MAGIC, kind, len(payload),
                                   zlib.crc32(payload))
                inj.net_send(self._sock, hdr, payload, site="coord.send")
            send_frame(self._sock, kind, payload)

    def start(self) -> None:
        self._threads = [
            threading.Thread(target=self._reader, name="coord-read",
                             daemon=True),
            threading.Thread(target=self._beats, name="coord-beat",
                             daemon=True),
        ]
        for t in self._threads:
            t.start()

    def register(self, data_addr) -> list[tuple]:
        """CHELLO with our data-plane address; blocks for PEERS (all n
        registered). Returns the peer address table; any commit the run
        already published is seeded into the local commit cache (by the
        reader's K_PEERS handler) so a respawned worker sees its recovery
        baseline immediately."""
        self._data_addr = list(data_addr)
        try:
            self._send(K_CHELLO, dict(shard=self.shard,
                                      addr=self._data_addr))
        except OSError:
            pass  # the reader's reconnect replays the CHELLO
        self._hello.set()  # heartbeats may flow now that CHELLO framed first
        with self._cv:
            while self._peers is None and self._abort is None:
                self._cv.wait(0.2)
            self.check_abort()
            peers = self._peers
        return [tuple(a) for a in peers["addrs"]]

    def _reconnect(self) -> bool:
        """Swap in a fresh coordinator connection and resume: re-CHELLO
        (the K_PEERS reply then triggers the pending-arrival replay).
        Returns False — with the abort flagged and a structured summary in
        ``failure`` — only when the retry budget is exhausted."""
        site = f"coord-reconnect:{self.shard}"
        try:
            sock = self._connect(site)
        except RetryExhausted as e:
            with self._cv:
                if not self._closed:
                    self._abort = self._abort or str(e)
                    self.failure = e.summary()
                self._cv.notify_all()
            return False
        with self._wlock:
            old, self._sock = self._sock, sock
        if old is not None:
            _force_close(old)
        if self._data_addr is not None:
            try:
                self._send(K_CHELLO, dict(shard=self.shard,
                                          addr=self._data_addr))
            except OSError:
                pass  # dead again already: the next recv fails and we loop
        return True

    def _replay_pending(self) -> None:
        """Re-send the arrival a coordinator outage may have stranded; the
        server's ``setdefault(...)[shard] = msg`` makes duplicates
        idempotent, and a commit that landed meanwhile already cleared it."""
        with self._cv:
            pending = self._pending_arrival
        if pending is not None:
            try:
                self._send(K_ARRIVE, pending)
            except OSError:
                pass  # still down: replayed again after the next reconnect

    def _reader(self) -> None:
        while True:
            try:
                kind, payload = recv_frame(self._sock)
                msg = json.loads(payload)
            except (ConnectionError, OSError, ValueError):
                with self._cv:
                    if self._closed:
                        self._cv.notify_all()
                        return
                if not self._reconnect():
                    return  # budget exhausted; abort already flagged
                continue
            if kind == K_COMMIT:
                with self._cv:
                    self._commits[int(msg["step"])] = msg
                    pa = self._pending_arrival
                    if pa is not None and int(msg["step"]) >= int(pa["step"]):
                        self._pending_arrival = None
                    self._cv.notify_all()
            elif kind == K_PEERS:
                with self._cv:
                    if msg.get("abort"):
                        self._abort = msg["abort"]
                    self._peers = msg
                    last = msg.get("last_commit")
                    if last is not None:
                        self._commits[int(last["step"])] = last
                        pa = self._pending_arrival
                        if pa is not None and \
                                int(last["step"]) >= int(pa["step"]):
                            self._pending_arrival = None
                    self._cv.notify_all()
                self._replay_pending()
            elif kind == K_PEER_UPDATE:
                cb = self.on_peer_update
                if cb is not None:
                    cb(int(msg["shard"]), tuple(msg["addr"]))
            elif kind == K_ABORT:
                with self._cv:
                    self._abort = msg["reason"]
                    self._cv.notify_all()

    def _beats(self) -> None:
        while not self._hello.is_set():
            if self._stop.wait(0.01):
                return
        seq = 0
        while not self._stop.is_set():
            seq += 1
            try:
                self._send(K_BEAT, dict(shard=self.shard, seq=seq))
            except OSError:
                pass  # mid-reconnect: the reader owns recovery; keep going
            self._stop.wait(self.heartbeat_interval)

    # -- FileCoordinator surface (worker side) ---------------------------------
    def arrive(self, step: int, shard: int, stats: dict) -> None:
        msg = dict(shard=int(shard), step=int(step), **stats)
        with self._cv:
            # cached until its commit lands, so a coordinator outage
            # between arrive and commit can replay it after reconnect
            self._pending_arrival = msg
        try:
            self._send(K_ARRIVE, msg)
        except OSError:
            pass  # cached above; replayed after the reconnect handshake

    def wait_commit(self, step: int, shard: int) -> dict:
        """Event-driven: sleeps on the condition the reader notifies when
        the commit frame lands — no polling loop, no stat syscalls."""
        step = int(step)
        with self._cv:
            while True:
                rec = self._commits.get(step)
                if rec is not None:
                    return rec
                if self._abort is not None:
                    raise RunAborted(
                        f"run aborted by coordinator: {self._abort}")
                self._cv.wait(1.0)

    def commit(self, step: int) -> dict | None:
        with self._cv:
            return self._commits.get(int(step))

    def aborted(self) -> str | None:
        with self._cv:
            return self._abort

    def check_abort(self) -> None:
        reason = self.aborted()
        if reason is not None:
            raise RunAborted(f"run aborted by coordinator: {reason}")

    def close(self) -> None:
        """Stop the beat thread, unblock the reader by closing the socket,
        and join both — raising if either leaks. ``_closed`` is set under
        the condition so the reader's its-not-an-abort check can't race."""
        with self._cv:
            self._closed = True
        self._stop.set()
        _force_close(self._sock)
        leaked = [t.name for t in self._threads
                  if t.ident is not None
                  and (t.join(timeout=10.0) or t.is_alive())]
        if leaked:
            raise RuntimeError(
                f"coordinator client threads failed to stop within 10s: "
                f"{', '.join(leaked)}; threads leaked")


# -- link probes (planner calibration) -----------------------------------------

def probe_link_throughput(n_bytes: int = 8 << 20,
                          chunk: int = 256 << 10) -> float:
    """Measured per-link throughput (bytes/s) through the REAL frame path:
    a loopback TCP connection, framed+CRC'd chunks, a concurrent reader —
    so the number the planner consumes includes framing and checksum cost
    and the pipelining a live link gets (send overlaps receive), which the
    old disk-bandwidth proxy could not express."""
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.bind(("127.0.0.1", 0))
    srv.listen(1)
    got = [0]

    def drain(conn):
        try:
            while got[0] < n_bytes:
                _, payload = recv_frame(conn)
                got[0] += len(payload)
        except ConnectionError:
            pass

    out = socket.create_connection(srv.getsockname())
    out.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    inn, _ = srv.accept()
    t = threading.Thread(target=drain, args=(inn,), daemon=True)
    t.start()
    blob = b"\xa5" * chunk
    t0 = time.perf_counter()
    sent = 0
    while sent < n_bytes:
        send_frame(out, K_RUN, blob)
        sent += chunk
    t.join(timeout=30.0)
    elapsed = max(time.perf_counter() - t0, 1e-9)
    drain_leaked = t.is_alive()
    for s in (out, inn, srv):
        try:
            s.close()
        except OSError:
            pass
    if drain_leaked:
        raise RuntimeError("link-probe drain thread failed to stop within "
                           "30s; thread leaked")
    return sent / elapsed


def probe_file_throughput(directory: str, n_bytes: int = 8 << 20,
                          chunk: int = 256 << 10) -> float:
    """The file-exchange baseline the socket transport replaces — the full
    round trip a delivered byte used to make (launch/procs.py's outbox/
    announce/inbox exchange): the sender writes the outbox run and fsyncs
    before the atomic announce rename (a crashed sender must not announce
    garbage), then the receiver reads the announced run, copies it into its
    own local inbox store, and reads it back for the digest.  Two writes,
    two reads and a durability barrier per delivered byte, where the socket
    path frames each byte exactly once."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, "probe.bin")
    inbox = os.path.join(directory, "probe-inbox.bin")
    marker = os.path.join(directory, "probe.ok")
    blob = b"\xa5" * chunk
    t0 = time.perf_counter()
    with open(path, "wb") as f:
        written = 0
        while written < n_bytes:
            f.write(blob)
            written += chunk
        f.flush()
        os.fsync(f.fileno())
    with open(marker + ".tmp", "w") as f:
        f.write("ok")
    os.replace(marker + ".tmp", marker)
    with open(path, "rb") as rd, open(inbox, "wb") as wr:
        while True:
            buf = rd.read(chunk)
            if not buf:
                break
            wr.write(buf)
    with open(inbox, "rb") as f:
        while f.read(chunk):
            pass
    elapsed = max(time.perf_counter() - t0, 1e-9)
    for p in (path, inbox, marker):
        os.unlink(p)
    return written / elapsed
