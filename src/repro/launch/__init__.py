"""Deployment launchers: run a planned GraphD job as real OS processes.

``launch="threads"`` (the default everywhere else in the repo) emulates the
paper's cluster inside one process. This package is the other half of the
claim: :func:`repro.launch.procs.run_processes` starts ONE WORKER PROCESS
PER SHARD, each opening only its owner view of the edge store, exchanging
messages through the shared-filesystem run-file transport and
synchronizing through the file-based coordinator barriers.
"""

__all__ = ["run_processes"]


def __getattr__(name):
    # lazy (PEP 562): ``python -m repro.launch.procs`` — the worker entry —
    # executes this package __init__ first; an eager procs import here
    # would both double-execute the module under runpy (RuntimeWarning in
    # every worker log) and slow worker startup
    if name == "run_processes":
        from repro.launch.procs import run_processes

        return run_processes
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
