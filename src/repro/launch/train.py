"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-4b \
        --steps 200 --batch 8 --seq 128 --reduced [--devices N]

On this CPU container use --reduced (same-family tiny config). On a real
pod, drop --reduced and pass the production mesh via --mesh-data/--mesh-model.
Checkpoints + restart come from training state dumps every --ckpt-every.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokens import synthetic_batch
from repro.models.transformer import init_params
from repro.training.optimizer import AdamWConfig
from repro.training.train import init_train_state, make_train_step


def save_train_ckpt(path, step, params, opt):
    os.makedirs(path, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten_with_path((params, opt))
    # npz cannot round-trip bfloat16: widen to f32 (exact); restore narrows
    # back using the in-memory template dtypes.
    arrs = {}
    for k, v in flat:
        a = np.asarray(v)
        arrs[jax.tree_util.keystr(k)] = (
            a.astype(np.float32) if a.dtype.name == "bfloat16" else a
        )
    np.savez(os.path.join(path, f"state-{step:06d}.npz"), **arrs)
    with open(os.path.join(path, "latest.json"), "w") as f:
        json.dump(dict(step=step), f)


def restore_train_ckpt(path, params, opt):
    with open(os.path.join(path, "latest.json")) as f:
        step = json.load(f)["step"]
    z = np.load(os.path.join(path, f"state-{step:06d}.npz"))
    flat, tdef = jax.tree_util.tree_flatten_with_path((params, opt))
    leaves = [
        jnp.asarray(z[jax.tree_util.keystr(k)]).astype(tmpl.dtype)
        for k, tmpl in flat
    ]
    params, opt = jax.tree_util.tree_unflatten(tdef, leaves)
    return step, params, opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="ckpt_train")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps)
    print(f"[train] {cfg.name}: {cfg.n_params()/1e6:.1f}M params "
          f"({cfg.n_active_params()/1e6:.1f}M active), "
          f"batch={args.batch}x{args.seq}")

    params = init_params(cfg, jax.random.key(args.seed))
    opt = init_train_state(cfg, params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      microbatches=args.microbatches))
    start = 0
    if args.resume and os.path.exists(
        os.path.join(args.ckpt_dir, "latest.json")
    ):
        start, params, opt = restore_train_ckpt(args.ckpt_dir, params, opt)
        print(f"[train] resumed at step {start}")

    tokens_per_step = args.batch * args.seq
    t_start = time.perf_counter()
    for s in range(start, args.steps):
        batch = synthetic_batch(cfg, s, args.seq, args.batch)
        t0 = time.perf_counter()
        params, opt, m = step_fn(params, opt, batch)
        loss = float(m["loss"])
        dt = time.perf_counter() - t0
        if s % max(args.steps // 20, 1) == 0 or s == args.steps - 1:
            print(f"  step {s:5d}  loss {loss:.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"{tokens_per_step / dt:.0f} tok/s")
        if args.ckpt_every and (s + 1) % args.ckpt_every == 0:
            save_train_ckpt(args.ckpt_dir, s + 1, params, opt)
    total = time.perf_counter() - t_start
    print(f"[train] done: {args.steps - start} steps in {total:.1f}s, "
          f"final loss {loss:.4f}")


if __name__ == "__main__":
    main()
