"""Production mesh + sharding rules (FSDP × TP × EP × SP).

Mesh: single pod (data=16, model=16) = 256 chips; multi-pod adds a leading
pod axis (pod=2, data=16, model=16) = 512 chips.

Parallelism map (DESIGN.md §5):
* batch        -> ('pod', 'data')  pure DP across pods (cheapest inter-pod
                  traffic: one gradient reduction per step)
* params       -> FSDP-shard the d_model-ish axis over 'data', TP-shard the
                  heads/ff/vocab/expert axis over 'model'
* MoE experts  -> EP over 'model'
* KV caches    -> sequence axis over 'model' (decode attention becomes
                  sequence-parallel; XLA turns the softmax reductions into
                  small all-reduces)

Importing this module never touches jax device state — everything is a
function (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devices, axes)


def dp_axes(mesh: Mesh):
    """The data-parallel (batch) axes of this mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------

def _base_spec(name: str, ndim: int) -> tuple:
    """Spec for the UNSTACKED leaf (no leading layer-group axis)."""
    if name in ("embed", "unembed"):
        return ("model", "data")  # vocab TP, d FSDP
    if name in ("wq", "wk", "wv", "w_ukv", "in_proj"):
        return ("data", "model")
    if name in ("wo", "out_proj"):
        return ("model", "data")
    if name in ("w_dkv", "w_krope"):
        return ("data", None)
    if name == "router":
        return ("data", None)
    if name in ("w_gate", "w_up"):
        if ndim == 3:  # MoE expert bank (E, d, f): EP + FSDP
            return ("model", "data", None)
        return ("data", "model")
    if name == "w_down":
        if ndim == 3:  # (E, f, d)
            return ("model", None, "data")
        return ("model", "data")
    if name in ("ws_gate", "ws_up"):
        return ("data", "model")
    if name == "ws_down":
        return ("model", "data")
    if name == "conv_w":
        return (None, "model")
    if name in ("conv_b",):
        return ("model",)
    if name in ("A_log", "dt_bias", "D"):
        return ("model",)
    # norms, gates, scalars: replicated
    return (None,) * ndim


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def _is_stacked(path) -> bool:
    """groups/encoder params carry a leading layer-group axis."""
    for entry in path:
        key = getattr(entry, "key", getattr(entry, "name", None))
        if key in ("groups", "encoder"):
            return True
    return False


def _axis_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return int(mesh.shape[ax])


def _clean(spec, shape, mesh: Mesh):
    """Drop spec axes that do not divide the dimension (or are absent)."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        if isinstance(ax, tuple):
            ax2 = tuple(a for a in ax if a in mesh.axis_names)
            ax = ax2 if ax2 else None
        elif ax not in mesh.axis_names:
            ax = None
        size = _axis_size(mesh, ax)
        out.append(ax if ax is not None and dim % size == 0 else None)
    return P(*out)


def _base_spec_serve(name: str, ndim: int) -> tuple:
    """Weight-stationary serving specs: NO FSDP axis on dense weights (no
    per-token all-gather — decode is latency-bound, params stay resident,
    TP over 'model' only). MoE expert banks additionally shard their ff
    axis over 'data' so 235B-class experts fit per chip."""
    if name in ("embed", "unembed"):
        return ("model", None)
    if name in ("wq", "wk", "wv", "w_ukv", "in_proj"):
        return (None, "model")
    if name in ("wo", "out_proj"):
        return ("model", None)
    if name in ("w_dkv", "w_krope", "router"):
        return (None, None)
    if name in ("w_gate", "w_up"):
        if ndim == 3:  # (E, d, f): EP + ff-TP over 'data'
            return ("model", None, "data")
        return (None, "model")
    if name == "w_down":
        if ndim == 3:  # (E, f, d)
            return ("model", "data", None)
        return ("model", None)
    if name in ("ws_gate", "ws_up"):
        return (None, "model")
    if name == "ws_down":
        return ("model", None)
    if name == "conv_w":
        return (None, "model")
    if name in ("conv_b", "A_log", "dt_bias", "D"):
        return ("model",)
    return (None,) * ndim


def param_specs(params_tree, mesh: Mesh, mode: str = "train") -> object:
    """PartitionSpec pytree for a params (or optimizer-state) tree.

    mode="train": FSDP('data') x TP('model')  (ZeRO-sharded states)
    mode="serve": weight-stationary TP (hillclimbed decode path, §Perf)
    """
    base_fn = _base_spec if mode == "train" else _base_spec_serve

    def spec_for(path, leaf):
        name = _leaf_name(path)
        if name in ("step",):
            return P()
        stacked = _is_stacked(path)
        base_ndim = leaf.ndim - (1 if stacked else 0)
        base = base_fn(name, base_ndim)
        base = tuple(base[:base_ndim]) + (None,) * (base_ndim - len(base))
        spec = ((None,) + base) if stacked else base
        assert len(spec) == leaf.ndim, (name, spec, leaf.shape)
        return _clean(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, params_tree)


def batch_specs_tree(batch_tree, mesh: Mesh) -> object:
    dp = dp_axes(mesh)

    def spec_for(path, leaf):
        return _clean((dp,) + (None,) * (leaf.ndim - 1), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, batch_tree)


def cache_specs_tree(cache_tree, mesh: Mesh) -> object:
    """KV caches: batch over DP axes, sequence/latent over 'model' (SP)."""
    dp = dp_axes(mesh)

    def spec_for(path, leaf):
        name = _leaf_name(path)
        stacked = _is_stacked(path)
        if name == "pos":  # (Lc,) int32 position table
            base = (None,)
        elif name in ("k", "v"):  # (B, Lc|T, Hkv, hd)
            base = (dp, "model", None, None)
        elif name in ("c_kv", "k_rope"):  # (B, Lc, r)
            base = (dp, "model", None)
        elif name == "state":  # (B, H, hd, N)
            base = (dp, "model", None, None)
        elif name == "conv":  # (B, K-1, C)
            base = (dp, None, "model")
        else:
            base = (dp,) + (None,) * (leaf.ndim - 1)
        spec = ((None,) + tuple(base)) if stacked else tuple(base)
        spec = spec[: leaf.ndim]
        return _clean(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
