import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""§Perf hillclimbing driver.

Runs the named optimization variants of the three chosen cells
(worst-roofline, most-collective-bound, most paper-representative), records
each to perf_results.json, and prints before/after against the baseline in
dryrun_results.json. Each variant is one hypothesis->change->measure cycle;
the narrative napkin math lives in EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m repro.launch.perf --cell A1 [A2 B1 B2 C1 C2 ...]
"""

import argparse
import dataclasses
import json

from repro.configs import get_config
from repro.launch.dryrun import run_cell, run_graphd_cell
from repro.models.attention import set_flat_heads


def variant_A(tag: str):
    """command-r-plus-104b x train_4k (most collective-bound)."""
    cfg = get_config("command-r-plus-104b")
    if tag == "A1":  # flat-head attention: shard the O(S^2) probs 16-way
        set_flat_heads(True)
    elif tag == "A2":  # A1 + no sequence sharding of the residual stream
        set_flat_heads(True)
        cfg = dataclasses.replace(cfg, seq_shard=False)
    elif tag == "A3":  # A1 + int8 error-feedback gradient compression
        set_flat_heads(True)
        cfg = dataclasses.replace(cfg, grad_compress=True)
    elif tag == "A4":  # A1 + no remat (flops down, activation memory up)
        set_flat_heads(True)
        cfg = dataclasses.replace(cfg, remat=False)
    try:
        return run_cell("command-r-plus-104b", "train_4k", multi_pod=False,
                        cfg=cfg, variant=tag)
    finally:
        set_flat_heads(False)


def variant_B(tag: str):
    """qwen3-moe-235b-a22b x decode_32k (worst roofline fraction)."""
    cfg = get_config("qwen3-moe-235b-a22b")
    pm = "train"
    if tag == "B1":  # weight-stationary TP: kill per-token FSDP all-gathers
        pm = "serve"
    elif tag == "B2":  # B1 + flat-head attention over the 32k cache
        pm = "serve"
        set_flat_heads(True)
    elif tag == "B3":  # B1 + tighter expert capacity (decode batch routing)
        pm = "serve"
        cfg = dataclasses.replace(cfg, capacity_factor=1.0)
    try:
        return run_cell("qwen3-moe-235b-a22b", "decode_32k", multi_pod=False,
                        cfg=cfg, param_mode=pm, variant=tag)
    finally:
        set_flat_heads(False)


def variant_C(tag: str):
    """graphd-pagerank superstep (the paper's own technique)."""
    if tag == "C1":  # compact wire: bf16 msgs + bool flags, one-hop a2a
        return run_graphd_cell(False, mode="recoded_compact", variant=tag)
    if tag == "C2":  # 4x larger edge blocks (streaming granularity B, §3.2)
        return run_graphd_cell(False, edge_block=16384, variant=tag)
    if tag == "C3":  # compact wire + big blocks
        return run_graphd_cell(False, mode="recoded_compact",
                               edge_block=16384, variant=tag)
    raise KeyError(tag)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("cells", nargs="+")
    ap.add_argument("--out", default="perf_results.json")
    args = ap.parse_args()

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for tag in args.cells:
        print(f"[perf] running variant {tag} ...", flush=True)
        fn = {"A": variant_A, "B": variant_B, "C": variant_C}[tag[0]]
        rec = fn(tag)
        results = [r for r in results if r.get("variant") != tag]
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(json.dumps(
            {k: rec[k] for k in (
                "variant", "flops_per_chip", "bytes_per_chip",
                "collective_bytes_per_chip", "t_compute_s", "t_memory_s",
                "t_collective_s", "dominant", "roofline_fraction",
            ) if k in rec},
            indent=1,
        ), flush=True)


if __name__ == "__main__":
    main()
