import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input shape) cell on the single-pod
(16, 16) and multi-pod (2, 16, 16) production meshes with pure
ShapeDtypeStruct inputs (zero allocation), then records:

* memory_analysis()  — proves the cell fits per-chip HBM,
* cost_analysis()    — per-chip HLO FLOPs / bytes for §Roofline,
* collective op bytes parsed from the post-SPMD HLO (launch/roofline.py).

The 11th config is the paper's own system: a 256-shard GraphD PageRank
superstep over a ClueWeb-scale abstract graph.

Usage:
  python -m repro.launch.dryrun --arch minitron-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod] [--out results.json]
  python -m repro.launch.dryrun --graphd [--multipod]
"""

import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import cost_analysis, shard_map
from repro.configs import ARCHS, SHAPES, cell_supported, get_config
from repro.data.tokens import batch_specs
from repro.launch.mesh import (
    batch_specs_tree, cache_specs_tree, dp_axes, make_production_mesh,
    param_specs, to_shardings,
)
from repro.launch.roofline import collective_bytes_from_text, roofline_terms
from repro.models.transformer import abstract_params
from repro.serving.cache import abstract_caches
from repro.serving.engine import decode_step, prefill
from repro.training.optimizer import AdamWConfig
from repro.training.train import make_train_step

WHISPER_SELF_LEN = 448  # decoder context; cross-KV covers `seq_len` frames


def _opt_state_abstract(params_abs, grad_compress: bool):
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    opt = dict(
        mu=jax.tree.map(f32, params_abs),
        nu=jax.tree.map(f32, params_abs),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )
    if grad_compress:
        opt["err"] = jax.tree.map(f32, params_abs)
    return opt


def _media_spec(cfg, B, seq_len):
    n_media = cfg.n_media_tokens
    if cfg.family == "audio":
        n_media = seq_len  # encoder frames = the shape's sequence length
    return jax.ShapeDtypeStruct((B, n_media, cfg.d_model), cfg.dtype), n_media


def lower_cell(arch: str, shape: str, mesh, cfg=None, opt_cfg=None,
               param_mode: str = "train"):
    """Build (fn, arg_specs, in_shardings, out_shardings) and lower+compile.

    param_mode="serve" switches to weight-stationary TP specs (§Perf)."""
    cfg = cfg or get_config(arch)
    info = SHAPES[shape]
    S, B, kind = info["seq_len"], info["global_batch"], info["kind"]

    params_abs = abstract_params(cfg)
    pspecs = param_specs(params_abs, mesh, mode=param_mode)

    if kind == "train":
        step_fn = make_train_step(cfg, opt_cfg or AdamWConfig())
        opt_abs = _opt_state_abstract(params_abs, cfg.grad_compress)
        ospecs = dict(
            mu=param_specs(params_abs, mesh, mode=param_mode),
            nu=param_specs(params_abs, mesh, mode=param_mode),
            step=P(),
        )
        if cfg.grad_compress:
            ospecs["err"] = param_specs(params_abs, mesh, mode=param_mode)
        batch_abs = batch_specs(cfg, S, B)
        if cfg.family == "audio":
            media, _ = _media_spec(cfg, B, S)
            batch_abs["media"] = media
        bspecs = batch_specs_tree(batch_abs, mesh)
        in_shard = to_shardings((pspecs, ospecs, bspecs), mesh)
        out_shard = to_shardings(
            (pspecs, ospecs, jax.tree.map(lambda _: P(), dict(
                loss=0, aux=0, grad_norm=0, lr=0))), mesh
        )
        fn = jax.jit(step_fn, in_shardings=in_shard,
                     out_shardings=out_shard)
        args = (params_abs, opt_abs, batch_abs)

    elif kind == "prefill":
        tok_len = WHISPER_SELF_LEN if cfg.family == "audio" else S
        caches_abs = abstract_caches(
            cfg, B, max_len=tok_len,
            n_media=S if cfg.family == "audio" else None,
        )
        cspecs = cache_specs_tree(caches_abs, mesh)
        toks = jax.ShapeDtypeStruct((B, tok_len), jnp.int32)
        tspec = batch_specs_tree(toks, mesh)
        args_list = [params_abs, toks, caches_abs]
        in_list = [pspecs, tspec, cspecs]
        if cfg.family in ("audio", "vlm"):
            media, _ = _media_spec(cfg, B, S)
            args_list.append(media)
            in_list.append(batch_specs_tree(media, mesh))
        fn = jax.jit(
            functools.partial(prefill, cfg),
            in_shardings=to_shardings(tuple(in_list), mesh),
            out_shardings=to_shardings(
                (batch_specs_tree(
                    jax.ShapeDtypeStruct((B, cfg.vocab), jnp.float32), mesh
                ), cspecs), mesh),
        )
        args = tuple(args_list)

    else:  # decode
        self_len = WHISPER_SELF_LEN if cfg.family == "audio" else S
        caches_abs = abstract_caches(
            cfg, B, max_len=self_len,
            n_media=S if cfg.family == "audio" else None,
        )
        cspecs = cache_specs_tree(caches_abs, mesh)
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(
            functools.partial(decode_step, cfg),
            in_shardings=to_shardings(
                (pspecs, cspecs, batch_specs_tree(tok, mesh), P()), mesh
            ),
            out_shardings=to_shardings(
                (batch_specs_tree(
                    jax.ShapeDtypeStruct((B, cfg.vocab), jnp.float32), mesh
                ), cspecs), mesh),
        )
        args = (params_abs, caches_abs, tok, pos)

    from repro.models.sharding import rules

    dp = dp_axes(mesh)
    seq = "model" if cfg.seq_shard else None
    with rules(batch=dp if len(dp) > 1 else dp[0], model="model", seq=seq,
               mesh=mesh):
        t0 = time.perf_counter()
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    return lowered, compiled, dict(
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1)
    )


def _cost_of(compiled):
    cost = cost_analysis(compiled)
    coll = collective_bytes_from_text(compiled.as_text())
    return dict(
        flops=cost.get("flops", 0.0),
        bytes=cost.get("bytes accessed", 0.0),
        coll=coll["total"],
        coll_by_op=coll["by_op"],
    )


def _extrapolate(c1, c2, G: int):
    """Depth-linear extrapolation from unrolled 1- and 2-group compiles:
    total(G) = base + G * per_group with base = 2*c1 - c2."""
    out = {}
    for k in ("flops", "bytes", "coll"):
        per_group = max(c2[k] - c1[k], 0.0)
        base = max(c1[k] - per_group, 0.0)
        out[k] = base + G * per_group
    out["coll_by_op"] = {
        op: max(c1["coll_by_op"].get(op, 0)
                + (G - 1) * max(c2["coll_by_op"].get(op, 0)
                                - c1["coll_by_op"].get(op, 0), 0), 0)
        for op in set(c1["coll_by_op"]) | set(c2["coll_by_op"])
    }
    return out


def analyze(arch, shape, mesh_name, mesh, compiled, cfg, times,
            param_mode="train"):
    """Full-model compile proves the cell; 1- and 2-group unrolled compiles
    recover exact depth-linear cost terms (scan bodies are counted once by
    XLA's cost analysis — verified empirically)."""
    n_chips = 512 if mesh_name == "multipod" else 256
    info = SHAPES[shape]
    mem = compiled.memory_analysis()

    G = cfg.n_pattern_groups
    _, comp1, _ = lower_cell(arch, shape, mesh, cfg=cfg.with_groups(1),
                             param_mode=param_mode)
    _, comp2, _ = lower_cell(arch, shape, mesh, cfg=cfg.with_groups(2),
                             param_mode=param_mode)
    cost = _extrapolate(_cost_of(comp1), _cost_of(comp2), G)

    terms = roofline_terms(
        cfg, info, flops=cost["flops"], bytes_accessed=cost["bytes"],
        collective_bytes=cost["coll"], n_chips=n_chips,
    )
    arg_bytes = getattr(mem, "argument_size_in_bytes", 0)
    rec = dict(
        arch=arch, shape=shape, mesh=mesh_name, ok=True,
        flops_per_chip=cost["flops"],
        bytes_per_chip=cost["bytes"],
        collective_bytes_per_chip=cost["coll"],
        collective_breakdown=cost["coll_by_op"],
        argument_bytes=arg_bytes,
        output_bytes=getattr(mem, "output_size_in_bytes", 0),
        cpu_temp_bytes=getattr(mem, "temp_size_in_bytes", 0),
        # modeled TPU-resident bytes: sharded args (exact) + remat
        # checkpoints + one layer's working set (documented in EXPERIMENTS)
        peak_bytes_model=arg_bytes + _activation_model_bytes(cfg, info,
                                                             n_chips),
        **times,
        **terms,
    )
    return rec


def _activation_model_bytes(cfg, info, n_chips: int) -> int:
    """Remat activation model: G checkpointed layer inputs + ~4 working
    buffers of one pattern group, batch/seq sharded across the mesh."""
    S, B, kind = info["seq_len"], info["global_batch"], info["kind"]
    if kind != "train":
        S_act = 1 if kind == "decode" else S
    else:
        S_act = S
    tokens_per_chip = max(B * S_act // n_chips, 1)
    a = tokens_per_chip * cfg.d_model * 2  # bf16 layer input
    G = cfg.n_pattern_groups
    work = 4 * a * len(cfg.pattern) + tokens_per_chip * max(
        cfg.d_ff, cfg.moe_dff, cfg.d_ssm_inner if cfg.ssm_state else 0, 1
    ) * 2
    logits = tokens_per_chip * cfg.vocab * 4 // 16  # vocab TP-sharded
    return int(G * a + work + logits)


def run_cell(arch: str, shape: str, multi_pod: bool, cfg=None,
             param_mode: str = "train", variant: str = ""):
    mesh_name = "multipod" if multi_pod else "singlepod"
    ok, why = cell_supported(arch, shape)
    if not ok:
        return dict(arch=arch, shape=shape, mesh=mesh_name, ok=False,
                    skipped=True, reason=why)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = cfg or get_config(arch)
    with mesh:
        lowered, compiled, times = lower_cell(arch, shape, mesh, cfg=cfg,
                                              param_mode=param_mode)
        rec = analyze(arch, shape, mesh_name, mesh, compiled, cfg, times,
                      param_mode=param_mode)
    if variant:
        rec["variant"] = variant
    return rec


# ---------------------------------------------------------------------------
# GraphD (the paper's system) as the 11th dry-run config
# ---------------------------------------------------------------------------

def run_graphd_cell(multi_pod: bool, scale: str = "clueweb",
                    mode: str = "recoded", edge_block: int = 4096,
                    variant: str = ""):
    """One PageRank superstep on a web-scale abstract graph, sharded over
    all chips (the pod is a flat ring of 'machines'). ``mode`` selects the
    exchange (recoded ring / recoded_compact all_to_all / basic)."""
    from repro.core.algorithms import PageRank
    from repro.core.engine import superstep_spmd
    from repro.graph.partition import abstract_partitioned_graph

    sizes = dict(
        clueweb=(978_408_098, 42_574_107_469),  # Table 1
        webuk=(133_633_040, 5_507_679_822),
    )
    import numpy as np

    V, E = sizes[scale]
    n = 512 if multi_pod else 256
    # the paper's |W| machines form a flat ring: no 2-D structure
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:n]), ("machines",))

    pg = abstract_partitioned_graph(n, V, E, edge_block=edge_block,
                                    vertex_pad=512)
    prog = PageRank(supersteps=10)
    axis = "machines"

    def step(pg_, v, a, s):
        sq = lambda t: jax.tree.map(lambda x: x[0], t)
        nv, na, st = superstep_spmd(
            prog, sq(pg_), sq(v), sq(a), s, axis=axis, mode=mode
        )
        return nv[None], na[None], st

    spec = P(axis)
    fn = shard_map(
        step, mesh=mesh,
        in_specs=(spec, spec, spec, P()),
        out_specs=(spec, spec, P()),
    )
    vals = jax.ShapeDtypeStruct((n, pg.P), jnp.float32)
    act = jax.ShapeDtypeStruct((n, pg.P), jnp.bool_)
    stp = jax.ShapeDtypeStruct((), jnp.int32)
    shard = NamedSharding(mesh, P(axis))
    rep = NamedSharding(mesh, P())
    jfn = jax.jit(
        fn,
        in_shardings=(jax.tree.map(lambda _: shard, pg), shard, shard, rep),
    )
    t0 = time.perf_counter()
    lowered = jfn.lower(pg, vals, act, stp)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    cost = cost_analysis(compiled)
    mem = compiled.memory_analysis()
    coll = collective_bytes_from_text(compiled.as_text())
    terms = roofline_terms(
        None, dict(kind="graphd", seq_len=0, global_batch=0),
        flops=cost.get("flops", 0.0),
        bytes_accessed=cost.get("bytes accessed", 0.0),
        collective_bytes=coll["total"], n_chips=n,
        graphd=dict(V=V, E=E, n=n),
    )
    return dict(
        arch=f"graphd-pagerank-{scale}", shape="superstep",
        variant=variant, mode=mode, edge_block=edge_block,
        mesh="multipod" if multi_pod else "singlepod", ok=True,
        flops_per_chip=cost.get("flops", 0.0),
        bytes_per_chip=cost.get("bytes accessed", 0.0),
        collective_bytes_per_chip=coll["total"],
        collective_breakdown=coll["by_op"],
        argument_bytes=getattr(mem, "argument_size_in_bytes", 0),
        temp_bytes=getattr(mem, "temp_size_in_bytes", 0),
        peak_bytes=(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        ),
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        **terms,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--graphd", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    def record(rec):
        results[:] = [
            r for r in results
            if (r["arch"], r["shape"], r["mesh"])
            != (rec["arch"], rec["shape"], rec["mesh"])
        ]
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)

    def one(arch, shape, multi):
        mesh_name = "multipod" if multi else "singlepod"
        key = (arch, shape, mesh_name)
        if key in done:
            print(f"[skip] {key} already done")
            return
        print(f"[dryrun] {arch} x {shape} on {mesh_name} ...", flush=True)
        try:
            rec = run_cell(arch, shape, multi)
        except Exception as e:
            traceback.print_exc()
            rec = dict(arch=arch, shape=shape, mesh=mesh_name, ok=False,
                       error=f"{type(e).__name__}: {e}")
        record(rec)
        status = "OK" if rec.get("ok") else (
            "SKIP" if rec.get("skipped") else "FAIL")
        print(f"  -> {status} "
              f"(compile {rec.get('compile_s', '-')}s, "
              f"peak {rec.get('peak_bytes', 0)/2**30:.2f} GiB/chip)",
              flush=True)

    if args.graphd:
        rec = run_graphd_cell(args.multipod)
        record(rec)
        print(json.dumps(rec, indent=1))
        return
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                one(arch, shape, args.multipod)
        return
    assert args.arch and args.shape, "--arch/--shape or --all"
    one(args.arch, args.shape, args.multipod)


if __name__ == "__main__":
    main()
