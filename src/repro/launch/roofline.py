"""Roofline analysis (deliverable g).

Three terms per (arch × shape × mesh), all per chip, from the compiled
dry-run artifact:

  compute    = HLO_FLOPs / peak_FLOP/s            (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw                 (819 GB/s)
  collective = collective_bytes / link_bw         (~50 GB/s/link ICI)

cost_analysis() reports the per-device partitioned program, so FLOPs/bytes
need no further division. Collective bytes are parsed from the post-SPMD
HLO: result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (all-reduce counted twice — RS+AG
decomposition; ring factors (n-1)/n ≈ 1 are ignored).

MODEL_FLOPS = 6·N·D for training (2·N·D for inference steps), N = active
params; the ratio MODEL_FLOPS/HLO_FLOPs surfaces remat/redundant compute.
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_text(hlo: str) -> dict:
    """Sum result-shape bytes of every collective op in post-SPMD HLO."""
    by_op = {op: 0 for op in _COLL_OPS}
    count = {op: 0 for op in _COLL_OPS}
    for line in hlo.splitlines():
        stripped = line.lstrip()
        # result op lines look like:  %x = bf16[8,128]{1,0} all-reduce(...
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(1)
        for op in _COLL_OPS:
            token = f" {op}("
            start_token = f" {op}-start("
            if token in f" {rhs}" or start_token in f" {rhs}":
                head = rhs.split(op, 1)[0]
                nbytes = sum(
                    _shape_bytes(d, dims)
                    for d, dims in _SHAPE_RE.findall(head)
                )
                mult = 2 if op == "all-reduce" else 1  # AR = RS + AG
                by_op[op] += nbytes * mult
                count[op] += 1
                break
    total = sum(by_op.values())
    return dict(total=total, by_op={k: v for k, v in by_op.items() if v},
                counts={k: v for k, v in count.items() if v})


def roofline_terms(cfg, shape_info, *, flops, bytes_accessed,
                   collective_bytes, n_chips, graphd=None) -> dict:
    """The three terms (seconds/step/chip), dominant term, model-FLOPs ratio."""
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_accessed / HBM_BW
    t_collective = collective_bytes / ICI_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_collective)
    dominant = max(terms, key=terms.get)

    model_flops_per_chip = 0.0
    if graphd is not None:
        # useful work of a PageRank superstep: ~10 flops/edge + 2/vertex
        model_flops_per_chip = (10 * graphd["E"] + 2 * graphd["V"]) / graphd["n"]
    elif cfg is not None:
        N = cfg.n_active_params()
        kind = shape_info["kind"]
        S, B = shape_info["seq_len"], shape_info["global_batch"]
        if kind == "train":
            tokens = S * B
            model_flops = 6 * N * tokens
        elif kind == "prefill":
            tokens = S * B
            model_flops = 2 * N * tokens
        else:  # decode: one token per sequence
            model_flops = 2 * N * B
        model_flops_per_chip = model_flops / n_chips

    ratio = model_flops_per_chip / flops if flops else 0.0
    bound = (
        t_compute / max(t_compute, t_memory, t_collective)
        if max(terms.values()) > 0
        else 0.0
    )
    return dict(
        t_compute_s=t_compute,
        t_memory_s=t_memory,
        t_collective_s=t_collective,
        dominant=dominant,
        model_flops_per_chip=model_flops_per_chip,
        useful_flops_ratio=ratio,
        roofline_fraction=round(
            model_flops_per_chip / PEAK_FLOPS
            / max(max(terms.values()), 1e-30), 4
        ),
    )
