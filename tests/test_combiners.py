"""Combiner algebra (paper §2.1/§5): every combiner must be commutative and
associative with a true identity e0, and its two concrete realizations — the
scatter path (in-memory A_s/A_r combine) and the reduce path (stacked-buffer
fold) — must agree. Fixed-seed and exhaustive-small-case versions that always
run; hypothesis sweeps live in test_properties.py."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import IMAX, IMIN, MAX, MIN, OR, SUM

COMBINERS = {"sum": SUM, "min": MIN, "max": MAX, "or": OR,
             "imin": IMIN, "imax": IMAX}
CORE_FOUR = ["sum", "min", "max", "or"]


def _norm(name, x):
    """Compare in each combiner's natural domain (OR = boolean semiring)."""
    a = np.asarray(x)
    return a.astype(bool) if name == "or" else a


def _sample(name, rng, size):
    if name == "or":
        return rng.integers(0, 2, size=size).astype(np.float32)
    return rng.integers(-50, 50, size=size).astype(np.float32)


class TestAlgebra:
    @pytest.mark.parametrize("name", list(COMBINERS))
    def test_commutative(self, name):
        comb = COMBINERS[name]
        rng = np.random.default_rng(0)
        a, b = (jnp.asarray(_sample(name, rng, 64)) for _ in range(2))
        np.testing.assert_array_equal(
            _norm(name, comb.combine(a, b)), _norm(name, comb.combine(b, a))
        )

    @pytest.mark.parametrize("name", list(COMBINERS))
    def test_associative(self, name):
        comb = COMBINERS[name]
        rng = np.random.default_rng(1)
        a, b, c = (jnp.asarray(_sample(name, rng, 64)) for _ in range(3))
        lhs = comb.combine(comb.combine(a, b), c)
        rhs = comb.combine(a, comb.combine(b, c))
        np.testing.assert_array_equal(_norm(name, lhs), _norm(name, rhs))

    @pytest.mark.parametrize("name", list(COMBINERS))
    def test_identity(self, name):
        comb = COMBINERS[name]
        dtype = jnp.int32 if name in ("imin", "imax", "or") else jnp.float32
        rng = np.random.default_rng(2)
        a = jnp.asarray(_sample(name, rng, 64)).astype(dtype)
        e0 = jnp.asarray(comb.e0, dtype)
        np.testing.assert_array_equal(
            _norm(name, comb.combine(a, e0)), _norm(name, a)
        )
        np.testing.assert_array_equal(
            _norm(name, comb.combine(e0, a)), _norm(name, a)
        )

    @pytest.mark.parametrize("name", CORE_FOUR)
    def test_exhaustive_small_domain(self, name):
        """Associativity over the full small domain — not just samples."""
        comb = COMBINERS[name]
        dom = [0.0, 1.0] if name == "or" else [-2.0, 0.0, 3.0]
        for x, y, z in itertools.product(dom, repeat=3):
            a, b, c = (jnp.float32(v) for v in (x, y, z))
            lhs = comb.combine(comb.combine(a, b), c)
            rhs = comb.combine(a, comb.combine(b, c))
            assert _norm(name, lhs) == _norm(name, rhs)


class TestScatterReduceAgree:
    """identity+scatter (the engine's A_s path) == reduce over stacked
    one-slot buffers (the engine's exchange-digest path)."""

    @pytest.mark.parametrize("name", CORE_FOUR)
    @pytest.mark.parametrize("seed", [0, 7, 23])
    def test_agree(self, name, seed):
        comb = COMBINERS[name]
        rng = np.random.default_rng(seed)
        P, M = 16, 80
        idx = rng.integers(0, P, size=M).astype(np.int32)
        msgs = _sample(name, rng, M)
        scattered = comb.scatter(
            comb.identity((P,), jnp.float32), jnp.asarray(idx),
            jnp.asarray(msgs),
        )
        stack = np.full((M, P), float(comb.e0), dtype=np.float32)
        stack[np.arange(M), idx] = msgs
        reduced = comb.reduce(jnp.asarray(stack), 0)
        if name == "or":
            np.testing.assert_array_equal(
                _norm(name, scattered), _norm(name, reduced)
            )
        else:
            np.testing.assert_allclose(
                np.asarray(scattered), np.asarray(reduced), rtol=1e-6
            )

    @pytest.mark.parametrize("name", CORE_FOUR)
    def test_scatter_of_identity_is_noop(self, name):
        """Padded edge slots scatter e0 — they must be compute-neutral
        (this is what makes padded blocks free in every mode)."""
        comb = COMBINERS[name]
        P = 8
        target = comb.identity((P,), jnp.float32)
        idx = jnp.zeros((32,), jnp.int32)
        e0s = jnp.full((32,), comb.e0, jnp.float32)
        out = comb.scatter(target, idx, e0s)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(target))
