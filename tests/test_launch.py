"""Launch-layer unit tests: sharding spec rules and the HLO collective
parser (these run with 1 device — no mesh construction that touches jax
device state beyond a fake Mesh object)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS
from repro.launch.roofline import (
    collective_bytes_from_text, roofline_terms,
)
from repro.models.transformer import abstract_params


def _fake_mesh(shape=(16, 16), names=("data", "model")):
    # an abstract mesh over fake devices is enough for spec computation
    devs = np.empty(shape, dtype=object)
    for i in range(devs.size):
        devs.flat[i] = jax.devices()[0]
    return Mesh(devs, names)


class TestParamSpecs:
    @pytest.mark.parametrize("arch", sorted(ARCHS))
    @pytest.mark.parametrize("mode", ["train", "serve"])
    def test_specs_divide_dims(self, arch, mode):
        from repro.launch.mesh import param_specs

        mesh = _fake_mesh()
        tree = abstract_params(ARCHS[arch])
        specs = param_specs(tree, mesh, mode=mode)

        def check(leaf, spec):
            assert len(spec) <= leaf.ndim
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 8):
                if ax is None:
                    continue
                size = (
                    np.prod([mesh.shape[a] for a in ax])
                    if isinstance(ax, tuple) else mesh.shape[ax]
                )
                assert dim % size == 0, (leaf.shape, spec)

        jax.tree.map(check, tree, specs,
                     is_leaf=lambda x: isinstance(x, P))

    def test_serve_mode_has_no_fsdp_on_dense(self):
        from repro.launch.mesh import param_specs

        mesh = _fake_mesh()
        tree = abstract_params(ARCHS["command-r-plus-104b"])
        serve = param_specs(tree, mesh, mode="serve")
        # dense wq under serve: no 'data' axis anywhere (weights resident)
        wq_spec = serve["groups"][0]["attn"]["wq"]
        assert "data" not in jax.tree.leaves(
            tuple(a for a in wq_spec if a), is_leaf=lambda x: True
        )


class TestCollectiveParser:
    HLO = """
  ENTRY main {
    %x = bf16[8,128]{1,0} parameter(0)
    %ag = bf16[8,2048]{1,0} all-gather(%x), replica_groups=...
    %ar = f32[16,16]{1,0} all-reduce(%y), to_apply=add
    %cp = s32[64]{0} collective-permute-start(%z), source_target_pairs=...
    %aa = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%a, %b)
    %not_a_coll = f32[9999,9999]{1,0} add(%p, %q)
  }
    """

    def test_bytes(self):
        got = collective_bytes_from_text(self.HLO)
        ag = 8 * 2048 * 2
        ar = 16 * 16 * 4 * 2  # all-reduce counted twice (RS+AG)
        cp = 64 * 4
        aa = 2 * 4 * 4 * 4
        assert got["by_op"]["all-gather"] == ag
        assert got["by_op"]["all-reduce"] == ar
        assert got["by_op"]["collective-permute"] == cp
        assert got["by_op"]["all-to-all"] == aa
        assert got["total"] == ag + ar + cp + aa

    def test_ignores_non_collectives(self):
        got = collective_bytes_from_text(self.HLO)
        assert 9999 * 9999 * 4 > got["total"]


class TestRooflineTerms:
    def test_dominant_and_fraction(self):
        cfg = ARCHS["minitron-4b"]
        info = dict(kind="train", seq_len=4096, global_batch=256)
        t = roofline_terms(cfg, info, flops=1e14, bytes_accessed=2e12,
                           collective_bytes=1e10, n_chips=256)
        assert t["dominant"] == "memory"
        assert 0 < t["roofline_fraction"] <= 1.01
        # useful flops: 6*N*D/chips
        expect = 6 * cfg.n_active_params() * 4096 * 256 / 256
        assert abs(t["model_flops_per_chip"] - expect) / expect < 1e-6

    def test_decode_uses_2nd(self):
        cfg = ARCHS["minitron-4b"]
        info = dict(kind="decode", seq_len=32768, global_batch=128)
        t = roofline_terms(cfg, info, flops=1e10, bytes_accessed=1e10,
                           collective_bytes=1e9, n_chips=256)
        expect = 2 * cfg.n_active_params() * 128 / 256
        assert abs(t["model_flops_per_chip"] - expect) / expect < 1e-6


class TestDryrunResultsIntegrity:
    """The committed dryrun_results.json satisfies the deliverable: every
    (arch x shape x mesh) cell present, ok or declared-skip."""

    def test_all_80_cells(self):
        import json
        import os

        path = os.path.join(os.path.dirname(__file__), "..",
                            "dryrun_results.json")
        if not os.path.exists(path):
            pytest.skip("dry-run sweep not yet recorded")
        with open(path) as f:
            rs = json.load(f)
        cells = {(r["arch"], r["shape"], r["mesh"]) for r in rs}
        assert len(cells) >= 80
        bad = [r for r in rs if not r.get("ok") and not r.get("skipped")]
        assert not bad, bad
        for r in rs:
            if r.get("ok"):
                assert r["flops_per_chip"] > 0
                assert r["argument_bytes"] < 16 * 2**30  # fits HBM
