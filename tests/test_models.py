"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
same-family config, one forward + one train step on CPU; output shapes and
finiteness asserted. Serving consistency: prefill+decode == full forward."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cell_supported, get_config
from repro.data.tokens import synthetic_batch
from repro.models.transformer import abstract_params, forward, init_params
from repro.serving.cache import cache_bytes, make_caches
from repro.serving.engine import decode_step, greedy_generate, prefill
from repro.training.optimizer import AdamWConfig
from repro.training.train import init_train_state, make_train_step

ALL_ARCHS = sorted(ARCHS)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward_and_train_step(name):
    cfg = ARCHS[name].reduced()
    params = init_params(cfg, jax.random.key(0))
    batch = synthetic_batch(cfg, 0, seq_len=32, global_batch=2)

    logits, aux = jax.jit(
        lambda p, b: forward(cfg, p, b["tokens"], b.get("media"))
    )(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), f"{name}: NaN/inf logits"

    step = jax.jit(make_train_step(cfg, AdamWConfig(total_steps=10)))
    opt = init_train_state(cfg, params)
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"])), f"{name}: non-finite loss"
    assert np.isfinite(float(m["grad_norm"])), f"{name}: non-finite grads"
    # params actually moved
    moved = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert moved


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_loss_decreases(name):
    cfg = ARCHS[name].reduced()
    params = init_params(cfg, jax.random.key(0))
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1,
                                                    total_steps=30)))
    opt = init_train_state(cfg, params)
    batch = synthetic_batch(cfg, 0, seq_len=32, global_batch=2)
    losses = []
    for _ in range(8):  # overfit one small batch
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], f"{name}: loss did not decrease {losses}"


@pytest.mark.parametrize(
    "name",
    [a for a in ALL_ARCHS if a != "whisper-large-v3"],
)
def test_smoke_prefill_decode_consistency(name):
    cfg = ARCHS[name].reduced()
    params = init_params(cfg, jax.random.key(1))
    B, S, DEC = 2, 16, 4
    batch = synthetic_batch(cfg, 0, S + DEC, B)
    toks, media = batch["tokens"], batch.get("media")
    logits_full, _ = jax.jit(lambda p, t, m: forward(cfg, p, t, m))(
        params, toks, media
    )
    caches = make_caches(cfg, B, max_len=S + DEC)
    lg, caches = jax.jit(functools.partial(prefill, cfg))(
        params, toks[:, :S], caches, media
    )
    errs = [float(jnp.abs(lg - logits_full[:, S - 1]).max())]
    dstep = jax.jit(functools.partial(decode_step, cfg))
    for t in range(DEC - 1):
        lg, caches = dstep(params, caches, toks[:, S + t:S + t + 1],
                           jnp.int32(S + t))
        errs.append(float(jnp.abs(lg - logits_full[:, S + t]).max()))
    assert max(errs) < 0.25, f"{name}: prefill/decode drift {errs}"


def test_whisper_serve():
    cfg = ARCHS["whisper-large-v3"].reduced()
    params = init_params(cfg, jax.random.key(1))
    B, S = 2, 8
    batch = synthetic_batch(cfg, 0, S, B)
    caches = make_caches(cfg, B, max_len=32)
    lg, caches = jax.jit(functools.partial(prefill, cfg))(
        params, batch["tokens"], caches, batch["media"]
    )
    assert lg.shape == (B, cfg.vocab)
    lg2, caches = jax.jit(functools.partial(decode_step, cfg))(
        params, caches, jnp.zeros((B, 1), jnp.int32), jnp.int32(S)
    )
    assert np.isfinite(np.asarray(lg2)).all()


def test_greedy_generate_runs():
    cfg = ARCHS["minitron-4b"].reduced()
    params = init_params(cfg, jax.random.key(2))
    caches = make_caches(cfg, 2, max_len=24)
    prompt = synthetic_batch(cfg, 0, 8, 2)["tokens"]
    out = greedy_generate(cfg, params, prompt, caches, steps=6)
    assert out.shape == (2, 6)


def test_sliding_window_cache_is_ring_buffer():
    """gemma3 local layers: cache length == window regardless of context."""
    cfg = ARCHS["gemma3-12b"].reduced()
    caches = make_caches(cfg, B=1, max_len=4096)
    # pattern = 5 local + 1 global; local kv caches have Lc == window
    local = caches["groups"][0]["kv"]
    glob = caches["groups"][5]["kv"]
    assert local["k"].shape[2] == cfg.pattern[0].window
    assert glob["k"].shape[2] == 4096


def test_mla_cache_is_latent():
    """deepseek-v2-lite: decode cache = kv_lora latent, not per-head K/V."""
    cfg = get_config("deepseek-v2-lite-16b")
    caches_abs = jax.eval_shape(
        lambda: make_caches(cfg, B=1, max_len=1024)
    )
    kv = caches_abs["groups"][0]["kv"]
    assert kv["c_kv"].shape[-1] == cfg.mla_kv_lora
    # latent cache is far smaller than the equivalent GQA cache per token
    mla_per_tok = kv["c_kv"].shape[-1] + kv["k_rope"].shape[-1]
    gqa_per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
    assert mla_per_tok < gqa_per_tok / 3


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_abstract_params_match_analytic_count(name):
    """eval_shape param tree size ≈ ModelConfig.n_params() (±2%)."""
    cfg = ARCHS[name]
    tree = abstract_params(cfg)
    total = sum(np.prod(l.shape) for l in jax.tree.leaves(tree))
    analytic = cfg.n_params()
    assert abs(total - analytic) / analytic < 0.02, (
        f"{name}: abstract {total/1e9:.2f}B vs analytic {analytic/1e9:.2f}B"
    )


def test_cell_support_matrix():
    cells = [(a, s) for a in ALL_ARCHS for s in SHAPES]
    assert len(cells) == 40
    skipped = [c for c in cells if not cell_supported(*c)[0]]
    assert all(s == "long_500k" for _, s in skipped)
    assert {a for a, _ in skipped} == set(ALL_ARCHS) - {
        "mamba2-2.7b", "hymba-1.5b", "gemma3-12b"
    }
