"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes, combiners, message kinds, and frontier densities."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.graph import partition_graph, rmat_graph
from repro.graph.kblocks import build_kernel_layout, layout_stats
from repro.kernels import ops
from repro.kernels.edge_combine import COMBINERS, MSG_KINDS
from repro.kernels.ref import digest_ref, edge_combine_ref


def _setup(scale=7, ef=8, seed=3, n=4, win=32, blk=32, vp=32):
    g = rmat_graph(scale=scale, edge_factor=ef, seed=seed)
    pg, _ = partition_graph(g, n_shards=n, edge_block=64, vertex_pad=vp)
    kl = build_kernel_layout(pg, BLK=blk, SRC_WIN=win, DST_WIN=win)
    return pg, kl


def _state(pg, density, seed=0):
    rng = np.random.default_rng(seed)
    P = pg.P
    values = jnp.asarray(rng.random(P, dtype=np.float32))
    degree = jnp.asarray(np.asarray(pg.degree)[0].astype(np.float32))
    active = jnp.asarray((rng.random(P) < density).astype(np.float32))
    return jnp.stack([values, degree, active], axis=0)


class TestEdgeCombine:
    @pytest.mark.parametrize("msg_kind", MSG_KINDS)
    @pytest.mark.parametrize("combiner", COMBINERS)
    def test_dense_all_semirings(self, msg_kind, combiner):
        pg, kl = _setup()
        state3 = _state(pg, density=0.7)
        i, k = 0, 1
        args = (
            state3, kl.sp[i, k], kl.dp[i, k], kl.w[i, k],
            jnp.arange(kl.NB, dtype=jnp.int32), jnp.int32(kl.NB),
            kl.blk_swin[i, k], kl.blk_dwin[i, k],
        )
        kw = dict(SRC_WIN=32, DST_WIN=32, msg_kind=msg_kind, combiner=combiner)
        A_k, c_k = ops.edge_combine(*args, **kw)
        A_r, c_r = edge_combine_ref(*args, **kw)
        np.testing.assert_allclose(np.asarray(A_k), np.asarray(A_r),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))

    @pytest.mark.parametrize("density", [0.0, 0.02, 0.2, 1.0])
    def test_skip_compaction_equals_dense(self, density):
        """skip() must be invisible in results at any frontier density."""
        pg, kl = _setup()
        state3 = _state(pg, density=density, seed=7)
        active_b = state3[2] > 0
        prefix = jnp.concatenate(
            [jnp.zeros(1, jnp.int32),
             jnp.cumsum(active_b.astype(jnp.int32))]
        )
        i, k = 0, 2
        keep = ops.skip_keep_mask(
            kl.blk_lo[i, k], kl.blk_hi[i, k], kl.blk_dwin[i, k], prefix
        )
        ids, nk = ops.compact_blocks(keep)
        kw = dict(SRC_WIN=32, DST_WIN=32, msg_kind="div_deg", combiner="sum")
        A_k, c_k = ops.edge_combine(
            state3, kl.sp[i, k], kl.dp[i, k], kl.w[i, k], ids, nk,
            kl.blk_swin[i, k], kl.blk_dwin[i, k], **kw,
        )
        dense = jnp.arange(kl.NB, dtype=jnp.int32)
        A_r, c_r = edge_combine_ref(
            state3, kl.sp[i, k], kl.dp[i, k], kl.w[i, k], dense,
            jnp.int32(kl.NB), kl.blk_swin[i, k], kl.blk_dwin[i, k], **kw,
        )
        np.testing.assert_allclose(np.asarray(A_k), np.asarray(A_r),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))

    @pytest.mark.parametrize("win,blk", [(8, 8), (16, 32), (64, 16)])
    def test_shape_sweep(self, win, blk):
        pg, kl = _setup(win=win, blk=blk, vp=max(win, 32))
        state3 = _state(pg, density=0.5, seed=11)
        i, k = 1, 3
        args = (
            state3, kl.sp[i, k], kl.dp[i, k], kl.w[i, k],
            jnp.arange(kl.NB, dtype=jnp.int32), jnp.int32(kl.NB),
            kl.blk_swin[i, k], kl.blk_dwin[i, k],
        )
        kw = dict(SRC_WIN=win, DST_WIN=win, msg_kind="add_w", combiner="min")
        A_k, c_k = ops.edge_combine(*args, **kw)
        A_r, c_r = edge_combine_ref(*args, **kw)
        np.testing.assert_allclose(np.asarray(A_k), np.asarray(A_r),
                                   rtol=1e-6, atol=1e-6)

    def test_empty_group(self):
        """Groups with zero edges produce pure identity outputs."""
        pg, kl = _setup(scale=5, ef=1, n=8, win=8, blk=8, vp=8)
        state3 = _state(pg, density=1.0)
        # find an empty group if any; otherwise force one via zero actives
        i, k = 0, 0
        empty_state = state3.at[2].set(0.0)  # nobody active
        A_k, c_k = ops.edge_combine(
            empty_state, kl.sp[i, k], kl.dp[i, k], kl.w[i, k],
            jnp.arange(kl.NB, dtype=jnp.int32), jnp.int32(kl.NB),
            kl.blk_swin[i, k], kl.blk_dwin[i, k],
            SRC_WIN=8, DST_WIN=8, msg_kind="copy", combiner="sum",
        )
        assert np.asarray(A_k).sum() == 0
        assert np.asarray(c_k).sum() == 0


class TestDigest:
    @pytest.mark.parametrize("combiner", COMBINERS)
    @pytest.mark.parametrize("P,win", [(64, 16), (128, 128), (96, 32)])
    def test_vs_ref(self, combiner, P, win):
        rng = np.random.default_rng(P + win)
        ar = jnp.asarray(rng.standard_normal(P).astype(np.float32))
        cnt = jnp.asarray(rng.integers(0, 5, P).astype(np.int32))
        rv = jnp.asarray(rng.standard_normal(P).astype(np.float32))
        rc = jnp.asarray(rng.integers(0, 5, P).astype(np.int32))
        a1, c1 = ops.digest(ar, cnt, rv, rc, combiner=combiner, WIN=win)
        a2, c2 = digest_ref(ar, cnt, rv, rc, combiner=combiner)
        np.testing.assert_allclose(np.asarray(a1), np.asarray(a2))
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


class TestLayoutStats:
    def test_fill_reported(self):
        pg, kl = _setup()
        s = layout_stats(kl)
        assert 0 < s["fill"] <= 1.0
        assert s["real_edges"] == pg.n_edges


# NOTE: the hypothesis sweep of kernel-vs-oracle over random graphs and
# frontier densities lives in test_properties.py (skipped when hypothesis
# is absent); a fixed-seed version stays here so the kernel is always covered.
@pytest.mark.parametrize("seed,density", [(3, 0.0), (17, 0.3), (91, 1.0)])
def test_kernel_matches_ref_fixed(seed, density):
    """Kernel == oracle on a few fixed graph × frontier combinations."""
    pg, kl = _setup(scale=6, ef=4, seed=seed, n=2, win=16, blk=16, vp=16)
    state3 = _state(pg, density=density, seed=seed % 97)
    i, k = 0, 1
    args = (
        state3, kl.sp[i, k], kl.dp[i, k], kl.w[i, k],
        jnp.arange(kl.NB, dtype=jnp.int32), jnp.int32(kl.NB),
        kl.blk_swin[i, k], kl.blk_dwin[i, k],
    )
    kw = dict(SRC_WIN=16, DST_WIN=16, msg_kind="div_deg", combiner="sum")
    A_k, c_k = ops.edge_combine(*args, **kw)
    A_r, c_r = edge_combine_ref(*args, **kw)
    np.testing.assert_allclose(np.asarray(A_k), np.asarray(A_r),
                               rtol=1e-5, atol=1e-6)
