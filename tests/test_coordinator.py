"""File-based coordinator (core/coordinator.py): barrier semantics with
stragglers, heartbeat-timeout detection of a SIGKILLed worker process,
shard-ascending aggregator reduction equivalence, and the abort poison
pill. Everything here is stdlib-speed — no jax, no engine."""

import os
import subprocess
import sys
import threading
import time

import pytest

from repro.core.coordinator import (
    FileCoordinator, RunAborted, atomic_write_json, read_json,
)


@pytest.fixture
def coord(tmp_path):
    return FileCoordinator(str(tmp_path / "coord"), 3,
                           heartbeat_interval=0.05, heartbeat_timeout=0.5)


class TestBarrier:
    def test_wait_arrivals_with_straggler(self, coord):
        """The barrier stays open until the LAST worker arrives — two fast
        workers plus one straggler that lands 10 poll ticks later."""
        stats = dict(n_active=1, n_msgs=2, agg=0.5, active_blocks=1)
        coord.arrive(0, 0, stats)
        coord.arrive(0, 2, stats)

        def straggler():
            time.sleep(10 * FileCoordinator.POLL)
            coord.arrive(0, 1, dict(stats, n_active=7))

        ticks = []
        t = threading.Thread(target=straggler)
        t.start()
        got = coord.wait_arrivals(0, on_wait=lambda g: ticks.append(len(g)))
        t.join()
        assert set(got) == {0, 1, 2}
        assert got[1]["n_active"] == 7
        # the on_wait hook really ran while the straggler was missing
        assert ticks and all(n == 2 for n in ticks)

    def test_commit_round_trip_and_worker_wait(self, coord):
        totals = dict(n_active=3, n_msgs=9, agg=1.25, active_blocks=4)
        published = coord.publish_commit(2, totals, halt=False,
                                        ckpt_landed=True)
        got = coord.wait_commit(2, shard=1)
        assert got == published
        assert got["halt"] is False and got["ckpt_landed"] is True
        assert got["n_active"] == 3 and got["agg"] == 1.25
        assert coord.commit(3) is None  # non-blocking probe

    def test_wait_file_sees_marker(self, coord, tmp_path):
        marker = str(tmp_path / "announce.json")

        def publish():
            time.sleep(5 * FileCoordinator.POLL)
            atomic_write_json(marker, dict(ok=True))

        t = threading.Thread(target=publish)
        t.start()
        coord.wait_file(marker, shard=0)  # returns instead of hanging
        t.join()
        assert read_json(marker) == dict(ok=True)

    def test_gc_steps(self, coord):
        for s in range(4):
            coord.arrive(s, 0, dict(n_active=0, n_msgs=0, agg=0.0))
        coord.gc_steps(before=3)
        assert coord.arrivals(2) == {}
        assert 0 in coord.arrivals(3)


class TestBarrierBackoff:
    def test_poll_delays_start_fast_and_cap(self, coord):
        """The wait backoff: first tick at POLL (a nearly-open barrier
        stays fast), monotone growth, settles at POLL_MAX."""
        delays = coord._poll_delays()
        seq = [next(delays) for _ in range(16)]
        assert seq[0] == FileCoordinator.POLL
        assert all(b >= a for a, b in zip(seq, seq[1:]))
        assert seq[-1] == FileCoordinator.POLL_MAX
        assert max(seq) == FileCoordinator.POLL_MAX
        # one generator per wait: a fresh wait starts fast again
        assert next(coord._poll_delays()) == FileCoordinator.POLL

    def test_wait_commit_poll_count_ceiling(self, coord, monkeypatch):
        """Regression for the busy-wait: a commit that lands after one
        (simulated) second of blocking must cost ~a dozen polls, not the
        200 the old fixed POLL=0.005 spin performed."""
        import repro.core.coordinator as mod

        clock = [0.0]
        polls = []

        def fake_sleep(d):
            polls.append(d)
            clock[0] += d
            if clock[0] >= 1.0 and coord.commit(0) is None:
                coord.publish_commit(
                    0, dict(n_active=0, n_msgs=0, agg=0.0, active_blocks=0),
                    halt=True, ckpt_landed=False)

        monkeypatch.setattr(mod.time, "sleep", fake_sleep)
        rec = coord.wait_commit(0, shard=0)
        assert rec["halt"] is True
        assert sum(polls) >= 1.0  # really waited the simulated second
        assert len(polls) <= 25, len(polls)  # fixed-POLL spin would be ~200


class TestReduction:
    def test_reduce_matches_threaded_accumulation(self):
        """The coordinator's reduction must be the threaded driver's loop —
        same order (shard-ascending), same types (int/int/Python-float
        left fold) — so the committed totals are bit-identical."""
        per_shard = [
            dict(n_active=5, n_msgs=17, agg=0.1, active_blocks=2),
            dict(n_active=0, n_msgs=3, agg=1e-17, active_blocks=0),
            dict(n_active=2, n_msgs=8, agg=0.3, active_blocks=1),
        ]
        # arrival order scrambled: reduction must sort by shard, not mtime
        arrivals = {2: per_shard[2], 0: per_shard[0], 1: per_shard[1]}
        got = FileCoordinator.reduce_arrivals(arrivals)

        n_active = n_msgs = 0
        agg = 0.0
        for rec in per_shard:  # the engine's per-destination accumulation
            n_active += int(rec["n_active"])
            n_msgs += int(rec["n_msgs"])
            agg += float(rec["agg"])
        assert got["n_active"] == n_active
        assert got["n_msgs"] == n_msgs
        assert got["agg"] == agg  # bitwise: same fold order and types
        assert got["active_blocks"] == 3

    def test_float_fold_order_is_shard_ascending(self):
        """Float addition does not commute bitwise; pin the fold order."""
        a, b, c = 0.1, 0.2, 0.3
        arrivals = {w: dict(n_active=0, n_msgs=0, agg=v)
                    for w, v in enumerate((a, b, c))}
        assert FileCoordinator.reduce_arrivals(arrivals)["agg"] == (a + b) + c


class TestLiveness:
    def test_heartbeat_daemon_keeps_fresh(self, coord):
        t = coord.start_heartbeat(0)
        try:
            time.sleep(0.2)
            assert coord.heartbeat_age(0) < 0.5
            assert not coord.stale(0)
        finally:
            t.stop.set()

    def test_missing_heartbeat_is_stale(self, coord):
        assert coord.heartbeat_age(2) == float("inf")
        assert coord.stale(2)

    def test_frozen_mtime_with_progress_stays_fresh(self, coord):
        """Regression: staleness was judged from ``os.path.getmtime``, and a
        shared filesystem that rounds mtime to whole seconds (or a skewed
        writer clock) false-tripped worker-dead detection. The fixture
        freezes the heartbeat file's mtime at the epoch while the record's
        ``seq`` keeps progressing — the watcher must stay fresh, because
        progress lives in the JSON, not the inode."""
        hb = coord.heartbeat_path(0)
        coord.beat(0)
        os.utime(hb, (0, 0))  # frozen-mtime fixture: inode says 1970
        assert coord.heartbeat_age(0) == 0.0  # first observation is fresh
        for _ in range(3):
            time.sleep(0.01)
            coord.beat(0)  # seq progresses...
            os.utime(hb, (0, 0))  # ...while the mtime never moves
            assert coord.heartbeat_age(0) == 0.0
        assert not coord.stale(0)

    def test_fresh_mtime_without_progress_goes_stale(self, coord):
        """The inverse direction: a rewritten-but-identical record (fresh
        mtime, no sequence progress) is a hung worker, and the age must
        keep growing from the first sighting of that content."""
        coord.beat(1)
        rec = read_json(coord.heartbeat_path(1))
        assert coord.heartbeat_age(1) == 0.0
        time.sleep(0.05)
        # same (seq, t) content republished: mtime advances, progress doesn't
        atomic_write_json(coord.heartbeat_path(1), rec)
        assert coord.heartbeat_age(1) >= 0.05

    def test_restarted_coord_server_grants_boot_grace(self):
        """Regression for the coordinator-restart drill: a successor
        CoordServer has seen NO beats at boot (every live worker looks
        beat-less until its reconnect lands), and the old rule — no beat
        on record => stale — would condemn all of them instantly and spiral
        a healthy run into respawning every worker. The watcher must extend
        grace while the coordinator restarts: a never-seen shard only goes
        stale ``heartbeat_timeout + boot_grace`` after THIS server booted,
        and an explicit ``grant_grace`` (the respawn path) extends further."""
        from repro.launch.net import CoordServer

        coord = CoordServer(3, heartbeat_timeout=0.1, boot_grace=0.3)
        try:
            # freshly booted: no worker has ever beaten, none is stale
            assert all(coord.heartbeat_age(w) == float("inf")
                       for w in range(3))
            assert not any(coord.stale(w) for w in range(3))
            time.sleep(0.15)  # past heartbeat_timeout, inside boot grace
            assert not any(coord.stale(w) for w in range(3))
            deadline = time.time() + 10
            while not coord.stale(0):  # boot grace expires -> stale
                assert time.time() < deadline, "boot grace never expired"
                time.sleep(0.02)
            # the respawn path's explicit grant waives staleness again
            coord.grant_grace(0, 30.0)
            assert not coord.stale(0)
            assert coord.stale(1)  # ...but only for the granted shard
        finally:
            coord.close()

    def test_sigkilled_worker_process_goes_stale(self, coord, tmp_path):
        """The real detection path: a separate OS process heartbeats
        through the shared directory; kill -9 stops the beats and the
        coordinator's staleness probe flips within the timeout."""
        src_root = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        env = dict(os.environ)
        env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
        p = subprocess.Popen(
            [sys.executable, "-c",
             "import sys, time\n"
             "from repro.core.coordinator import FileCoordinator\n"
             f"c = FileCoordinator({coord.dir!r}, 3, "
             "heartbeat_interval=0.05)\n"
             "c.start_heartbeat(1)\n"
             "time.sleep(60)\n"],
            env=env,
        )
        try:
            deadline = time.time() + 10
            while coord.heartbeat_age(1) == float("inf"):
                assert time.time() < deadline, "worker never beat"
                time.sleep(0.02)
            assert not coord.stale(1)
            p.kill()  # SIGKILL: no atexit, no cleanup — beats just stop
            p.wait()
            deadline = time.time() + 10
            while not coord.stale(1):
                assert time.time() < deadline, "kill -9 never detected"
                time.sleep(0.02)
            assert coord.heartbeat_age(1) > coord.heartbeat_timeout
        finally:
            if p.poll() is None:
                p.kill()
                p.wait()


class TestAbort:
    def test_abort_unblocks_commit_wait(self, coord):
        def poison():
            time.sleep(5 * FileCoordinator.POLL)
            coord.abort("drill")

        t = threading.Thread(target=poison)
        t.start()
        with pytest.raises(RunAborted, match="drill"):
            coord.wait_commit(0, shard=1)  # no commit will ever land
        t.join()
        assert coord.aborted() == "drill"

    def test_abort_unblocks_marker_wait(self, coord, tmp_path):
        coord.abort("stop")
        with pytest.raises(RunAborted, match="stop"):
            coord.wait_file(str(tmp_path / "never.json"), shard=0)

    def test_read_json_partial_file_is_unpublished(self, tmp_path):
        p = str(tmp_path / "rec.json")
        with open(p, "w") as f:
            f.write('{"truncated": ')
        assert read_json(p) is None
        assert read_json(str(tmp_path / "absent.json")) is None

def test_worker_import_path_is_jax_free():
    """Workers start their heartbeat BEFORE any heavy import; that only
    holds if importing the coordinator (and the package __init__s it
    triggers) never pulls in jax. Regression: an eager repro.core
    __init__ once loaded the whole engine here, and three workers
    cold-importing jax on a loaded single-core machine outlived the
    heartbeat grace window — a false 'worker dead' detection."""
    src_root = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    subprocess.run(
        [sys.executable, "-c",
         "import sys\n"
         "import repro.launch.procs\n"
         "import repro.core.coordinator\n"
         "assert 'jax' not in sys.modules, "
         "'worker startup imports must stay light'\n"],
        check=True, env=env,
    )
