"""Cross-mode equivalence matrix: EVERY algorithm in ``core/algorithms.py``
runs under ``basic`` / ``streamed`` (combiner path and combiner-less OMS
path) / pipelined-streamed (half-duplex, full-duplex, varint-delta
compressed, and payload-compressed), and the results must agree *bit for
bit* — same halt step, same active bitmaps, same final values.

One documented carve-out: float-SUM programs (PageRank). The pipelined
sender combines each outgoing group A_s(i→k) before transmitting (§4/§5) —
a legal reassociation of IEEE additions, so grouped modes can differ from
``basic``'s message-sequential sum in the last ulp (observed <= 4e-9 on
values of ~1e-2; everything else about the run, including the halt step and
message counts, stays identical). Order-insensitive reductions (MIN/MAX,
integer programs, exact-integer float sums) have no such freedom: for them
the assertion is strict equality, which is what pins down chunk-boundary,
slice-boundary and channel-ordering bugs.

``GRAPHD_TEST_EDGE_BLOCK`` (CI sets it tiny) forces many chunk boundaries so
every block/chunk/slice edge case is crossed; the default keeps local runs
quick.
"""

import copy
import os
import tempfile

import numpy as np
import pytest

from repro.core import (
    ChannelConfig, EngineConfig, GraphDEngine, GraphDJob, MemoryBudget,
    StreamConfig,
)
from repro.core.algorithms import (
    BFS, DegreeSum, DistinctInLabels, HashMin, LabelSpread, PageRank,
    SecondMinLabel, SSSP,
)
from repro.core.plan import (
    GraphMeta, estimate_memory, plan as make_plan, ram_total,
)
from repro.graph import partition_graph, partition_graph_streamed, rmat_graph

EDGE_BLOCK = int(os.environ.get("GRAPHD_TEST_EDGE_BLOCK", "32"))
N_SHARDS = 3

# (name, program factory, exact): ``exact`` means bit-identical values are
# REQUIRED; False allows the ulp slack of reassociated float sums.
ALGORITHMS = [
    ("pagerank", lambda g, rmap: PageRank(supersteps=5), False),
    ("hashmin", lambda g, rmap: HashMin(), True),
    ("sssp", lambda g, rmap: SSSP(
        int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])), True),
    ("bfs", lambda g, rmap: BFS(
        int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])), True),
    ("degreesum", lambda g, rmap: DegreeSum(), True),
    ("labelspread", lambda g, rmap: LabelSpread(), True),
    ("distinct", lambda g, rmap: DistinctInLabels(n_groups=8, rounds=2), True),
    ("secondmin", lambda g, rmap: SecondMinLabel(), True),
]

# every streamed variant the engine offers; basic is the reference.
# "pipelined" is PR-3's sender-only half-duplex pipeline; "full-duplex"
# adds the background receiver digest; "payload-compressed" additionally
# runs the (lossless) payload codec on every wire and disk stream — all
# of which must be invisible in the results.
STREAMED_VARIANTS = [
    ("streamed", dict()),
    ("pipelined", dict(pipeline=True, full_duplex=False)),
    ("full-duplex", dict(pipeline=True)),
    ("pipelined-compressed", dict(pipeline=True, compress=True)),
    ("payload-compressed", dict(pipeline=True, compress=True,
                                compress_payload=True)),
    # the codec auto-pick: first superstep raw + sampled, then the measured
    # per-channel choice — the switch point must be invisible in results
    ("payload-auto", dict(pipeline=True, compress=True,
                          compress_payload="auto")),
]

# semi-external cache budgets (bytes per shard, scaled to block_bytes at
# run time): 0 = pure streaming, a few blocks = eviction churn, and a
# "fits entirely" point where every block is served from RAM after its
# first read. Results must be bit-identical at EVERY point.
SEMI_EXTERNAL_BUDGET_BLOCKS = (0, 2, None)  # None -> whole graph / n_shards


def _streamed_config(pipeline=False, compress=False, compress_payload=False,
                     full_duplex=True):
    return EngineConfig(
        mode="streamed",
        stream=StreamConfig(chunk_blocks=2),
        channel=ChannelConfig(pipeline=pipeline, compress=compress,
                              compress_payload=compress_payload,
                              full_duplex=full_duplex),
    )


def _store_for(kwargs, stores):
    store, store_c, store_cp = stores
    if kwargs.get("compress_payload"):
        return store_cp
    if kwargs.get("compress"):
        return store_c
    return store


@pytest.fixture(scope="module")
def matrix_graph():
    g = rmat_graph(scale=6, edge_factor=6, seed=5, weights="uniform")
    pg, rmap = partition_graph(g, n_shards=N_SHARDS, edge_block=EDGE_BLOCK)
    with tempfile.TemporaryDirectory(prefix="graphd-eqv-") as d:
        pgs, _, store = partition_graph_streamed(
            g, N_SHARDS, os.path.join(d, "plain"), edge_block=EDGE_BLOCK,
            recode=rmap,
        )
        # a compressed spill of the SAME graph: the pipelined-compressed
        # variant reads varint-delta edge blocks end to end
        _, _, store_c = partition_graph_streamed(
            g, N_SHARDS, os.path.join(d, "compressed"),
            edge_block=EDGE_BLOCK, recode=rmap, compress=True,
        )
        # ... and a fully-compressed one (position AND weight channels):
        # the payload-compressed variant decodes every stream end to end
        _, _, store_cp = partition_graph_streamed(
            g, N_SHARDS, os.path.join(d, "payload"),
            edge_block=EDGE_BLOCK, recode=rmap, compress=True,
            compress_payload=True,
        )
        assert store_c.disk_bytes() < store.disk_bytes()
        assert store_cp.disk_bytes() < store_c.disk_bytes()
        yield g, rmap, pg, pgs, (store, store_c, store_cp)


def _run(eng):
    (values, active), hist = eng.run(max_supersteps=60)
    return (np.asarray(values), np.asarray(active), len(hist),
            [r.n_active for r in hist], [r.n_msgs for r in hist])


@pytest.mark.parametrize("name,factory,exact",
                         ALGORITHMS, ids=[a[0] for a in ALGORITHMS])
def test_matrix_all_modes_match_basic(matrix_graph, name, factory, exact):
    g, rmap, pg, pgs, stores = matrix_graph
    v_ref, a_ref, steps_ref, act_ref, msgs_ref = _run(
        GraphDEngine(pg, factory(g, rmap), config=EngineConfig(mode="basic"))
    )
    for variant, kwargs in STREAMED_VARIANTS:
        st = _store_for(kwargs, stores)
        v, a, steps, act, msgs = _run(
            GraphDEngine(pgs, factory(g, rmap),
                         config=_streamed_config(**kwargs), stream_store=st)
        )
        assert steps == steps_ref, (name, variant, "halt step")
        assert act == act_ref, (name, variant, "active trajectory")
        assert msgs == msgs_ref, (name, variant, "message counts")
        assert np.array_equal(a, a_ref), (name, variant, "active bitmap")
        if exact:
            assert np.array_equal(v, v_ref), (name, variant, "values")
        else:
            # reassociated IEEE sums: ulp-scale slack, nothing more
            np.testing.assert_allclose(v, v_ref, rtol=3e-6, atol=0)


@pytest.mark.parametrize("name,factory,exact",
                         ALGORITHMS, ids=[a[0] for a in ALGORITHMS])
def test_matrix_semi_external_matches_streamed(matrix_graph, name, factory,
                                               exact):
    """The semi-external column of the matrix: a hot-block cache budget
    changes only WHERE an edge block is read from (RAM copy vs memmap), so
    the results must be bit-identical to pure ``mode="streamed"`` at EVERY
    budget — 0, an eviction-churning few blocks, and "the whole graph fits"
    — for all 8 algorithms, float-SUM programs included (the fold consumes
    the same staged rows either way: no reassociation freedom at all)."""
    g, rmap, pg, pgs, stores = matrix_graph
    store = stores[0]
    v_ref, a_ref, steps_ref, act_ref, msgs_ref = _run(
        GraphDEngine(pgs, factory(g, rmap), config=_streamed_config(),
                     stream_store=store)
    )
    block_bytes = store.block_bytes()
    nonempty = store.nonempty_blocks()
    for blocks in SEMI_EXTERNAL_BUDGET_BLOCKS:
        if blocks is None:  # the engine caps capacity at cache * n_shards
            cache = -(-nonempty * block_bytes // N_SHARDS)
        else:
            cache = blocks * block_bytes
        eng = GraphDEngine(
            pgs, factory(g, rmap),
            config=EngineConfig(
                mode="streamed",
                stream=StreamConfig(chunk_blocks=2, cache_bytes=cache),
            ),
            stream_store=store,
        )
        (values, active), hist = eng.run(max_supersteps=60)
        v, a = np.asarray(values), np.asarray(active)
        assert len(hist) == steps_ref, (name, cache, "halt step")
        assert [r.n_active for r in hist] == act_ref, (name, cache, "active")
        assert [r.n_msgs for r in hist] == msgs_ref, (name, cache, "msgs")
        assert np.array_equal(a, a_ref), (name, cache, "active bitmap")
        assert np.array_equal(v, v_ref), (name, cache, "values")
        if blocks == 0:
            # budget 0 degenerates to counted pure streaming
            assert sum(r.cache_hits for r in hist) == 0, (name, "budget 0")
        if blocks is None:
            # fits entirely: each block pays disk at most once, ever
            assert sum(r.blocks_read for r in hist) <= nonempty, (
                name, "fits-entirely budget re-read a block from disk")


def test_semi_external_sssp_skips_inactive_shards(tmp_path):
    """The selective-scheduling drill (§3.2 skip() + residency counters):
    SSSP on a chain crosses the shards one frontier vertex at a time, so in
    late rounds whole source shards have no active vertices — and those
    shards' edge blocks must see ZERO reads (not cache hits: no I/O at
    all), while the records tally them as skipped."""
    from repro.graph import chain_graph

    n_vertices = 48
    g = chain_graph(n_vertices)
    pgs, rmap, store = partition_graph_streamed(
        g, N_SHARDS, str(tmp_path / "chain"), edge_block=4
    )
    src = int(rmap.to_new(np.array([0]))[0])
    eng = GraphDEngine(
        pgs, SSSP(src), config=_streamed_config(), stream_store=store
    )
    # spy on the ONE disk funnel, counting block reads per SOURCE shard
    # (plain streamed config => no owner views: every read hits `store`)
    reads = [0] * N_SHARDS
    orig = store.read_blocks

    def spy(i, k, ids, *out):
        reads[i] += len(ids)
        return orig(i, k, ids, *out)

    store.read_blocks = spy
    trace = []  # (reads snapshot, shard-has-active-sources, record)

    def on_step(rec, state):
        _, active = state
        trace.append((list(reads),
                      np.asarray(active).any(axis=1).copy(), rec))

    try:
        eng.run(max_supersteps=200, on_step=on_step)
    finally:
        del store.read_blocks  # restore the class method
    # step s+1 folds the frontier that step s left: a shard inactive at the
    # end of s must contribute zero disk reads during s+1
    drilled = 0
    for (reads0, alive, _), (reads1, _, rec) in zip(trace, trace[1:]):
        for w in range(N_SHARDS):
            if not alive[w]:
                assert reads1[w] == reads0[w], (
                    f"superstep {rec.step}: shard {w} had no active "
                    f"sources yet its blocks were read")
                drilled += 1
        if not alive.all():
            assert rec.blocks_skipped > 0, rec.step
    # the drill must actually have exercised late rounds with dead shards
    assert drilled > 0, "chain drill never produced an inactive shard"


def test_payload_auto_records_choice(matrix_graph):
    """``compress_payload="auto"``: the decision is taken from the first
    superstep's sample and recorded (with measured ratios) in
    ``ChannelStats.payload_choice``; the engine's later per-step stores run
    the picked per-channel format."""
    g, rmap, pg, pgs, stores = matrix_graph
    eng = GraphDEngine(
        pgs, PageRank(supersteps=5),
        config=_streamed_config(pipeline=True, compress_payload="auto"),
        stream_store=stores[0],
    )
    _run(eng)
    assert not eng._payload_auto  # decided after the first superstep
    choice = eng.channel_stats.payload_choice
    assert "msg=" in choice and "(" in choice, choice
    # PageRank combined groups carry a cnt channel; it was sampled too
    assert "cnt=" in choice, choice


def test_job_facade_matches_handwired_streamed_pipeline(matrix_graph,
                                                        tmp_path):
    """The job-facade column of the matrix (the PR's acceptance bar):
    ``GraphDJob(PageRank(supersteps=9), graph, budget=..., workdir=...)``
    — one call, no hand-wiring — must be BIT-IDENTICAL to the current
    manual partition_graph_streamed + EdgeStreamStore + GraphDEngine
    pipeline setup, float-SUM included (same grouping, same chunking, same
    transmit order => no reassociation freedom between the two)."""
    g, rmap, pg, pgs, (store, store_c, store_cp) = matrix_graph
    # a budget only the §4 pipeline fits: the planner's floor for the
    # pipelined fold (ONE group + ONE receiver accumulator; at this floor
    # the ladder has shed the batch lanes and the full-duplex receiver
    # staging), computed with the same algebra the planner runs, on the
    # realized geometry
    P_est = max((-(-g.n_vertices // N_SHARDS) + 7) // 8 * 8, 8)
    common = dict(n_shards=N_SHARDS, P=P_est, E_cap=pgs.E_cap,
                  edge_block=EDGE_BLOCK, value_itemsize=4, msg_itemsize=4,
                  combined=True, chunk_blocks=1, inflight=1, group_batch=1)
    floor_pipe = ram_total(
        estimate_memory(mode="streamed", pipeline=True, full_duplex=False,
                        **common),
        "streamed")
    floor_plain = ram_total(
        estimate_memory(mode="streamed", pipeline=False, **common),
        "streamed")
    assert floor_pipe < floor_plain  # the budget below really forces §4

    job = GraphDJob(
        PageRank(supersteps=9), g,
        budget=MemoryBudget(ram_per_shard=floor_pipe, n_shards=N_SHARDS),
        workdir=str(tmp_path / "job"), edge_block=EDGE_BLOCK,
    )
    assert job.plan.mode == "streamed" and job.plan.pipeline
    assert "streamed+pipeline" in job.plan.explain()
    res = job.run(max_supersteps=60)

    # hand-wired reference with the SAME physical knobs the plan derived
    st = job.plan.config.stream
    ch = job.plan.config.channel
    eng = GraphDEngine(
        pgs, PageRank(supersteps=9), config=job.plan.config,
        stream_store=store,
    )
    assert eng._stream_reader.chunk_blocks == st.chunk_blocks
    (values, active), hist = eng.run(max_supersteps=60)
    assert res.values == eng.gather_values(values)  # bit-identical
    assert res.n_supersteps == len(hist)
    assert [r.n_msgs for r in res.history] == [r.n_msgs for r in hist]
    assert not ch.compress  # disk was unconstrained; nothing forced it
    job.close()


@pytest.mark.parametrize("name,factory,exact",
                         ALGORITHMS, ids=[a[0] for a in ALGORITHMS])
def test_matrix_processes_launch_matches_full_duplex(matrix_graph, tmp_path,
                                                     name, factory, exact):
    """The ``processes`` columns of the matrix: the same algorithm run as
    THREE REAL OS PROCESSES — over the shared-filesystem transport AND over
    the TCP socket transport (``transport="sockets"``) — must be
    bit-identical to the single-process full-duplex streamed run of the
    SAME plan: values, active/message trajectories, aggregator, and
    density, float programs included (the per-group fold and
    source-ascending digest order are identical on all three sides, so
    there is no reassociation freedom at all, not even the PageRank ulp
    carve-out)."""
    g, rmap, *_ = matrix_graph
    p = make_plan(factory(g, rmap), GraphMeta.of(g),
                  MemoryBudget(n_shards=N_SHARDS), edge_block=EDGE_BLOCK,
                  launch="processes")
    assert p.mode == "streamed" and p.pipeline
    assert p.config.channel.full_duplex and p.launch == "processes"
    jt = GraphDJob(factory(g, rmap), g, plan=copy.deepcopy(p),
                   workdir=str(tmp_path / "threads"))
    rt = jt.run(max_supersteps=60)
    jp = GraphDJob(factory(g, rmap), g, plan=copy.deepcopy(p),
                   workdir=str(tmp_path / "procs"), launch="processes")
    rp = jp.run(max_supersteps=60)
    js = GraphDJob(factory(g, rmap), g, plan=copy.deepcopy(p),
                   workdir=str(tmp_path / "socks"), launch="processes",
                   launch_opts=dict(transport="sockets"))
    rs = js.run(max_supersteps=60)
    for label, r in (("files", rp), ("sockets", rs)):
        assert r.n_supersteps == rt.n_supersteps, (name, label)
        for field in ("n_active", "n_msgs", "agg", "density"):
            assert [getattr(x, field) for x in r.history] == \
                   [getattr(x, field) for x in rt.history], \
                   (name, label, field)
        assert rt.values == r.values, (name, label)  # bit-identical
    # the socket run used no shared-filesystem exchange: the announce
    # markers of the file transport were never written
    assert not os.path.exists(
        os.path.join(js._dir("procs", js._tag), "announce"))
    jt.close()
    jp.close()
    js.close()


def test_matrix_streamed_variants_agree_exactly(matrix_graph):
    """The streamed variants must agree bit-for-bit with EACH OTHER even for
    float-SUM programs when their grouping matches: pipelining (either
    duplex), compression (positions or payloads) are transport changes, and
    transport must never touch values. (The pipelined sender combines per
    group like the log-attached fold does, so those families are compared,
    not the direct fold.)"""
    g, rmap, pg, pgs, stores = matrix_graph
    prog = lambda: PageRank(supersteps=5)
    results = {}
    for variant, kwargs in STREAMED_VARIANTS[1:]:  # the grouped variants
        v, a, *_ = _run(
            GraphDEngine(pgs, prog(), config=_streamed_config(**kwargs),
                         stream_store=_store_for(kwargs, stores))
        )
        results[variant] = (v, a)
    v_ref, a_ref = results["pipelined"]
    for variant, (v, a) in results.items():
        assert np.array_equal(v, v_ref), variant
        assert np.array_equal(a, a_ref), variant
