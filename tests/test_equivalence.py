"""Cross-mode equivalence matrix: EVERY algorithm in ``core/algorithms.py``
runs under ``basic`` / ``streamed`` (combiner path and combiner-less OMS
path) / pipelined-streamed (plain and varint-delta compressed), and the
results must agree *bit for bit* — same halt step, same active bitmaps, same
final values.

One documented carve-out: float-SUM programs (PageRank). The pipelined
sender combines each outgoing group A_s(i→k) before transmitting (§4/§5) —
a legal reassociation of IEEE additions, so grouped modes can differ from
``basic``'s message-sequential sum in the last ulp (observed <= 4e-9 on
values of ~1e-2; everything else about the run, including the halt step and
message counts, stays identical). Order-insensitive reductions (MIN/MAX,
integer programs, exact-integer float sums) have no such freedom: for them
the assertion is strict equality, which is what pins down chunk-boundary,
slice-boundary and channel-ordering bugs.

``GRAPHD_TEST_EDGE_BLOCK`` (CI sets it tiny) forces many chunk boundaries so
every block/chunk/slice edge case is crossed; the default keeps local runs
quick.
"""

import os
import tempfile

import numpy as np
import pytest

from repro.core import GraphDEngine
from repro.core.algorithms import (
    BFS, DegreeSum, DistinctInLabels, HashMin, LabelSpread, PageRank,
    SecondMinLabel, SSSP,
)
from repro.graph import partition_graph, partition_graph_streamed, rmat_graph

EDGE_BLOCK = int(os.environ.get("GRAPHD_TEST_EDGE_BLOCK", "32"))
N_SHARDS = 3

# (name, program factory, exact): ``exact`` means bit-identical values are
# REQUIRED; False allows the ulp slack of reassociated float sums.
ALGORITHMS = [
    ("pagerank", lambda g, rmap: PageRank(supersteps=5), False),
    ("hashmin", lambda g, rmap: HashMin(), True),
    ("sssp", lambda g, rmap: SSSP(
        int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])), True),
    ("bfs", lambda g, rmap: BFS(
        int(rmap.to_new(np.array([int(g.vertex_ids[0])]))[0])), True),
    ("degreesum", lambda g, rmap: DegreeSum(), True),
    ("labelspread", lambda g, rmap: LabelSpread(), True),
    ("distinct", lambda g, rmap: DistinctInLabels(n_groups=8, rounds=2), True),
    ("secondmin", lambda g, rmap: SecondMinLabel(), True),
]

# every streamed variant the engine offers; basic is the reference
STREAMED_VARIANTS = [
    ("streamed", dict()),
    ("pipelined", dict(pipeline=True)),
    ("pipelined-compressed", dict(pipeline=True, compress=True)),
]


@pytest.fixture(scope="module")
def matrix_graph():
    g = rmat_graph(scale=6, edge_factor=6, seed=5, weights="uniform")
    pg, rmap = partition_graph(g, n_shards=N_SHARDS, edge_block=EDGE_BLOCK)
    with tempfile.TemporaryDirectory(prefix="graphd-eqv-") as d:
        pgs, _, store = partition_graph_streamed(
            g, N_SHARDS, os.path.join(d, "plain"), edge_block=EDGE_BLOCK,
            recode=rmap,
        )
        # a compressed spill of the SAME graph: the pipelined-compressed
        # variant reads varint-delta edge blocks end to end
        _, _, store_c = partition_graph_streamed(
            g, N_SHARDS, os.path.join(d, "compressed"),
            edge_block=EDGE_BLOCK, recode=rmap, compress=True,
        )
        assert store_c.disk_bytes() < store.disk_bytes()
        yield g, rmap, pg, pgs, store, store_c


def _run(eng):
    (values, active), hist = eng.run(max_supersteps=60)
    return (np.asarray(values), np.asarray(active), len(hist),
            [r.n_active for r in hist], [r.n_msgs for r in hist])


@pytest.mark.parametrize("name,factory,exact",
                         ALGORITHMS, ids=[a[0] for a in ALGORITHMS])
def test_matrix_all_modes_match_basic(matrix_graph, name, factory, exact):
    g, rmap, pg, pgs, store, store_c = matrix_graph
    v_ref, a_ref, steps_ref, act_ref, msgs_ref = _run(
        GraphDEngine(pg, factory(g, rmap), mode="basic")
    )
    for variant, kwargs in STREAMED_VARIANTS:
        st = store_c if kwargs.get("compress") else store
        v, a, steps, act, msgs = _run(
            GraphDEngine(pgs, factory(g, rmap), mode="streamed",
                         stream_store=st, stream_chunk_blocks=2, **kwargs)
        )
        assert steps == steps_ref, (name, variant, "halt step")
        assert act == act_ref, (name, variant, "active trajectory")
        assert msgs == msgs_ref, (name, variant, "message counts")
        assert np.array_equal(a, a_ref), (name, variant, "active bitmap")
        if exact:
            assert np.array_equal(v, v_ref), (name, variant, "values")
        else:
            # reassociated IEEE sums: ulp-scale slack, nothing more
            np.testing.assert_allclose(v, v_ref, rtol=3e-6, atol=0)


def test_matrix_streamed_variants_agree_exactly(matrix_graph):
    """The streamed variants must agree bit-for-bit with EACH OTHER even for
    float-SUM programs when their grouping matches: pipelining and
    compression are transport changes, and transport must never touch
    values. (The pipelined sender combines per group like the log-attached
    fold does, so those two families are compared, not the direct fold.)"""
    g, rmap, pg, pgs, store, store_c = matrix_graph
    prog = lambda: PageRank(supersteps=5)
    v_pipe, a_pipe, *_ = _run(
        GraphDEngine(pgs, prog(), mode="streamed", stream_store=store,
                     stream_chunk_blocks=2, pipeline=True)
    )
    v_cmp, a_cmp, *_ = _run(
        GraphDEngine(pgs, prog(), mode="streamed", stream_store=store_c,
                     stream_chunk_blocks=2, pipeline=True, compress=True)
    )
    assert np.array_equal(v_pipe, v_cmp)
    assert np.array_equal(a_pipe, a_cmp)
